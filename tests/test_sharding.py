"""Sharding plan + PartitionSpec rules (single-device mesh stand-ins)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.api import build_model
from repro.sharding.specs import MeshPlan, _spec_for, make_plan, param_specs


class FakeMesh:
    """Duck-typed mesh: only .shape and .size are consulted by the specs."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.size = 1
        for v in shape.values():
            self.size *= v


def plan_for(arch, multi_pod=False):
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi_pod
                    else {"data": 16, "model": 16})
    return make_plan(mesh, get_config(arch))


class TestPlan:
    def test_data_client_arch(self):
        p = plan_for("olmo-1b")
        assert p.client_axes == ("data",)
        assert p.num_clients == 16
        assert p.fsdp_axes == ()

    def test_data_client_multipod_extends_clients(self):
        p = plan_for("olmo-1b", multi_pod=True)
        assert p.client_axes == ("pod", "data")
        assert p.num_clients == 32

    def test_pod_client_arch_single_pod(self):
        p = plan_for("llama3-405b")
        assert p.client_axes == ()
        assert p.num_clients == 1
        assert p.fsdp_axes == ("data",)
        assert p.batch_axes == ("data",)

    def test_pod_client_arch_multi_pod(self):
        p = plan_for("llama3-405b", multi_pod=True)
        assert p.client_axes == ("pod",)
        assert p.num_clients == 2


class TestSpecRules:
    def test_divisible_tp_dim_sharded(self):
        p = plan_for("llama3-405b")
        spec = _spec_for((16384, 128, 128), ("embed", "heads", "head_dim"), p,
                         client_leading=False)
        assert spec == P("data", "model", None)

    def test_non_divisible_falls_back_to_replication(self):
        p = plan_for("whisper-small")
        # whisper: 12 heads on a 16-way model axis -> replicate
        spec = _spec_for((768, 12, 64), ("embed", "heads", "head_dim"), p,
                         client_leading=False)
        assert spec == P(None, None, None)

    def test_vocab_sharded_when_divisible(self):
        p = plan_for("llama3-405b")
        spec = _spec_for((128256, 16384), ("vocab", "embed"), p,
                         client_leading=False)
        assert spec == P("model", "data")

    def test_experts_sharded(self):
        p = plan_for("arctic-480b")
        spec = _spec_for((128, 7168, 4864),
                         ("experts", "embed", "expert_mlp"), p,
                         client_leading=False)
        assert spec[0] == "model"   # experts over TP axis (expert parallel)
        assert spec[1] == "data"    # fsdp

    def test_no_double_axis_use(self):
        """A mesh axis must not shard two dims of one tensor."""
        p = plan_for("deepseek-67b")
        spec = _spec_for((22016, 22016), ("mlp", "expert_mlp"), p,
                         client_leading=False)
        used = [s for s in spec if s is not None]
        assert len(set(used)) == len(used)


class TestParamSpecsTree:
    @pytest.mark.parametrize("arch", ["olmo-1b", "arctic-480b", "zamba2-1.2b",
                                      "whisper-small", "xlstm-125m"])
    def test_full_tree_covered(self, arch):
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = model.param_shapes()
        plan = plan_for(arch)
        specs = param_specs(shapes, model.axes(), plan)
        n_shapes = len(jax.tree.leaves(shapes))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_shapes == n_specs
        # every spec is consistent with its tensor rank and divisibility
        for s, sp in zip(jax.tree.leaves(shapes),
                         jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(sp) <= len(s.shape)
            for dim, part in zip(s.shape, tuple(sp)):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                size = 1
                for a in axes:
                    size *= plan.mesh.shape[a]
                assert dim % size == 0, (arch, s.shape, sp)
