"""FedAP on the transformer zoo (pruning_lm): shrink + still-runs tests.

Marked ``slow`` (builds/prunes every reduced arch) — deselected from the
default tier-1 run; execute with ``-m slow`` or ``-m ""``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.pruning_lm import fedap_lm, prune_lm_experts, prune_lm_ffn
from repro.models.api import build_model, input_specs
from repro.utils import tree_size

TRAIN = InputShape("t", 64, 2, "train")


class TestFFNPrune:
    @pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-vl-7b", "zamba2-1.2b"])
    def test_shrinks_and_runs(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        before = tree_size(params)
        new_params, new_cfg, info = prune_lm_ffn(params, cfg, 0.4, align=64)
        assert new_cfg.d_ff < cfg.d_ff
        assert info["realized_rate"] <= 0.4 + 1e-6    # p_l <= p*_l
        assert tree_size(new_params) < before
        new_model = build_model(new_cfg)
        batch = input_specs(new_cfg, TRAIN, abstract=False)
        loss = new_model.loss(new_params, batch)
        assert bool(jnp.isfinite(loss))

    def test_keeps_high_norm_units(self):
        cfg = get_config("olmo-1b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        # inflate the norm of units [0:8] in every layer — they must survive
        wi = params["layers"]["mlp"]["wi"]
        params["layers"]["mlp"]["wi"] = wi.at[:, :, :8].mul(100.0)
        new_params, new_cfg, _ = prune_lm_ffn(params, cfg, 0.5, align=None)
        big = jnp.linalg.norm(new_params["layers"]["mlp"]["wi"], axis=1)
        # the 8 inflated units dominate the kept set's norm mass
        assert float(jnp.max(big)) > 50.0


class TestExpertPrune:
    @pytest.mark.parametrize("arch", ["arctic-480b", "llama4-maverick-400b-a17b"])
    def test_moe_prunes_experts(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        new_params, new_cfg, info = prune_lm_experts(params, cfg, 0.5,
                                                     min_keep=2)
        assert new_cfg.moe.num_experts < cfg.moe.num_experts
        assert new_cfg.moe.num_experts >= new_cfg.moe.top_k
        new_model = build_model(new_cfg)
        batch = input_specs(new_cfg, TRAIN, abstract=False)
        loss = new_model.loss(new_params, batch)
        assert bool(jnp.isfinite(loss))


class TestDispatch:
    def test_moe_routes_to_expert_prune(self):
        cfg = get_config("arctic-480b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        _, new_cfg, _ = fedap_lm(params, cfg, 0.3)
        assert new_cfg.moe.num_experts <= cfg.moe.num_experts

    def test_dense_routes_to_ffn_prune(self):
        cfg = get_config("olmo-1b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        _, new_cfg, _ = fedap_lm(params, cfg, 0.3, align=64)
        assert new_cfg.d_ff < cfg.d_ff
