"""The static-analysis pass is itself under test: every lint rule has a
good/bad fixture pair (including the pragma escapes), the compile-budget
sentinel must catch an artificially injected re-trace, and the HLO
checker must flag a seeded f64 leak / host callback.

The dynamic sentinel tests run REAL tiny plans (seconds, CPU) — the same
canonical world `python -m repro.analysis` uses.
"""
import json

import pytest

from repro.analysis import compile_budget, hlo_lint
from repro.analysis.lint import lint_source


def rules_of(violations):
    return sorted({v.rule for v in violations})


# ---------------------------------------------------------------------------
# R1 — PRNG key discipline
# ---------------------------------------------------------------------------

class TestR1KeyReuse:
    def test_key_used_twice_flagged(self):
        src = """
import jax

def f(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.normal(key, (3,))
    return a + b
"""
        vs = lint_source(src, rules=["R1"])
        assert rules_of(vs) == ["R1"]
        assert "key" in vs[0].message

    def test_split_then_use_clean(self):
        src = """
import jax

def f(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.normal(k2, (3,))
    return a + b
"""
        assert lint_source(src, rules=["R1"]) == []

    def test_rebinding_resets_consumption(self):
        src = """
import jax

def f(key):
    for _ in range(3):
        key, sub = jax.random.split(key)
        x = jax.random.normal(sub, (3,))
    return x
"""
        assert lint_source(src, rules=["R1"]) == []

    def test_loop_reuse_without_rebind_flagged(self):
        src = """
import jax

def f(key):
    out = []
    for _ in range(3):
        out.append(jax.random.normal(key, (3,)))
    return out
"""
        assert rules_of(lint_source(src, rules=["R1"])) == ["R1"]

    def test_exclusive_branches_not_flagged(self):
        # the engine's dropout split: both branches consume `key`, but
        # only one executes
        src = """
import jax

def f(key, dropout: float):
    if dropout:
        k1, k2, k3 = jax.random.split(key, 3)
    else:
        k1, k2 = jax.random.split(key, 2)
    return jax.random.normal(k1, (3,))
"""
        assert lint_source(src, rules=["R1"]) == []

    def test_fold_in_loop_is_blessed(self):
        src = """
import jax

def f(key):
    return [jax.random.normal(jax.random.fold_in(key, i), (3,))
            for i in range(4)]
"""
        assert lint_source(src, rules=["R1"]) == []

    def test_fold_in_same_constant_twice_flagged(self):
        src = """
import jax

def f(key):
    a = jax.random.fold_in(key, 7)
    b = jax.random.fold_in(key, 7)
    return a, b
"""
        assert rules_of(lint_source(src, rules=["R1"])) == ["R1"]

    def test_seed_ladder_flagged_and_fold_in_clean(self):
        ladder = """
import jax

def bench():
    p = jax.random.normal(jax.random.key(0), (3,))
    q = jax.random.normal(jax.random.key(1), (3,))
    return p, q
"""
        vs = lint_source(ladder, rules=["R1"])
        assert rules_of(vs) == ["R1"]
        assert "fold_in" in vs[0].message

        fixed = """
import jax

def bench():
    base = jax.random.key(0)
    p = jax.random.normal(jax.random.fold_in(base, 0), (3,))
    q = jax.random.normal(jax.random.fold_in(base, 1), (3,))
    return p, q
"""
        assert lint_source(fixed, rules=["R1"]) == []

    def test_pragma_suppresses(self):
        src = """
import jax

def f(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.normal(key, (3,))  # lint: key-reuse-ok
    return a + b
"""
        assert lint_source(src, rules=["R1"]) == []


# ---------------------------------------------------------------------------
# R2 — host syncs reachable from jit roots
# ---------------------------------------------------------------------------

class TestR2HostSync:
    def test_item_in_jitted_function_flagged(self):
        src = """
import jax

@jax.jit
def step(x):
    return x * x.sum().item()
"""
        vs = lint_source(src, rules=["R2"])
        assert rules_of(vs) == ["R2"]
        assert ".item()" in vs[0].message

    def test_reachability_through_call_chain(self):
        src = """
import jax
import numpy as np

def helper(x):
    return np.asarray(x)

@jax.jit
def step(x):
    return helper(x) * 2
"""
        vs = lint_source(src, rules=["R2"])
        assert rules_of(vs) == ["R2"]
        assert "helper" in vs[0].message

    def test_unreachable_host_code_not_flagged(self):
        src = """
import numpy as np

def host_only(x):
    return float(np.asarray(x).mean())
"""
        assert lint_source(src, rules=["R2"]) == []

    def test_float_on_static_shape_not_flagged(self):
        src = """
import jax

@jax.jit
def step(x):
    scale = float(x.shape[0])
    return x / scale
"""
        assert lint_source(src, rules=["R2"]) == []

    def test_float_on_traced_value_flagged(self):
        src = """
import jax

@jax.jit
def step(x):
    return x / float(x)
"""
        assert rules_of(lint_source(src, rules=["R2"])) == ["R2"]

    def test_pragma_suppresses(self):
        src = """
import jax
import numpy as np

@jax.jit
def step(x):
    c = np.asarray([1.0, 2.0])  # lint: host-sync-ok
    return x * c[0]
"""
        assert lint_source(src, rules=["R2"]) == []


# ---------------------------------------------------------------------------
# R3 — traced-value branching in engine/kernels modules
# ---------------------------------------------------------------------------

class TestR3StaticBranch:
    PATH = "src/repro/kernels/fixture.py"

    def test_branch_on_traced_value_flagged(self):
        src = """
import jax.numpy as jnp

def f(x):
    if jnp.sum(x) > 0:
        return x
    return -x
"""
        vs = lint_source(src, path=self.PATH, rules=["R3"])
        assert rules_of(vs) == ["R3"]
        assert "static-branch" in vs[0].message

    def test_shape_and_config_branches_clean(self):
        src = """
def f(x, cfg, causal: bool = True, block: int = 128):
    if x.ndim != 2:
        raise ValueError(f"bad rank {x.shape}")
    if cfg.use_masks:
        block = block * 2
    if causal and x.shape[0] % block == 0:
        return x
    return -x
"""
        assert lint_source(src, path=self.PATH, rules=["R3"]) == []

    def test_propagated_config_scalar_clean(self):
        # the PR 6 `if alpha > 0:` pattern — static via assignment from a
        # config attribute chain
        src = """
def f(state, cfg):
    alpha = cfg.feddyn.alpha
    if alpha > 0:
        return state
    return None
"""
        assert lint_source(src, path=self.PATH, rules=["R3"]) == []

    def test_pragma_allows_static_branch(self):
        src = """
def f(x, flags):
    if flags[0]:  # lint: static-branch
        return x
    return -x
"""
        assert lint_source(src, path=self.PATH, rules=["R3"]) == []

    def test_out_of_scope_module_not_checked(self):
        src = """
import jax.numpy as jnp

def f(x):
    if jnp.sum(x) > 0:
        return x
    return -x
"""
        assert lint_source(src, path="src/repro/launch/fixture.py",
                           rules=["R3"]) == []


# ---------------------------------------------------------------------------
# R4 / R5
# ---------------------------------------------------------------------------

class TestR4R5:
    def test_bare_assert_in_kernels_flagged(self):
        src = """
def kernel(x, block: int = 128):
    assert x.shape[0] % block == 0
    return x
"""
        vs = lint_source(src, path="src/repro/kernels/fixture.py",
                         rules=["R4"])
        assert rules_of(vs) == ["R4"]
        assert "ValueError" in vs[0].message
        # same snippet outside kernels/ is fine (pytest-style asserts etc.)
        assert lint_source(src, path="src/repro/core/fixture.py",
                           rules=["R4"]) == []

    def test_mutable_default_flagged(self):
        src = """
def f(x, acc=[]):
    acc.append(x)
    return acc
"""
        assert rules_of(lint_source(src, rules=["R5"])) == ["R5"]

    def test_module_level_jnp_flagged_and_pragma(self):
        src = """
import jax.numpy as jnp

TABLE = jnp.arange(16)
"""
        vs = lint_source(src, rules=["R5"])
        assert rules_of(vs) == ["R5"]
        assert "import time" in vs[0].message

        src_ok = """
import jax.numpy as jnp

TABLE = jnp.arange(16)  # lint: import-time-ok

def f(x):
    y = jnp.zeros_like(x)
    return y
"""
        assert lint_source(src_ok, rules=["R5"]) == []


# ---------------------------------------------------------------------------
# Compile-budget sentinel
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    return compile_budget.make_world()


class TestCompileBudget:
    def test_budget_file_is_source_of_truth(self):
        budget = compile_budget.load_budget()
        names = {sc.name for sc in compile_budget.scenarios()}
        assert names == set(budget["scenarios"])
        for name, entry in budget["scenarios"].items():
            assert entry["programs"] >= 1, name
        # the specific counts the repo's tests rely on
        assert compile_budget.expected_programs("local/prune_mask") == 1
        assert compile_budget.expected_programs("mesh/prune_mask") == 1
        assert compile_budget.expected_programs("mesh/mask_then_shrink") == 2

    def test_canonical_scenario_within_budget(self, world):
        sc = next(s for s in compile_budget.scenarios()
                  if s.name == "local/scan_eval")
        errors = compile_budget.check(scenario_list=[sc], world=world)
        assert errors == []

    def test_injected_retrace_is_caught(self, world):
        """Negative proof: a plan with TWO distinct chunk lengths against
        a budget that promises ONE program must fail, naming the plan
        event after which the count jumped."""
        from repro.core import Eval, Scan, Snapshot, TrainPlan

        sc = compile_budget.Scenario(
            "local/injected_retrace", "local",
            lambda: TrainPlan(Scan(1), Snapshot(), Scan(2), Eval()))
        budget = {"scenarios": {"local/injected_retrace": {"programs": 1}}}
        errors = compile_budget.check(budget=budget, scenario_list=[sc],
                                      world=world)
        assert len(errors) == 1
        assert "local/injected_retrace" in errors[0]
        assert "Scan(rounds=2)" in errors[0]   # the event that re-traced

    def test_missing_scenario_is_reported(self, world):
        sc = next(s for s in compile_budget.scenarios()
                  if s.name == "local/scan_eval")
        errors = compile_budget.check(budget={"scenarios": {}},
                                      scenario_list=[sc], world=world)
        assert len(errors) == 1 and "--update" in errors[0]


# ---------------------------------------------------------------------------
# HLO invariant checker
# ---------------------------------------------------------------------------

class TestHloLint:
    def test_f64_leak_detected(self):
        leaky = """
HloModule leak

ENTRY %main (p0: f32[4]) -> f64[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %c = f64[4]{0} convert(f32[4]{0} %p0)
}
"""
        assert hlo_lint.f64_ops(leaky) > 0

    def test_clean_f32_program_has_no_f64(self):
        import jax
        import jax.numpy as jnp

        txt = jax.jit(lambda x: jnp.sin(x) * 2.0).lower(
            jnp.zeros((4,), jnp.float32)).compile().as_text()
        assert hlo_lint.f64_ops(txt) == 0
        assert hlo_lint.host_callbacks(txt) == []

    def test_host_callback_detected(self):
        txt = """
HloModule cb

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  %cb = f32[4]{0} custom-call(f32[4]{0} %p0), custom_call_target="xla_python_cpu_callback"
  %tok = token[] after-all()
  %inf = (f32[4]{0}, token[]) infeed(token[] %tok)
  ROOT %r = f32[4]{0} add(f32[4]{0} %cb, f32[4]{0} %p0)
}
"""
        found = hlo_lint.host_callbacks(txt)
        assert any("callback" in f for f in found)
        assert any("infeed" in f for f in found)

    def test_local_chunk_invariants(self, world):
        """The real local chunk: no f64, no collectives, no callbacks."""
        from repro.launch import hlo_cost

        txt, _ = hlo_lint._lower_chunk("local", world)
        assert hlo_lint.f64_ops(txt) == 0
        assert hlo_lint.host_callbacks(txt) == []
        cm = hlo_cost.HloCostModel(txt)
        assert dict(cm.entry_cost().collective_counts) == {}
