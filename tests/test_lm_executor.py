"""The unified LM path: TrainPlan-driven transformer fine-tuning on the
SAME PlanExecutor stack as the CNN repro.

Locks, mirroring the CNN suites (tests/test_plan.py, test_engine_diff.py):

  * pruning_lm decision invariants — `_aligned_keep` monotone in the
    rate / a multiple of the alignment / never 0, uniform kept count
    across the scanned stack, and construction-time validation naming
    the rate, the alignment and the layer;
  * mask/shrink forward equivalence on a tiny LM — the filter-mask
    forward equals the masked-params forward EXACTLY (bit-for-bit: the
    coupling-closed zero set contributes silu(0)=0 through wo), the
    all-ones mask is a bit-exact no-op, and both match the structurally
    shrunk forward to float tolerance (compacting the zero rows changes
    the K-reduction association — the same 5e-5-class budget as the
    CNN's masked-vs-shrink lock);
  * a full fedap_plan run with Prune(mode="mask") on the local scan
    backend — layer-adaptive FedAP injected as keep-masks carried in
    the layer scan, ZERO extra chunk programs (budgeted in
    compile_budget.json), kernel mode matching params mode;
  * mesh == local parity <= 1e-5 per round through the full
    FederatedTrainer path (adapts to the available device count, like
    tests/test_mesh_backend.py — 8-way under the CI job's XLA_FLAGS);
  * the scan-compiled engine vs the f64 `ref_engine` oracle on explicit
    LM batches for FedAvg and the FedDUM momentum wiring (masked row
    included): the oracle runs the ROUND ARITHMETIC (aggregation,
    momentum, dynamic server update) in float64 around the shared jax
    grad function, so any disagreement > 1e-5 is engine wiring, not
    model float noise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.compile_budget import expected_programs
from repro.configs.base import ModelConfig
from repro.core import engine, ref_engine
from repro.core.engine import EngineConfig
from repro.core.plan import fedap_plan
from repro.core.pruning import FedAPConfig
from repro.core.pruning_lm import (
    _aligned_keep,
    ffn_kept_indices,
    ffn_param_masks,
)
from repro.core.rounds import FederatedTrainer, feddumap_config
from repro.data.pipeline import build_lm_federated_data
from repro.data.synthetic import TokenSpec
from repro.models.lm import LM

TINY = dict(name="dense-tiny", family="dense", rope="1d", norm="rmsnorm",
            act="silu", param_dtype="float32", remat="none",
            num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=512, vocab_size=2048)


def tiny_model():
    """A FRESH LM per run: the session compile cache is keyed on the
    model instance, and init is a pure function of (cfg, key), so every
    fresh instance starts from identical params."""
    return LM(ModelConfig(**TINY))


@pytest.fixture(scope="module")
def lm_data():
    return build_lm_federated_data(
        num_clients=8,
        spec=TokenSpec(vocab_size=2048, num_topics=16, seq_len=17,
                       num_sequences=256))


def lm_cfg(**kw):
    return feddumap_config(num_clients=8, clients_per_round=4,
                           local_epochs=1, batch_size=4,
                           server_batch_size=8, lr=3e-3, lr_decay=1.0,
                           fedap=FedAPConfig(align=128, min_rate=0.5,
                                             probe_size=4, participants=2),
                           **kw)


MASK_PLAN = lambda: fedap_plan(4, prune_round=2, mode="mask", eval_every=1)


@pytest.fixture(scope="module")
def local_mask_run(lm_data):
    """The reference run: fedap_plan with Prune(mode="mask") on the local
    scan backend — shared by the artifact, budget, mesh and kernel locks."""
    tr = FederatedTrainer(tiny_model(), lm_data, lm_cfg())
    return tr, tr.run(MASK_PLAN())


# ---------------------------------------------------------------------------
# pruning_lm decision invariants (host-side, no training)
# ---------------------------------------------------------------------------

class TestPruningLMInvariants:
    def test_aligned_keep_monotone_in_rate(self):
        keeps = [_aligned_keep(512, r, 128) for r in
                 (0.0, 0.1, 0.3, 0.5, 0.7, 0.9)]
        assert keeps == sorted(keeps, reverse=True)
        assert keeps[0] == 512                      # rate 0 keeps everything

    def test_aligned_keep_multiple_of_alignment_and_never_zero(self):
        for rate in (0.1, 0.5, 0.74, 0.9, 0.999):
            keep = _aligned_keep(512, rate, 128)
            assert keep % 128 == 0 and 1 <= keep <= 512
        # narrower than the alignment: falls back to the raw count, >= 1
        assert _aligned_keep(64, 0.9, 128) == 7
        assert _aligned_keep(8, 0.999, None) == 1

    def test_rate_validation_names_rate_and_layer(self):
        with pytest.raises(ValueError, match=r"rate.*\[0, 1\).*1\.0"):
            _aligned_keep(512, 1.0, 128)
        with pytest.raises(ValueError, match="mlp stack"):
            ffn_kept_indices({"layers": {"mlp": {
                "wi": jnp.ones((2, 16, 96)), "wg": jnp.ones((2, 16, 96)),
                "wo": jnp.ones((2, 96, 16))}}}, ModelConfig(**TINY), -0.1)

    def test_alignment_overflow_names_alignment_and_width(self):
        # width 192 >= align 128 but not a multiple: rate 0.1 keeps 173,
        # which aligns UP to 256 > 192
        with pytest.raises(ValueError, match="128-lane-aligned.*192"):
            _aligned_keep(192, 0.1, 128, layer="mlp stack (d_ff=192)")

    def test_uniform_kept_count_across_scanned_stack(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        idx = ffn_kept_indices(params, model.cfg, 0.5, align=128)
        assert idx.shape == (TINY["num_layers"], 256)   # ONE count, all layers
        # rows are sorted unique unit ids — a valid gather per layer
        for row in idx:
            assert len(set(row.tolist())) == len(row)
            assert (np.diff(row) > 0).all()

    def test_decide_kept_matches_pruning_lm(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        kept = model.decide_kept(params, 0.5)
        np.testing.assert_array_equal(
            np.asarray(kept["mlp"]),
            ffn_kept_indices(params, model.cfg, 0.5, align=128))


class TestMaskShrinkEquivalence:
    @pytest.fixture(scope="class")
    def forwards(self):
        model = tiny_model()
        params = model.init(jax.random.key(3))
        rng = np.random.default_rng(5)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, TINY["vocab_size"], (2, 16)), jnp.int32)}
        kept = model.decide_kept(params, 0.5)
        return model, params, batch, kept

    def test_filter_mask_equals_param_mask_exactly(self, forwards):
        """The coupling-closed zero set: masking the FFN pre-activation
        (filter masks in the scan) and masking the params (wi/wg cols +
        wo rows) are the SAME computation — bit-for-bit."""
        model, params, batch, kept = forwards
        logits_fm, _ = model.apply(params, batch,
                                   masks=model.filter_masks(params, kept))
        masked = jax.tree.map(jnp.multiply, params,
                              model.param_masks(params, kept))
        logits_pm, _ = model.apply(masked, batch)
        np.testing.assert_array_equal(np.asarray(logits_fm),
                                      np.asarray(logits_pm))

    def test_masked_forward_matches_shrunk_forward(self, forwards):
        """Pruning as masks == pruning as structure, to float tolerance:
        compacting the kept units changes the wo K-reduction association
        (the zero rows vanish), so the budget is the CNN suite's
        5e-5-class one, not bit equality."""
        model, params, batch, kept = forwards
        logits_fm, _ = model.apply(params, batch,
                                   masks=model.filter_masks(params, kept))
        logits_sh, _ = model.apply(model.shrink_params(params, kept), batch)
        np.testing.assert_allclose(np.asarray(logits_fm),
                                   np.asarray(logits_sh), atol=5e-5)

    def test_all_ones_masks_are_a_bit_exact_noop(self, forwards):
        model, params, batch, _ = forwards
        logits, _ = model.apply(params, batch)
        logits_m, _ = model.apply(params, batch,
                                  masks=model.filter_masks(params, {}))
        np.testing.assert_array_equal(np.asarray(logits),
                                      np.asarray(logits_m))

    def test_param_masks_zero_exactly_the_shrunk_coordinates(self, forwards):
        model, params, _, kept = forwards
        masks = ffn_param_masks(params, kept)
        mlp = masks["layers"]["mlp"]
        unit = np.zeros((TINY["num_layers"], TINY["d_ff"]), np.float32)
        np.put_along_axis(unit, np.asarray(kept["mlp"]), 1.0, axis=1)
        np.testing.assert_array_equal(np.asarray(mlp["wi"]),
                                      np.broadcast_to(unit[:, None, :],
                                                      mlp["wi"].shape))
        np.testing.assert_array_equal(np.asarray(mlp["wo"]),
                                      np.broadcast_to(unit[:, :, None],
                                                      mlp["wo"].shape))
        # everything outside the mlp stays all-ones
        for leaf in jax.tree.leaves({k: v for k, v in
                                     masks["layers"].items() if k != "mlp"}):
            np.testing.assert_array_equal(np.asarray(leaf), 1.0)

    def test_moe_mask_mode_rejected(self):
        """A zeroed router logit is not -inf: MoE stacks must refuse
        mask-mode pruning up front and point at Prune(mode='shrink')."""
        from repro.configs import get_config
        from repro.models.api import build_model

        model = build_model(get_config("arctic-480b").reduced())
        with pytest.raises(ValueError, match="MoE"):
            model.apply({}, {"tokens": jnp.zeros((1, 4), jnp.int32)},
                        masks={"mlp": jnp.ones((1, 4))})


# ---------------------------------------------------------------------------
# The executor path: fedap_plan on the local backend, budget, kernel, mesh
# ---------------------------------------------------------------------------

class TestLMExecutor:
    def test_mask_plan_prunes_at_the_lane_boundary(self, local_mask_run):
        _, res = local_mask_run
        art = res.artifacts["prune"]
        assert art["kept_counts"] == {"mlp": 256}          # rate 0.5, aligned
        assert np.asarray(art["kept"]["mlp"]).shape == (2, 256)
        assert art["layer_rates"] == {"mlp": 0.5}
        assert res.history["round"] == [1, 2, 3, 4]
        assert all(np.isfinite(res.history["loss"]))
        # the param-structured keep-masks are in force in the round state:
        # exactly 256 surviving wi columns in every layer
        m_wi = np.asarray(res.state["masks"]["layers"]["mlp"]["wi"])
        np.testing.assert_array_equal(m_wi.sum(axis=2), 256.0)

    def test_mask_prune_adds_zero_chunk_programs(self, local_mask_run):
        """The LM leg of the zero-re-lowering contract: the Prune(mask)
        event swaps scan-carried masks only — the chunk program count is
        the compile_budget.json LM baseline (== the no-prune count)."""
        tr, _ = local_mask_run
        ce = tr._compiled(use_masks=True)
        assert ce.chunk._cache_size() \
            == expected_programs("local/lm_prune_mask")
        assert expected_programs("local/lm_prune_mask") \
            == expected_programs("local/scan_eval")

    def test_kernel_mode_matches_params_mode(self, lm_data, local_mask_run):
        """masked_compute="kernel" routes the masked FFN matmuls through
        the Pallas masked_matmul — same decision, same training to 1e-5."""
        _, res_p = local_mask_run
        tr = FederatedTrainer(tiny_model(), lm_data,
                              lm_cfg(masked_compute="kernel"))
        res_k = tr.run(MASK_PLAN())
        assert {k: np.asarray(v).tolist()
                for k, v in res_k.artifacts["prune"]["kept"].items()} \
            == {k: np.asarray(v).tolist()
                for k, v in res_p.artifacts["prune"]["kept"].items()}
        for a, b in zip(jax.tree.leaves(res_k.params),
                        jax.tree.leaves(res_p.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        np.testing.assert_allclose(res_k.history["loss"],
                                   res_p.history["loss"], atol=1e-5)
        assert tr._compiled(use_masks=True).chunk._cache_size() \
            == expected_programs("local/lm_prune_mask_kernel")

    def test_mesh_matches_local_per_round(self, lm_data, local_mask_run):
        """mesh == local <= 1e-5 PER ROUND through the full trainer path
        (1-way mesh under plain tier-1, 8-way under the CI job)."""
        _, res_l = local_mask_run
        tr = FederatedTrainer(tiny_model(), lm_data, lm_cfg(),
                              backend="mesh")
        res_m = tr.run(MASK_PLAN())
        for key in ("loss", "acc", "tau_eff"):
            np.testing.assert_allclose(
                res_m.history[key], res_l.history[key], atol=1e-5,
                err_msg=f"mesh history[{key}] diverged from local")
        for a, b in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_l.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# Engine vs the widened f64 oracle on explicit LM batches
# ---------------------------------------------------------------------------

O = dict(num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
         vocab_size=256)
CLIENTS, STEPS, BATCH, TAU, SBATCH, SEQ, ROUNDS = 2, 2, 2, 2, 2, 8, 2

ORACLE_ROWS = {
    "fedavg": (dict(use_server_update=False, local_momentum="none",
                    server_momentum=False), False),
    "feddum-masked": (dict(use_server_update=True, local_momentum="restart",
                           server_momentum=True), True),
}


@pytest.fixture(scope="module")
def oracle_world():
    model = LM(ModelConfig(**{**TINY, **O}))
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(17)

    def toks(lead):
        t = rng.integers(0, O["vocab_size"], lead + (SEQ + 1,))
        return (t[..., :-1].astype(np.int32), t[..., 1:].astype(np.int32))

    rounds = []
    for _ in range(ROUNDS):
        rounds.append({
            "client": toks((CLIENTS, STEPS, BATCH)),
            "sizes": np.asarray([30.0, 20.0], np.float32),
            "server": toks((TAU, SBATCH)),
            "d_round": np.float32(0.3),
            "d_server": np.float32(0.02),
            "n0": np.float32(50.0),
        })
    return model, params, rounds


@pytest.mark.parametrize("row", list(ORACLE_ROWS))
def test_lm_engine_matches_f64_oracle(oracle_world, row):
    """round_core under scan+jit vs ref_round: the oracle's aggregation,
    momentum and FedDU server update run in float64 around the SAME jax
    grad function, so a per-round drift > 1e-5 is engine wiring."""
    model, params, rounds = oracle_world
    mode, use_masks = ORACLE_ROWS[row]
    cfg = EngineConfig(lr=0.05, lr_decay=0.97, use_masks=use_masks, **mode)

    masks = None
    if use_masks:
        masks = ffn_param_masks(
            params, {"mlp": ffn_kept_indices(params, model.cfg, 0.5,
                                             align=64)})

    def la(p, b):
        return model.loss_and_acc(p, b[0], b[1])

    def grad(p, b):
        return jax.grad(lambda q: la(q, b)[0])(p)

    def np_la(p, b):
        p32 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), p)
        loss, acc = la(p32, (jnp.asarray(b[0]), jnp.asarray(b[1])))
        return float(loss), float(acc)

    def np_grad(p, b):
        p32 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), p)
        g = grad(p32, (jnp.asarray(b[0]), jnp.asarray(b[1])))
        return jax.tree.map(lambda x: np.asarray(x, np.float64), g)

    # oracle leg: naive f64 loops, per-round history
    ref = ref_engine.ref_init_state(params, cfg, masks=masks)
    ref_params, ref_taus = [], []
    for b in rounds:
        ref, met = ref_engine.ref_round(cfg, np_grad, np_la, ref, b)
        ref_params.append(ref["params"])
        ref_taus.append(met["tau_eff"])

    # engine leg: round_core under lax.scan + jit, per-round history
    state0 = engine.init_round_state(jax.tree.map(jnp.asarray, params), cfg)
    if masks is not None:
        state0["masks"] = jax.tree.map(jnp.asarray, masks)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[jax.tree.map(jnp.asarray, b) for b in rounds])

    @jax.jit
    def run(state, batches):
        def body(st, b):
            st, metrics = engine.round_core(cfg, grad, la, st, b)
            return st, (metrics["tau_eff"], st["params"])
        return jax.lax.scan(body, state, batches)

    _, (taus, phist) = run(state0, stacked)

    # Round-arithmetic budget: both legs share the SAME f32 jax grad, so
    # the only divergence is the federated arithmetic (aggregation,
    # momentum, FedDU update) in f64 vs f32 — measured worst drift is
    # ~2.5e-7 over ROUNDS rounds; 2e-6 gives ~8x headroom.  (The model
    # forward's own f32 error is locked separately against the NumPy-f64
    # oracle in tests/test_ref64.py.)
    for r in range(ROUNDS):
        for leaf, ref_leaf in zip(jax.tree.leaves(phist),
                                  jax.tree.leaves(ref_params[r])):
            np.testing.assert_allclose(
                np.asarray(leaf[r]), ref_leaf, atol=2e-6,
                err_msg=f"[{row}] params diverged from oracle at round {r}")
    np.testing.assert_allclose(np.asarray(taus), np.asarray(ref_taus),
                               atol=2e-6, err_msg=f"[{row}] tau_eff")
    if masks is not None:
        for leaf, m in zip(jax.tree.leaves(phist), jax.tree.leaves(masks)):
            np.testing.assert_array_equal(
                np.asarray(leaf[-1])[np.asarray(m) == 0], 0.0)
