"""Data pipeline: partition protocol + server-subset non-IID control."""
import numpy as np
import pytest

from repro.core import niid
from repro.data.partition import dirichlet_partition, label_shard_partition, server_subset
from repro.data.pipeline import build_federated_data
from repro.data.synthetic import SyntheticSpec, TokenSpec, synthetic_classification, synthetic_tokens


class TestLabelShard:
    def test_paper_protocol(self):
        """Sort by label, 2 shards each: most clients see <= 2 labels."""
        rng = np.random.default_rng(0)
        labels = rng.integers(0, 10, 4000)
        parts = label_shard_partition(labels, num_clients=20, seed=0)
        assert len(parts) == 20
        sizes = {len(p) for p in parts}
        assert len(sizes) == 1          # equal sizes (vmap contract)
        # 2 shards, each spanning at most one label boundary -> <= 4 labels
        n_labels = [len(np.unique(labels[p])) for p in parts]
        assert max(n_labels) <= 4
        assert np.mean(n_labels) < 4.0

    def test_no_overlap(self):
        labels = np.random.default_rng(1).integers(0, 10, 1000)
        parts = label_shard_partition(labels, num_clients=10, seed=1)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist()))


class TestDirichlet:
    def test_alpha_controls_skew(self):
        labels = np.random.default_rng(2).integers(0, 10, 5000)
        skewed = dirichlet_partition(labels, 10, alpha=0.05, seed=2)
        uniform = dirichlet_partition(labels, 10, alpha=100.0, seed=2)

        def mean_degree(parts):
            dists = np.stack([np.bincount(labels[p], minlength=10) / len(p)
                              for p in parts])
            sizes = np.asarray([len(p) for p in parts], np.float32)
            p_bar = niid.global_distribution(dists, sizes)
            return float(np.mean([niid.non_iid_degree(d, p_bar) for d in dists]))

        assert mean_degree(skewed) > mean_degree(uniform) * 2


class TestServerSubset:
    def test_niid_ordering(self):
        """severe > mild > iid in JS degree — reproduces the paper's d1/d2/d3
        server-data regimes (Figure 6)."""
        labels = np.random.default_rng(3).integers(0, 10, 20000)
        pool = np.arange(10000, 20000)
        p_bar = np.full(10, 0.1, np.float32)
        degs = {}
        for kind in ["iid", "mild", "severe"]:
            idx = server_subset(labels, pool, 2000, niid_target=kind, seed=3)
            dist = np.bincount(labels[idx], minlength=10).astype(np.float32)
            dist /= dist.sum()
            degs[kind] = float(niid.non_iid_degree(dist, p_bar))
        assert degs["severe"] > degs["mild"] > degs["iid"]
        assert degs["iid"] < 0.01


class TestFederatedBuilder:
    def test_shapes_and_distributions(self):
        spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                             train_size=3000, test_size=200)
        data = build_federated_data(num_clients=10, server_fraction=0.05,
                                    device_pool=2000, spec=spec)
        assert data.client_x.shape[0] == 10
        assert data.client_dists.shape == (10, 10)
        np.testing.assert_allclose(data.client_dists.sum(1), 1.0, atol=1e-5)
        assert data.server_x.shape[0] == 100   # 5% of 2000
        assert data.test_x.shape[0] == 200

    def test_synthetic_learnable(self):
        """A linear probe beats chance easily -> the task carries signal."""
        spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                             train_size=2000, test_size=500, noise_scale=0.9)
        tx, ty, vx, vy = synthetic_classification(spec)
        x = tx.reshape(len(tx), -1)
        v = vx.reshape(len(vx), -1)
        # one-shot least-squares probe
        y1h = np.eye(10)[ty]
        w, *_ = np.linalg.lstsq(x, y1h, rcond=None)
        acc = (v @ w).argmax(1) == vy
        assert acc.mean() > 0.5

    def test_token_stream_structure(self):
        toks, topics = synthetic_tokens(TokenSpec(num_sequences=64, seq_len=128))
        assert toks.shape == (64, 128)
        assert toks.min() >= 0
        # topic-conditioned vocabulary slices should differ across topics
        t0 = toks[topics == topics[0]]
        assert t0.std() > 0
