"""Non-IID degree (Formulas 2-3): unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import niid


def _dist(vals):
    v = np.asarray(vals, np.float64) + 1e-9
    return v / v.sum()


dists = st.lists(st.floats(0.0, 1.0), min_size=4, max_size=4).filter(
    lambda v: sum(v) > 1e-3).map(_dist)


class TestKL:
    def test_zero_for_identical(self):
        p = jnp.asarray([0.25, 0.25, 0.5])
        assert float(niid.kl_divergence(p, p)) == pytest.approx(0.0, abs=1e-6)

    def test_known_value(self):
        p = jnp.asarray([1.0, 0.0])
        q = jnp.asarray([0.5, 0.5])
        assert float(niid.kl_divergence(p, q)) == pytest.approx(np.log(2), abs=1e-5)

    def test_handles_zero_entries(self):
        p = jnp.asarray([0.5, 0.5, 0.0])
        q = jnp.asarray([0.3, 0.3, 0.4])
        assert np.isfinite(float(niid.kl_divergence(p, q)))


class TestJS:
    @given(dists, dists)
    @settings(max_examples=50, deadline=None)
    def test_nonnegative_symmetric_bounded(self, p, q):
        a = float(niid.js_divergence(jnp.asarray(p), jnp.asarray(q)))
        b = float(niid.js_divergence(jnp.asarray(q), jnp.asarray(p)))
        assert a >= -1e-6
        assert a == pytest.approx(b, abs=1e-5)
        assert a <= np.log(2) + 1e-6

    @given(dists)
    @settings(max_examples=20, deadline=None)
    def test_zero_iff_equal(self, p):
        assert float(niid.js_divergence(jnp.asarray(p), jnp.asarray(p))) == \
            pytest.approx(0.0, abs=1e-6)


class TestDegrees:
    def test_label_distribution(self):
        y = jnp.asarray([0, 0, 1, 2])
        d = niid.label_distribution(y, 4)
        np.testing.assert_allclose(d, [0.5, 0.25, 0.25, 0.0], atol=1e-6)

    def test_global_distribution_weighted(self):
        dists = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
        sizes = jnp.asarray([3.0, 1.0])
        np.testing.assert_allclose(niid.global_distribution(dists, sizes),
                                   [0.75, 0.25], atol=1e-6)

    def test_more_skewed_has_higher_degree(self):
        p_bar = jnp.asarray([0.25, 0.25, 0.25, 0.25])
        mild = jnp.asarray([0.4, 0.3, 0.2, 0.1])
        severe = jnp.asarray([1.0, 0.0, 0.0, 0.0])
        assert float(niid.non_iid_degree(severe, p_bar)) > \
            float(niid.non_iid_degree(mild, p_bar))

    def test_round_distribution_selects(self):
        dists = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [0.5, 0.5]])
        sizes = jnp.asarray([1.0, 1.0, 2.0])
        out = niid.round_distribution(dists, sizes, jnp.asarray([0, 1]))
        np.testing.assert_allclose(out, [0.5, 0.5], atol=1e-6)
