"""Non-IID degree (Formulas 2-3): unit + hypothesis property tests.

The property classes at the bottom lock the scenario-matrix axes: a
Dirichlet(alpha) partition is an EXACT partition for any (alpha, clients,
seed); ``label_distribution`` always lands on the simplex; and the mean
non-IID degree of a Dirichlet partition is bounded by ln 2 and vanishes
as alpha -> infinity (the heterogeneity knob the benchmark grid sweeps).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import niid
from repro.data.partition import dirichlet_partition


def _dist(vals):
    v = np.asarray(vals, np.float64) + 1e-9
    return v / v.sum()


dists = st.lists(st.floats(0.0, 1.0), min_size=4, max_size=4).filter(
    lambda v: sum(v) > 1e-3).map(_dist)


class TestKL:
    def test_zero_for_identical(self):
        p = jnp.asarray([0.25, 0.25, 0.5])
        assert float(niid.kl_divergence(p, p)) == pytest.approx(0.0, abs=1e-6)

    def test_known_value(self):
        p = jnp.asarray([1.0, 0.0])
        q = jnp.asarray([0.5, 0.5])
        assert float(niid.kl_divergence(p, q)) == pytest.approx(np.log(2), abs=1e-5)

    def test_handles_zero_entries(self):
        p = jnp.asarray([0.5, 0.5, 0.0])
        q = jnp.asarray([0.3, 0.3, 0.4])
        assert np.isfinite(float(niid.kl_divergence(p, q)))


class TestJS:
    @given(dists, dists)
    @settings(max_examples=50, deadline=None)
    def test_nonnegative_symmetric_bounded(self, p, q):
        a = float(niid.js_divergence(jnp.asarray(p), jnp.asarray(q)))
        b = float(niid.js_divergence(jnp.asarray(q), jnp.asarray(p)))
        assert a >= -1e-6
        assert a == pytest.approx(b, abs=1e-5)
        assert a <= np.log(2) + 1e-6

    @given(dists)
    @settings(max_examples=20, deadline=None)
    def test_zero_iff_equal(self, p):
        assert float(niid.js_divergence(jnp.asarray(p), jnp.asarray(p))) == \
            pytest.approx(0.0, abs=1e-6)


class TestDegrees:
    def test_label_distribution(self):
        y = jnp.asarray([0, 0, 1, 2])
        d = niid.label_distribution(y, 4)
        np.testing.assert_allclose(d, [0.5, 0.25, 0.25, 0.0], atol=1e-6)

    def test_global_distribution_weighted(self):
        dists = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
        sizes = jnp.asarray([3.0, 1.0])
        np.testing.assert_allclose(niid.global_distribution(dists, sizes),
                                   [0.75, 0.25], atol=1e-6)

    def test_more_skewed_has_higher_degree(self):
        p_bar = jnp.asarray([0.25, 0.25, 0.25, 0.25])
        mild = jnp.asarray([0.4, 0.3, 0.2, 0.1])
        severe = jnp.asarray([1.0, 0.0, 0.0, 0.0])
        assert float(niid.non_iid_degree(severe, p_bar)) > \
            float(niid.non_iid_degree(mild, p_bar))

    def test_round_distribution_selects(self):
        dists = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [0.5, 0.5]])
        sizes = jnp.asarray([1.0, 1.0, 2.0])
        out = niid.round_distribution(dists, sizes, jnp.asarray([0, 1]))
        np.testing.assert_allclose(out, [0.5, 0.5], atol=1e-6)


class TestLabelDistributionSimplex:
    @given(st.integers(0, 10_000), st.integers(1, 6), st.integers(1, 80))
    @settings(max_examples=30, deadline=None)
    def test_rows_on_simplex(self, seed, num_classes, n):
        rng = np.random.default_rng(seed)
        labels = jnp.asarray(rng.integers(0, num_classes, n))
        d = np.asarray(niid.label_distribution(labels, num_classes))
        assert d.shape == (num_classes,)
        assert (d >= 0.0).all()
        assert d.sum() == pytest.approx(1.0, abs=1e-5)


class TestDirichletPartitionProperties:
    @given(st.integers(2, 8), st.integers(0, 10_000),
           st.sampled_from([0.1, 0.5, 5.0]))
    @settings(max_examples=15, deadline=None)
    def test_exact_partition(self, num_clients, seed, alpha):
        """The index lists are a TRUE partition: disjoint, covering, and
        their sizes sum to the dataset size — the invariant the per-client
        ``sizes`` aggregation weights rely on."""
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, 5, 400)
        parts = dirichlet_partition(labels, num_clients, alpha=alpha,
                                    seed=seed, min_size=1)
        assert len(parts) == num_clients
        assert sum(len(p) for p in parts) == len(labels)
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == len(labels)   # disjoint + covering


class TestDirichletDegreeLimit:
    @given(st.integers(0, 1000))
    @settings(max_examples=5, deadline=None)
    def test_degree_bounded_and_vanishes_with_alpha(self, seed):
        """Mean non-IID degree over a Dirichlet partition stays in
        [0, ln 2] for every alpha and -> 0 as alpha -> infinity (the
        partitions converge to the global label distribution)."""
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, 5, 1000)
        mean_deg = []
        for alpha in (0.1, 1.0, 1000.0):
            parts = dirichlet_partition(labels, 5, alpha=alpha, seed=seed,
                                        min_size=1)
            dists = jnp.stack([niid.label_distribution(jnp.asarray(labels[p]),
                                                       5) for p in parts])
            sizes = jnp.asarray([len(p) for p in parts], jnp.float32)
            p_bar = niid.global_distribution(dists, sizes)
            degs = np.asarray(niid.non_iid_degree(dists, p_bar))
            assert (degs >= -1e-6).all()
            assert (degs <= np.log(2) + 1e-6).all()
            mean_deg.append(float(degs.mean()))
        # the sweep's endpoints order: heavy skew >> near-IID
        assert mean_deg[-1] < 0.02
        assert mean_deg[-1] <= mean_deg[0] + 1e-3
