"""The trip-count-aware HLO cost model, validated on known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _cost_of(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(compiled.as_text())


class TestFlops:
    def test_single_matmul(self):
        a = jnp.zeros((256, 512), jnp.float32)
        b = jnp.zeros((512, 128), jnp.float32)
        tot = _cost_of(lambda x, y: x @ y, a, b)
        expect = 2 * 256 * 512 * 128
        assert tot.flops == pytest.approx(expect, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        a = jnp.zeros((128, 128), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ a, None
            out, _ = jax.lax.scan(body, x, None, length=10)
            return out

        tot = _cost_of(f, jnp.zeros((128, 128), jnp.float32))
        expect = 10 * 2 * 128 ** 3
        assert tot.flops == pytest.approx(expect, rel=0.05)

    def test_nested_scans_multiply(self):
        a = jnp.zeros((128, 128), jnp.float32)

        def f(x):
            def inner(c, _):
                return c @ a, None

            def outer(c, _):
                c, _ = jax.lax.scan(inner, c, None, length=4)
                return c, None

            out, _ = jax.lax.scan(outer, x, None, length=3)
            return out

        tot = _cost_of(f, jnp.zeros((128, 128), jnp.float32))
        expect = 12 * 2 * 128 ** 3
        assert tot.flops == pytest.approx(expect, rel=0.05)


class TestBytes:
    def test_elementwise_traffic(self):
        x = jnp.zeros((1 << 20,), jnp.float32)
        tot = _cost_of(lambda v: v * 2.0 + 1.0, x)
        # read x + write out = 8 MiB (fused), small constant overhead ok
        assert 0.8e7 <= tot.bytes <= 3e7

    def test_scan_accumulates_bytes(self):
        x = jnp.zeros((1 << 18,), jnp.float32)

        def f(v):
            def body(c, _):
                return c * 1.5, None
            out, _ = jax.lax.scan(body, v, None, length=8)
            return out

        tot = _cost_of(f, x)
        single = 2 * x.size * 4
        assert tot.bytes >= 0.8 * 8 * single


class TestParsing:
    def test_tuple_types_with_index_comments(self):
        # regression: '/*index=5*/' inside tuple types broke the instruction
        # regex and silently dropped all while-loops
        line = ("  %while.1 = (s32[], bf16[1,2]{1,0}, /*index=2*/f32[3,4]{1,0}) "
                "while(%tuple.1), condition=%cond.1, body=%body.1")
        parsed = hlo_cost.HloCostModel._split_instr(line)
        assert parsed is not None
        name, ty, opcode, _ = parsed
        assert opcode == "while"
        assert "f32[3,4]" in ty

    def test_collective_not_confused_by_operand_names(self):
        # regression: 'fusion(%all-gather.3)' must NOT count as a collective
        txt = """
HloModule m, entry_computation_layout={()->f32[8]{0}}

%fused.1 (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  ROOT %neg = f32[8]{0} negate(%p0)
}

ENTRY %main.1 () -> f32[8] {
  %all-gather.3 = f32[8]{0} constant({1,1,1,1,1,1,1,1})
  ROOT %fusion.1 = f32[8]{0} fusion(%all-gather.3), kind=kLoop, calls=%fused.1
}
"""
        tot = hlo_cost.analyze(txt)
        assert tot.collective_counts == {}
