"""Integration tests: the federated engine + every baseline, small scale,
driven through the declarative TrainPlan API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedAPConfig,
    FederatedTrainer,
    TrainPlan,
    baselines,
    fedap_plan,
    feddumap_config,
)
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import SimpleCNN
from repro.utils import tree_size


@pytest.fixture(scope="module")
def small_world():
    # noise_scale 0.3 keeps the synthetic task learnable within a handful
    # of rounds so the convergence assertion below is signal, not luck
    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=2600, test_size=300, noise_scale=0.3)
    data = build_federated_data(num_clients=10, server_fraction=0.1,
                                device_pool=2000, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3), channels=(8, 16, 16),
                      fc_width=32)
    return data, model


def _run(data, model, cfg, rounds=3, hook=None):
    tr = FederatedTrainer(model, data, cfg)
    plan = (TrainPlan.with_callback(rounds, hook) if hook is not None
            else rounds)
    return tr.run(plan)


COMMON = dict(num_clients=10, clients_per_round=3, local_epochs=1,
              batch_size=10, lr=0.05)


class TestAlgorithms:
    def test_fedavg_runs_and_improves(self, small_world):
        data, model = small_world
        cfg = baselines.fedavg_config(
            **{**COMMON, "clients_per_round": 5, "local_epochs": 2})
        tr = FederatedTrainer(model, data, cfg)
        res = tr.run(12, eval_every=4)
        assert res.history["acc"][-1] > 0.2    # well above 10-class chance

    def test_feddu_tau_eff_decays(self, small_world):
        data, model = small_world
        res = _run(data, model, baselines.feddu_config(**COMMON), rounds=4)
        assert res.history["tau_eff"][0] > 0.0
        assert all(np.isfinite(res.history["tau_eff"]))

    # slow tier: per-mode numerical correctness is already locked by the
    # oracle differential suite (test_engine_diff.py); this is the full-CNN
    # integration pass over the same modes
    @pytest.mark.slow
    @pytest.mark.parametrize("maker", [
        baselines.server_momentum_config,
        baselines.device_momentum_config,
        baselines.fedda_config,
        feddumap_config,
    ])
    def test_momentum_variants_run(self, small_world, maker):
        data, model = small_world
        res = _run(data, model, maker(**COMMON), rounds=2)
        assert np.isfinite(res.history["loss"][-1])

    def test_data_sharing_transform(self, small_world):
        data, model = small_world
        shared = baselines.apply_data_sharing(data, np.random.default_rng(0))
        assert shared.client_x.shape[1] > data.client_x.shape[1]
        res = _run(shared, model, baselines.fedavg_config(**COMMON), rounds=2)
        assert np.isfinite(res.history["loss"][-1])

    def test_hybrid_fl_transform(self, small_world):
        data, model = small_world
        hyb = baselines.apply_hybrid_fl(data)
        assert hyb.client_x.shape[0] == data.client_x.shape[0] + 1
        cfg = baselines.fedavg_config(**{**COMMON, "num_clients": 11})
        res = _run(hyb, model, cfg, rounds=2)
        assert np.isfinite(res.history["loss"][-1])

    def test_distillation_hook(self, small_world):
        data, model = small_world
        hook = baselines.make_distillation_round_end(model, data, steps=2, batch=16)
        res = _run(data, model, baselines.fedavg_config(**COMMON), rounds=2,
                   hook=hook)
        assert np.isfinite(res.history["loss"][-1])


class TestPruningIntegration:
    @pytest.mark.slow  # full FedAP probe + re-materialize + re-jit cycle
    def test_fedap_shrink_event_and_training_continues(self, small_world):
        data, model = small_world
        # min_rate: the pure eigen-gap rule may prune nothing on this easy
        # synthetic task; the floor makes the shrink assertion strict
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.4)
        cfg = feddumap_config(**COMMON, fedap=apcfg)
        tr = FederatedTrainer(model, data, cfg)
        init_params = model.init(jax.random.key(0))
        res = tr.run(fedap_plan(4, prune_round=2, mode="shrink"))
        assert res.artifacts["prune"]["kept"] is not None
        assert tree_size(res.params) < tree_size(init_params)
        assert np.isfinite(res.history["loss"][-1])

    @pytest.mark.slow  # full FedAP probe at static shapes, inside the scan
    def test_fedap_mask_event_stays_static(self, small_world):
        data, model = small_world
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.4)
        cfg = feddumap_config(**COMMON, fedap=apcfg)
        tr = FederatedTrainer(model, data, cfg)
        init_params = model.init(jax.random.key(0))
        res = tr.run(fedap_plan(4, prune_round=2, mode="mask"))
        # static shapes: nothing shrank...
        assert (jax.tree.map(jnp.shape, res.params)
                == jax.tree.map(jnp.shape, init_params))
        # ...but a real fraction of coordinates is masked, and they stay
        # exactly zero through the post-prune rounds inside the scan
        assert "masks" in res.state
        masked_coords = 0
        for p, m in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(res.state["masks"])):
            np.testing.assert_array_equal(np.asarray(p)[np.asarray(m) == 0], 0.0)
            masked_coords += int(np.sum(np.asarray(m) == 0))
        assert masked_coords > 0
        assert np.isfinite(res.history["loss"][-1])

    @pytest.mark.slow  # mask semantics unit-tested in test_pruning.py
    def test_unstructured_hook_masks(self, small_world):
        data, model = small_world
        hook = baselines.make_unstructured_pruning_hook(rate=0.5, prune_round=2)
        res = _run(data, model, baselines.fedavg_config(**COMMON),
                   rounds=3, hook=hook)
        zeros = sum(float(jnp.mean(p == 0)) for p in jax.tree.leaves(res.params))
        assert zeros > 0.1                      # a real fraction masked
        assert np.isfinite(res.history["loss"][-1])

    def test_hrank_hook_structured(self, small_world):
        data, model = small_world
        hook = baselines.make_hrank_pruning_hook(model, data, rate=0.4,
                                                 prune_round=2, probe=8)
        res = _run(data, model, baselines.fedavg_config(**COMMON),
                   rounds=3, hook=hook)
        init_params = model.init(jax.random.key(0))
        assert tree_size(res.params) < tree_size(init_params)
        assert np.isfinite(res.history["loss"][-1])
