"""Minimal stand-in for the `hypothesis` API surface this suite uses.

The container image does not ship `hypothesis` (and tier-1 must not pip
install).  When the real library is absent, tests/conftest.py installs this
module as ``sys.modules["hypothesis"]`` so the property-based tests RUN
(deterministic pseudo-random examples) instead of failing at collection.

Covered API (exactly what the tests import):
  given(*strategies)            — decorator, draws ``max_examples`` tuples
  settings(max_examples=, deadline=) — decorator, attaches run options
  strategies.floats / integers / lists / sampled_from, with .map / .filter

With the real hypothesis installed (see requirements-dev.txt) this module
is never imported.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20
_FILTER_RETRIES = 1000


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw  # draw(rng) -> value

    def example(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(_FILTER_RETRIES):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("shim filter(): predicate rejected all examples")
        return SearchStrategy(draw)


def floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        # hit the endpoints occasionally — cheap edge-case coverage
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return float(rng.uniform(lo, hi))

    return SearchStrategy(draw)


def integers(min_value=0, max_value=100, **_kw):
    lo, hi = int(min_value), int(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return int(rng.integers(lo, hi + 1))

    return SearchStrategy(draw)


def lists(elements, min_size=0, max_size=10, **_kw):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return SearchStrategy(draw)


def sampled_from(seq):
    seq = list(seq)
    return SearchStrategy(lambda rng: seq[int(rng.integers(len(seq)))])


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        opts = getattr(fn, "_shim_settings", {})
        n = opts.get("max_examples", _DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # one deterministic stream per test, independent of run order
            # AND of the process (builtin hash() is salted per interpreter)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example(rng) for s in strats]
                drawn_kw = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*args, *drawn, **{**kwargs, **drawn_kw})

        # hide the drawn parameters from pytest's fixture resolution: only
        # the leading non-strategy params (e.g. ``self``) stay visible
        params = list(inspect.signature(fn).parameters.values())
        keep = len(params) - len(strats) - len(kw_strats)
        wrapper.__signature__ = inspect.Signature(params[:keep])
        del wrapper.__wrapped__
        return wrapper

    return deco


# `from hypothesis import strategies as st` resolves this attribute.
strategies = types.ModuleType("hypothesis.strategies")
strategies.floats = floats
strategies.integers = integers
strategies.lists = lists
strategies.sampled_from = sampled_from
