"""Scenario-matrix grid runner: seed threading + reproducibility.

The benchmark grid derives every cell's key from (base_seed, cell_index)
via ``jax.random.fold_in`` — distinct cells get distinct chains, and
rerunning the same grid reproduces it ARRAY-exactly (the regression the
recorded BENCH_scenario_matrix.json relies on).
"""
import json

import numpy as np

from benchmarks import paper_experiments as pe


class TestCellSeed:
    def test_deterministic_and_distinct(self):
        assert pe._cell_seed(0, 0) == pe._cell_seed(0, 0)
        seeds = {pe._cell_seed(0, i) for i in range(32)}
        assert len(seeds) == 32                       # one chain per cell
        assert pe._cell_seed(0, 3) != pe._cell_seed(1, 3)   # base matters

    def test_run_one_folds_tag_when_no_cell_index(self):
        # two different tags with the same base seed must NOT share a key
        import zlib
        a = pe._cell_seed(0, zlib.crc32(b"main_cnn_fedavg"))
        b = pe._cell_seed(0, zlib.crc32(b"main_cnn_feddu"))
        assert a != b


class TestGridReproducibility:
    def test_two_grid_runs_array_equal(self):
        """Satellite lock: the SAME smoke grid run twice is bit-identical
        — full history, every cell."""
        cells, _ = pe.scenario_cells("smoke")
        runs = []
        for _ in range(2):
            runs.append([pe.run_scenario_cell(c, rounds=2, backend="local",
                                              base_seed=0, cell_index=i)
                         for i, c in enumerate(cells)])
        for r1, r2 in zip(*runs):
            assert r1["seed"] == r2["seed"]
            for k in ("loss", "acc", "tau_eff"):
                np.testing.assert_array_equal(
                    np.asarray(r1["history"][k]),
                    np.asarray(r2["history"][k]),
                    err_msg=f"cell {r1['cell_index']} history[{k}]")

    def test_cells_cover_all_algorithms(self):
        cells, rounds = pe.scenario_cells("smoke")
        assert {c["algo"] for c in cells} == {"fedavg", "fedprox", "feddyn"}
        assert rounds == 2
        cells_full, _ = pe.scenario_cells("full")
        assert {c["dirichlet_alpha"] for c in cells_full} == {0.1, 0.5, 100.0}
        assert {(c["clients_per_round"], c["dropout_rate"])
                for c in cells_full} == {(8, 0.0), (4, 0.0), (8, 0.25)}

    def test_matrix_artifact_round_trips(self, tmp_path):
        """suite_scenario_matrix writes one combined JSON keyed by cell,
        reloadable with the seeds it trained on."""
        recs = pe.suite_scenario_matrix("smoke", backends=("local",),
                                        base_seed=0, out_dir=tmp_path)
        loaded = json.loads(
            (tmp_path / "BENCH_scenario_matrix.json").read_text())
        assert loaded["grid"] == "smoke" and loaded["base_seed"] == 0
        assert [c["seed"] for c in loaded["cells"]] == \
            [r["seed"] for r in recs]
        assert all(np.isfinite(c["final_acc"]) for c in loaded["cells"])
