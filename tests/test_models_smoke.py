"""Per-architecture smoke tests (REDUCED configs: 2 layers, d_model<=256,
<=4 experts) — one forward + one train-grad step + one decode step on CPU,
asserting output shapes and finiteness.  Plus a decode-vs-apply parity test
that validates the KV-cache / recurrent-state machinery exactly.

Marked ``slow`` (minutes of XLA compiles across the whole zoo) — deselected
from the default tier-1 run; execute with ``-m slow`` or ``-m ""``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import InputShape
from repro.models.api import build_model, decode_cache_len, input_specs

TRAIN = InputShape("t", 64, 2, "train")
DECODE = InputShape("d", 64, 2, "decode")


@pytest.fixture(scope="module")
def zoo():
    """Reduced models + params, built once per test session."""
    out = {}
    for name in ARCH_NAMES:
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        out[name] = (cfg, model, model.init(jax.random.key(0)))
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
class TestSmoke:
    def test_forward_shapes_and_finite(self, zoo, name):
        cfg, model, params = zoo[name]
        batch = input_specs(cfg, TRAIN, abstract=False)
        logits, aux = model.apply(params, batch)
        assert logits.shape == (2, 64, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        assert bool(jnp.isfinite(aux))

    def test_train_grad_step(self, zoo, name):
        cfg, model, params = zoo[name]
        batch = input_specs(cfg, TRAIN, abstract=False)
        loss, g = jax.value_and_grad(model.loss)(params, batch)
        assert bool(jnp.isfinite(loss))
        norms = [float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g)]
        assert all(np.isfinite(n) for n in norms)
        assert sum(norms) > 0.0

    def test_decode_step(self, zoo, name):
        cfg, model, params = zoo[name]
        batch = input_specs(cfg, DECODE, abstract=False)
        cache = model.init_cache(2, decode_cache_len(cfg, DECODE))
        if cfg.family == "encdec":
            cache = model.prefill_cross(params, cache, batch)
        logits, cache2 = model.decode_step(params, cache, batch)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        assert int(cache2["index"]) == 1


@pytest.mark.parametrize("name", ["olmo-1b", "chatglm3-6b", "xlstm-125m",
                                  "zamba2-1.2b", "whisper-small"])
def test_decode_matches_apply(zoo, name):
    """Token-by-token decode must reproduce the full-sequence forward —
    the strongest correctness check on caches/recurrent state."""
    cfg, model, params = zoo[name]
    s = 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((2, cfg.encoder.frames, cfg.d_model)), jnp.float32)
    full_logits, _ = model.apply(params, batch)

    cache = model.init_cache(2, s)
    if cfg.family == "encdec":
        cache = model.prefill_cross(params, cache, batch)
    outs = []
    for t in range(s):
        step_batch = {"tokens": tokens[:, t:t + 1]}
        logits, cache = model.decode_step(params, cache, step_batch)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_vlm_decode_matches_apply(zoo):
    """Same parity check through the embeds path (vision stub)."""
    cfg, model, params = zoo["qwen2-vl-7b"]
    s = 12
    rng = np.random.default_rng(1)
    embeds = jnp.asarray(rng.standard_normal((2, s, cfg.d_model)) * 0.1, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None], (3, 2, s))
    full_logits, _ = model.apply(params, {"embeds": embeds, "positions": pos})
    cache = model.init_cache(2, s)
    outs = []
    for t in range(s):
        logits, cache = model.decode_step(
            params, cache, {"embeds": embeds[:, t:t + 1], "positions": pos[:, :, t:t + 1]})
        outs.append(logits[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1), np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_reduced_configs_meet_spec():
    """Reduced variants obey the smoke-test contract (2 layers,
    d_model <= 512, <= 4 experts)."""
    for name in ARCH_NAMES:
        cfg = get_config(name).reduced()
        assert cfg.num_layers == 2
        assert cfg.d_model <= 512
        if cfg.moe:
            assert cfg.moe.num_experts <= 4


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned dimensions."""
    expect = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for name, (l, d, h, kv, ff, v) in expect.items():
        cfg = get_config(name)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), name
    # MoE specifics
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("arctic-480b").moe.dense_d_ff == 4864
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get_config("zamba2-1.2b").ssm.state_dim == 64
