"""Serving-path locks: continuous batching, pruned decode, checkpoints.

* **Mask == shrink at decode.**  Serving a FedAP mask-mode checkpoint
  through the block-skipping kernel (``decode_step(..., masks=)``) and
  serving its structural compaction (``shrink_ffn_at``) are the same
  model: per-step logits agree <= 1e-5, all-ones masks are bit-exact
  against the plain dense step.
* **Continuous batching is just batching.**  The ``DecodeEngine`` —
  ragged prompts, chunked prefill, slot reuse, on-device done-mask —
  emits token-for-token what a naive one-sequence-at-a-time greedy loop
  over ``decode_step`` emits.
* **Zero re-traces.**  A whole serving session compiles exactly the
  budgeted program count (``compile_budget.json`` ``serving/*`` rows)
  no matter how many requests are admitted and retired.
* **Checkpoints round-trip.**  ``RunResult.save`` -> ``load_artifact``
  -> ``load_servable`` reconstructs params, kept filters, masks and the
  ``ModelConfig``, for all three serve modes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.compile_budget import expected_programs
from repro.configs.base import ModelConfig
from repro.core import pruning_lm
from repro.core.plan import RunResult, load_artifact
from repro.launch.mesh import make_host_mesh
from repro.models.lm import LM
from repro.serving import (
    DecodeEngine,
    ServeConfig,
    Servable,
    load_servable,
)

CFG = ModelConfig(name="dense-tiny", family="dense", rope="1d",
                  norm="rmsnorm", act="silu", param_dtype="float32",
                  remat="none", num_layers=2, d_model=128, num_heads=4,
                  num_kv_heads=2, d_ff=512, vocab_size=2048)


@pytest.fixture(scope="module")
def world():
    """(model, params, kept, fmasks, zeroed, shrunk_model, shrunk) — the
    dense model, a 0.5-rate FedAP keep decision, its mask-mode params
    (pruned coordinates zeroed) and its structural compaction."""
    model = LM(CFG)
    params = model.init(jax.random.key(0))
    kept = model.decide_kept(params, 0.5)        # 128-lane-aligned
    fmasks = model.filter_masks(params, kept)
    zeroed = jax.tree.map(jnp.multiply, params, model.param_masks(params, kept))
    d_kept = int(np.asarray(kept["mlp"]).shape[-1])
    shrunk_model = LM(dataclasses.replace(CFG, d_ff=d_kept))
    shrunk = pruning_lm.shrink_ffn_at(params, kept["mlp"])
    return model, params, kept, fmasks, zeroed, shrunk_model, shrunk


def ragged_prompts(n, max_prompt, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=int(rng.integers(1, max_prompt + 1)))
            .astype(np.int32) for _ in range(n)]


def naive_greedy(model, params, prompt, max_new, cache_len, masks=None):
    """One sequence at a time through the scalar-index decode_step —
    chunked prefill (one prompt token per step), then argmax decoding.
    The oracle the continuous-batching engine must match exactly."""
    cache = model.init_cache(1, cache_len)
    step = jax.jit(lambda p, c, t: model.decode_step(
        p, c, {"tokens": t}, masks=masks))
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    out, consumed = [], 0
    while len(out) < max_new:
        logits, cache = step(params, cache, tok)
        nxt = int(jnp.argmax(logits[0, 0]))
        consumed += 1
        if consumed < len(prompt):
            tok = jnp.asarray([[prompt[consumed]]], jnp.int32)
        else:
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
    return np.asarray(out, np.int32)


# ---------------------------------------------------------------------------
# Mask == shrink at decode
# ---------------------------------------------------------------------------

class TestPrunedDecodeParity:
    def test_masked_step_equals_shrunk_step(self, world):
        """Logits of the masked decode path (dense shapes, block-skipping
        kernel) equal the compacted model's <= 1e-5 at every step."""
        model, _, _, fmasks, zeroed, s_model, shrunk = world
        b, cache_len = 2, 8
        cm = model.init_cache(b, cache_len)
        cs = s_model.init_cache(b, cache_len)
        rng = np.random.default_rng(1)
        for _ in range(4):
            tok = jnp.asarray(rng.integers(0, CFG.vocab_size, (b, 1)),
                              jnp.int32)
            lm_, cm = model.decode_step(zeroed, cm, {"tokens": tok},
                                        masks=fmasks)
            ls_, cs = s_model.decode_step(shrunk, cs, {"tokens": tok})
            np.testing.assert_allclose(np.asarray(lm_), np.asarray(ls_),
                                       atol=1e-5, rtol=1e-5)

    def test_all_ones_masks_bit_exact(self, world):
        """masks of all-ones must not perturb the dense step at all."""
        model, params, _, _, _, _, _ = world
        ones = {"mlp": jnp.ones((CFG.num_layers, CFG.d_ff), jnp.float32)}
        b, cache_len = 2, 8
        ca = model.init_cache(b, cache_len)
        cb = model.init_cache(b, cache_len)
        rng = np.random.default_rng(2)
        for _ in range(3):
            tok = jnp.asarray(rng.integers(0, CFG.vocab_size, (b, 1)),
                              jnp.int32)
            la, ca = model.decode_step(params, ca, {"tokens": tok})
            lb, cb = model.decode_step(params, cb, {"tokens": tok},
                                       masks=ones)
            assert np.array_equal(np.asarray(la), np.asarray(lb))

    def test_masked_engine_equals_shrunk_engine(self, world):
        """End-to-end: the two pruned serve modes emit identical tokens."""
        model, _, _, fmasks, zeroed, s_model, shrunk = world
        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4, steps_per_wave=3)
        prompts = ragged_prompts(5, 4, CFG.vocab_size, seed=3)
        got_m = DecodeEngine(model, zeroed, scfg, masks=fmasks).run(prompts)
        got_s = DecodeEngine(s_model, shrunk, scfg).run(prompts)
        assert [c.uid for c in got_m] == [c.uid for c in got_s]
        for a, b in zip(got_m, got_s):
            assert np.array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# Continuous batching == naive decoding
# ---------------------------------------------------------------------------

class TestEngineSemantics:
    def test_engine_matches_naive_greedy(self, world):
        """Ragged prompts + slot reuse through 2 slots: every completion
        equals the one-sequence naive loop, token for token."""
        model, params, _, _, _, _, _ = world
        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4, steps_per_wave=3)
        prompts = ragged_prompts(5, scfg.max_prompt, CFG.vocab_size, seed=4)
        eng = DecodeEngine(model, params, scfg)
        done = eng.run(prompts)
        assert [c.uid for c in done] == list(range(len(prompts)))
        for comp in done:
            want = naive_greedy(model, params, comp.prompt,
                                scfg.max_new_tokens, scfg.cache_len)
            np.testing.assert_array_equal(comp.tokens, want)

    def test_eos_stops_early(self, world):
        """An eos_id in-vocabulary retires a slot before max_new_tokens;
        the engine still drains and uids stay stable."""
        model, params, _, _, _, _, _ = world
        # pick the token the model emits first for prompt [7] as the eos
        first = int(naive_greedy(model, params, np.asarray([7]), 1, 8)[0])
        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4, eos_id=first, steps_per_wave=2)
        eng = DecodeEngine(model, params, scfg)
        done = eng.run([np.asarray([7], np.int32),
                        np.asarray([11, 3], np.int32)])
        assert len(done) == 2
        got = done[0].tokens
        assert got[-1] == first and len(got) <= scfg.max_new_tokens

    def test_interleaved_submission(self, world):
        """submit() between waves — the admission path mid-session —
        completes everything with the same per-request tokens."""
        model, params, _, _, _, _, _ = world
        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4, steps_per_wave=2)
        prompts = ragged_prompts(4, 4, CFG.vocab_size, seed=5)
        eng = DecodeEngine(model, params, scfg)
        eng.submit(prompts[0])
        done = []
        done.extend(eng.step_wave())
        for p in prompts[1:]:
            eng.submit(p)
        while eng.pending:
            done.extend(eng.step_wave())
        assert sorted(c.uid for c in done) == list(range(len(prompts)))
        by_uid = {c.uid: c for c in done}
        for uid, p in enumerate(prompts):
            want = naive_greedy(model, params, p, scfg.max_new_tokens,
                                scfg.cache_len)
            np.testing.assert_array_equal(by_uid[uid].tokens, want)

    def test_mesh_engine_matches_local(self, world):
        """Slot axis sharded over the host mesh (1-way under tier-1,
        8-way under the CI mesh job) == the mesh-less engine."""
        model, params, _, _, _, _, _ = world
        mesh = make_host_mesh(model=1)
        n = mesh.shape["data"]
        slots = 2 * n
        scfg = ServeConfig(slots=slots, cache_len=8, max_prompt=4,
                           max_new_tokens=4, steps_per_wave=3)
        prompts = ragged_prompts(2 * slots + 1, 4, CFG.vocab_size, seed=6)
        local = DecodeEngine(model, params, scfg).run(prompts)
        sharded = DecodeEngine(model, params, scfg, mesh=mesh).run(prompts)
        assert [c.uid for c in local] == [c.uid for c in sharded]
        for a, b in zip(local, sharded):
            assert np.array_equal(a.tokens, b.tokens)

    def test_config_validation(self, world):
        model, params, _, _, _, _, _ = world
        with pytest.raises(ValueError, match="cache_len"):
            ServeConfig(slots=2, cache_len=6, max_prompt=4, max_new_tokens=4)
        eng = DecodeEngine(model, params,
                           ServeConfig(slots=1, cache_len=8, max_prompt=4,
                                       max_new_tokens=4))
        with pytest.raises(ValueError, match="prompt length"):
            eng.submit(np.arange(5, dtype=np.int32))

    def test_unservable_family_rejected(self):
        """The engine's per-slot index semantics need the scanned KV
        stack — a recurrent-state model must be refused, not silently
        mis-served."""
        from repro.configs import get_config
        from repro.models.api import build_model

        cfg = get_config("xlstm-125m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        with pytest.raises(ValueError, match="scanned-KV"):
            DecodeEngine(model, params, ServeConfig(
                slots=2, cache_len=8, max_prompt=4, max_new_tokens=4))


# ---------------------------------------------------------------------------
# Zero re-traces (the serving compile-budget contract, asserted in-process)
# ---------------------------------------------------------------------------

class TestServingCompileBudget:
    def test_steady_state_no_retrace(self, world):
        """Admissions, retirements and slot reuse never re-trace: the
        session-wide program count equals the compile_budget.json
        serving row after EVERY wave."""
        model, params, _, _, _, _, _ = world
        want = expected_programs("serving/decode_dense")
        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4, steps_per_wave=2)
        eng = DecodeEngine(model, params, scfg)
        for p in ragged_prompts(6, 4, CFG.vocab_size, seed=7):
            eng.submit(p)
        waves = 0
        while eng.pending:
            eng.step_wave()
            waves += 1
            assert sum(eng.program_counts().values()) == want, \
                f"re-trace at wave {waves}: {eng.program_counts()}"
        assert waves >= 3            # slot reuse actually happened
        assert eng.program_counts() == {"admit": 1, "wave": 1}

    def test_budget_rows_agree_across_modes(self):
        for mode in ("dense", "masked", "shrunk"):
            assert expected_programs(f"serving/decode_{mode}") == 2


# ---------------------------------------------------------------------------
# Checkpoint round-trip + load_servable
# ---------------------------------------------------------------------------

def masked_run_result(params, kept, fmasks):
    return RunResult(
        params=params,
        history={"round": [2], "acc": [0.5], "loss": [1.2],
                 "tau_eff": [1.0], "time": [0.1]},
        artifacts={"prune": {"mode": "mask", "p_star": 0.5,
                             "layer_rates": [0.5, 0.5], "kept": dict(kept),
                             "filter_masks": dict(fmasks)}},
        state={})


class TestCheckpoint:
    def test_save_load_roundtrip(self, tmp_path, world):
        model, _, kept, fmasks, zeroed, _, _ = world
        masked_run_result(zeroed, kept, fmasks).save(
            tmp_path / "ckpt", model_config=CFG)
        art = load_artifact(tmp_path / "ckpt")
        assert art["mode"] == "mask"
        assert art["model_config"] == CFG
        assert art["history"]["acc"] == [0.5]
        assert art["meta"]["prune"]["kept_counts"] == {
            "mlp": int(np.asarray(kept["mlp"]).shape[-1])}
        np.testing.assert_array_equal(art["kept"]["mlp"],
                                      np.asarray(kept["mlp"]))
        np.testing.assert_array_equal(art["filter_masks"]["mlp"],
                                      np.asarray(fmasks["mlp"]))
        got = jax.tree.leaves(art["params"])
        want = jax.tree.leaves(zeroed)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, np.asarray(w))

    def test_dense_run_saves_without_prune(self, tmp_path, world):
        model, params, _, _, _, _, _ = world
        RunResult(params=params, history={}, artifacts={}, state={}).save(
            tmp_path / "ckpt", model_config=CFG)
        art = load_artifact(tmp_path / "ckpt")
        assert art["kept"] is None and art["mode"] is None
        sv = load_servable(tmp_path / "ckpt")
        assert sv.mode == "dense" and sv.masks is None

    def test_format_guard(self, tmp_path):
        (tmp_path / "ckpt").mkdir()
        (tmp_path / "ckpt" / "meta.json").write_text('{"format": "other"}')
        with pytest.raises(ValueError, match="not a repro checkpoint"):
            load_artifact(tmp_path / "ckpt")

    def test_servable_modes_agree(self, tmp_path, world):
        """auto (-> masked for a mask-mode run), masked and shrunk loads
        of the SAME checkpoint produce token-identical engines; shrunk
        actually compacts d_ff."""
        model, _, kept, fmasks, zeroed, _, _ = world
        masked_run_result(zeroed, kept, fmasks).save(
            tmp_path / "ckpt", model_config=CFG)
        d_kept = int(np.asarray(kept["mlp"]).shape[-1])

        servables = {m: load_servable(tmp_path / "ckpt", m)
                     for m in ("auto", "masked", "shrunk", "dense")}
        assert servables["auto"].mode == "masked"
        assert servables["shrunk"].model.cfg.d_ff == d_kept
        assert servables["masked"].model.cfg.d_ff == CFG.d_ff

        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4, steps_per_wave=3)
        prompts = ragged_prompts(3, 4, CFG.vocab_size, seed=8)
        runs = {}
        for m, sv in servables.items():
            assert isinstance(sv, Servable)
            runs[m] = DecodeEngine(sv.model, sv.params, scfg,
                                   masks=sv.masks).run(prompts)
        for m in ("masked", "shrunk", "dense"):
            for a, b in zip(runs["auto"], runs[m]):
                assert np.array_equal(a.tokens, b.tokens), m

    def test_shrunk_checkpoint_loads_shrunk(self, tmp_path, world):
        """A shrink-mode run's params are already compacted: the recorded
        (pre-shrink) config's d_ff is overridden by the param shapes and
        re-shrinking is a no-op."""
        model, _, kept, _, _, s_model, shrunk = world
        res = RunResult(
            params=shrunk,
            history={},
            artifacts={"prune": {"mode": "shrink", "p_star": 0.5,
                                 "layer_rates": [0.5, 0.5],
                                 "kept": dict(kept)}},
            state={})
        res.save(tmp_path / "ckpt", model_config=CFG)   # dense-time cfg
        sv = load_servable(tmp_path / "ckpt")
        assert sv.mode == "shrunk"
        assert sv.model.cfg.d_ff == int(np.asarray(kept["mlp"]).shape[-1])
        prompts = ragged_prompts(2, 4, CFG.vocab_size, seed=9)
        scfg = ServeConfig(slots=2, cache_len=8, max_prompt=4,
                           max_new_tokens=4)
        got = DecodeEngine(sv.model, sv.params, scfg).run(prompts)
        want = DecodeEngine(s_model, shrunk, scfg).run(prompts)
        for a, b in zip(got, want):
            assert np.array_equal(a.tokens, b.tokens)

    def test_missing_config_is_loud(self, tmp_path, world):
        model, params, _, _, _, _, _ = world
        RunResult(params=params, history={}, artifacts={}, state={}).save(
            tmp_path / "ckpt")                          # no model_config
        with pytest.raises(ValueError, match="model_config"):
            load_servable(tmp_path / "ckpt")

    def test_in_memory_run_result_source(self, world):
        """load_servable accepts the RunResult itself (no disk trip)."""
        model, _, kept, fmasks, zeroed, _, _ = world
        res = masked_run_result(zeroed, kept, fmasks)
        sv = load_servable(res, "auto", model_config=CFG)
        assert sv.mode == "masked" and sv.masks is not None
