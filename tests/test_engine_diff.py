"""Differential tests: the scan-compiled engine vs. the pure-NumPy oracle.

The core lock is ONE table-driven parity fixture
(``test_parity_table_local_mesh_oracle``): every (client algorithm,
momentum mode, use_masks) combination — FedAvg / FedProx / FedDyn crossed
with the FedDU / FedDUM / FedDA wirings, plus dropout rows — runs for
several rounds through THREE legs

  * `repro.core.engine.round_core` under `jax.lax.scan` + `jit` (exactly
    how the simulation driver and the pod path execute it),
  * the SAME scan with the round state placed on a host mesh through
    ``fl_specs.fl_state_specs`` NamedShardings (the MeshBackend's state
    placement, client_state per-client leaves included), and
  * `repro.core.ref_engine.ref_round` — naive float64 NumPy loops,

on identical explicit batches, and every leg must agree with the oracle to
<= 1e-5 PER ROUND through one shared assertion helper — a new engine mode
gets locked by adding one table row.

A second suite locks the two public wirings to each other: the pod path's
``make_fl_train_step`` (FLRunConfig) and the simulation trainer's
``round_step`` (FLConfig) must produce IDENTICAL params from the same
params/batches on a toy model.  Limit tests pin the client algorithms'
exact reductions: FedProx mu=0 is BIT-identical to FedAvg, FedDyn alpha=0
matches FedAvg <= 1e-6.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, ref_engine
from repro.core.engine import EngineConfig
from repro.core.ref_engine import SoftmaxRegression
from repro.models.cnn import softmax_xent_acc

DIM, CLASSES = 6, 4
CLIENTS, STEPS, BATCH = 3, 2, 5
TAU, SBATCH = 3, 5
ROUNDS = 3


@pytest.fixture(scope="module")
def world():
    model = SoftmaxRegression(dim=DIM, num_classes=CLASSES)
    rng = np.random.default_rng(42)
    params = model.init(seed=7)

    def batches(lead):
        x = rng.standard_normal(lead + (DIM,)).astype(np.float32)
        y = rng.integers(0, CLASSES, lead).astype(np.int32)
        return x, y

    rounds = []
    for _ in range(ROUNDS):
        cx, cy = batches((CLIENTS, STEPS, BATCH))
        sx, sy = batches((TAU, SBATCH))
        rounds.append({
            "client": (cx, cy),
            "sizes": np.asarray([40.0, 25.0, 35.0], np.float32),
            "server": (sx, sy),
            "d_round": np.float32(0.3),
            "d_server": np.float32(0.02),
            "n0": np.float32(500.0),
        })
    return model, params, rounds


def jnp_loss_and_acc(params, b):
    logits = b[0] @ params["w"] + params["b"]
    return softmax_xent_acc(logits, b[1])


def jnp_grad(params, b):
    return jax.grad(lambda p: jnp_loss_and_acc(p, b)[0])(params)


MODES = {
    "fedavg": dict(use_server_update=False, local_momentum="none",
                   server_momentum=False),
    "feddu": dict(use_server_update=True, local_momentum="none",
                  server_momentum=False),
    "server_momentum": dict(use_server_update=True, local_momentum="none",
                            server_momentum=True),
    "device_momentum": dict(use_server_update=True, local_momentum="restart",
                            server_momentum=False),
    "feddum": dict(use_server_update=True, local_momentum="restart",
                   server_momentum=True),
    "fedda": dict(use_server_update=True, local_momentum="communicated",
                  server_momentum=True),
}

# ---------------------------------------------------------------------------
# THE parity table: every (client algorithm, momentum mode, use_masks)
# combination + dropout rows, each run local-scan vs mesh-placed-scan vs
# f64 oracle through ONE assertion helper.  A new engine mode gets locked
# by adding one row here.
# ---------------------------------------------------------------------------

N_TOTAL = 6          # total clients (sizes the FedDyn per-client state)
SELS = np.asarray([[4, 1, 3], [0, 2, 5], [5, 0, 2]], np.int32)
# dropout rows: round 1 drops EVERY client — the aggregation must be an
# exact no-op (delta form), with client state untouched
ACTIVES = np.asarray([[1, 0, 1], [0, 0, 0], [1, 1, 1]], np.float32)

ALGOS = {
    "fedavg": {},
    "fedprox": dict(algorithm="fedprox",
                    fedprox=engine.FedProxConfig(mu=0.05)),
    "feddyn": dict(algorithm="feddyn",
                   feddyn=engine.FedDynConfig(alpha=0.05)),
}

PARITY_TABLE = [
    (algo, mode, use_masks, False)
    for algo in ALGOS
    for mode in MODES
    for use_masks in (False, True)
] + [
    ("fedavg", "feddum", False, True),
    ("fedprox", "feddum", False, True),
    ("feddyn", "feddum", False, True),
]


def _row_id(row):
    algo, mode, use_masks, dropout = row
    return (f"{algo}-{mode}" + ("-masked" if use_masks else "")
            + ("-dropout" if dropout else ""))


def _parity_masks():
    rng = np.random.default_rng(3)
    return {"w": (rng.random((DIM, CLASSES)) > 0.4).astype(np.float32),
            "b": (rng.random((CLASSES,)) > 0.4).astype(np.float32)}


def _parity_rounds(rounds, dropout):
    out = []
    for r, b in enumerate(rounds):
        b = dict(b)
        b["sel"] = SELS[r]
        if dropout:
            b["active"] = ACTIVES[r]
        out.append(b)
    return out


def _engine_history(cfg, state0, rounds, *, mesh=False):
    """Run the scan-compiled engine and return per-round
    (params, server_m, tau_eff) histories.  ``mesh=True`` places the round
    state through ``fl_state_specs`` NamedShardings on a host mesh first —
    the MeshBackend's state placement, client_state included."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[jax.tree.map(jnp.asarray, b) for b in rounds])
    state0 = jax.tree.map(jnp.asarray, state0)
    if mesh:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import make_host_mesh
        from repro.sharding.fl_specs import fl_state_specs
        from repro.sharding.specs import MeshPlan

        m = make_host_mesh(model=1)
        plan = MeshPlan(mesh=m, multi_pod=False, client_axes=("data",),
                        fsdp_axes=(), tp_axes=(), batch_axes=(),
                        num_clients=m.shape["data"])
        specs = fl_state_specs(state0, None, plan,
                               client_axes=plan.client_axes)
        state0 = jax.device_put(state0, jax.tree.map(
            lambda s: NamedSharding(m, s), specs,
            is_leaf=lambda x: isinstance(x, P)))
        stacked = jax.device_put(stacked, NamedSharding(m, P()))

    @jax.jit
    def run(state, batches):
        def body(st, b):
            st, metrics = engine.round_core(cfg, jnp_grad, jnp_loss_and_acc,
                                            st, b)
            return st, (metrics["tau_eff"], st["params"], st["server_m"])
        return jax.lax.scan(body, state, batches)

    _, (taus, phist, mhist) = run(state0, stacked)
    return phist, mhist, taus


def _assert_leg_matches_oracle(leg, phist, mhist, taus, ref_params, ref_ms,
                               ref_taus, row_id, masks=None):
    """THE shared assertion: each leg agrees with the f64 oracle <= 1e-5
    on params, server momentum and tau_eff — PER ROUND."""
    for r in range(ROUNDS):
        for leaf, ref_leaf in zip(jax.tree.leaves(phist),
                                  jax.tree.leaves(ref_params[r])):
            np.testing.assert_allclose(
                np.asarray(leaf[r]), ref_leaf, atol=1e-5,
                err_msg=f"[{row_id}] {leg} params diverged at round {r}")
        for leaf, ref_leaf in zip(jax.tree.leaves(mhist),
                                  jax.tree.leaves(ref_ms[r])):
            np.testing.assert_allclose(
                np.asarray(leaf[r]), ref_leaf, atol=1e-5,
                err_msg=f"[{row_id}] {leg} server_m diverged at round {r}")
    np.testing.assert_allclose(np.asarray(taus), np.asarray(ref_taus),
                               atol=1e-5,
                               err_msg=f"[{row_id}] {leg} tau_eff")
    if masks is not None:
        # pruned coordinates stay exactly zero on every leg
        for leaf, m in zip(jax.tree.leaves(phist), jax.tree.leaves(masks)):
            np.testing.assert_array_equal(np.asarray(leaf[-1])[m == 0], 0.0)


@pytest.mark.parametrize("algo,mode,use_masks,dropout", PARITY_TABLE,
                         ids=[_row_id(r) for r in PARITY_TABLE])
def test_parity_table_local_mesh_oracle(world, algo, mode, use_masks,
                                        dropout):
    model, params, rounds = world
    cfg = EngineConfig(lr=0.08, lr_decay=0.97, use_masks=use_masks,
                       **ALGOS[algo], **MODES[mode])
    rounds = _parity_rounds(rounds, dropout)
    masks = _parity_masks() if use_masks else None

    state0 = engine.init_round_state(jax.tree.map(jnp.asarray, params), cfg,
                                     num_clients=N_TOTAL)
    if masks is not None:
        state0["masks"] = jax.tree.map(jnp.asarray, masks)

    # oracle leg: naive float64 NumPy loops, per-round history
    ref = ref_engine.ref_init_state(params, cfg, masks=masks,
                                    num_clients=N_TOTAL)
    ref_params, ref_ms, ref_taus = [], [], []
    for b in rounds:
        ref, met = ref_engine.ref_round(cfg, model.np_grad,
                                        model.np_loss_and_acc, ref, b)
        ref_params.append(ref["params"])
        ref_ms.append(ref["server_m"])
        ref_taus.append(met["tau_eff"])

    row_id = _row_id((algo, mode, use_masks, dropout))
    for leg, on_mesh in (("local", False), ("mesh", True)):
        phist, mhist, taus = _engine_history(cfg, state0, rounds,
                                             mesh=on_mesh)
        _assert_leg_matches_oracle(leg, phist, mhist, taus, ref_params,
                                   ref_ms, ref_taus, row_id, masks=masks)


def _scan_engine(cfg, state0, rounds):
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rounds)

    @jax.jit
    def run(state, batches):
        def body(st, b):
            st, metrics = engine.round_core(cfg, jnp_grad, jnp_loss_and_acc,
                                            st, b)
            return st, metrics["tau_eff"]
        return jax.lax.scan(body, state, batches)

    return run(state0, stacked)


def test_fedprox_mu0_bit_identical_to_fedavg(world):
    """mu = 0 multiplies the proximal term to EXACT zero: the FedProx
    engine must be bit-identical to FedAvg on the same batches."""
    _, params, rounds = world
    base = dict(lr=0.08, lr_decay=0.97, **MODES["feddum"])
    cfg_avg = EngineConfig(**base)
    cfg_px = EngineConfig(algorithm="fedprox",
                          fedprox=engine.FedProxConfig(mu=0.0), **base)
    rounds = _parity_rounds(rounds, False)
    s_avg, t_avg = _scan_engine(
        cfg_avg, engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                         cfg_avg), rounds)
    s_px, t_px = _scan_engine(
        cfg_px, engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                        cfg_px, num_clients=N_TOTAL), rounds)
    for a, b in zip(jax.tree.leaves(s_avg["params"]),
                    jax.tree.leaves(s_px["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(t_avg), np.asarray(t_px))


def test_feddyn_alpha0_reduces_to_fedavg(world):
    """alpha = 0: the correction state stays exactly zero and the server
    division never enters the graph — FedDyn must match FedAvg <= 1e-6."""
    _, params, rounds = world
    base = dict(lr=0.08, lr_decay=0.97, **MODES["feddum"])
    cfg_avg = EngineConfig(**base)
    cfg_dy = EngineConfig(algorithm="feddyn",
                          feddyn=engine.FedDynConfig(alpha=0.0), **base)
    rounds = _parity_rounds(rounds, False)
    s_avg, _ = _scan_engine(
        cfg_avg, engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                         cfg_avg), rounds)
    s_dy, _ = _scan_engine(
        cfg_dy, engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                        cfg_dy, num_clients=N_TOTAL), rounds)
    for a, b in zip(jax.tree.leaves(s_avg["params"]),
                    jax.tree.leaves(s_dy["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # the correction state itself never moved off zero
    for leaf in jax.tree.leaves(s_dy["client_state"]):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


@pytest.mark.parametrize("mode", list(MODES))
def test_kernel_masked_compute_matches_params_and_oracle(world, mode):
    """masked_compute="kernel" (filter masks threaded into the model fns)
    must match the param-masked engine AND the f64 oracle to <= 1e-5 in
    every momentum mode.  For the toy softmax model the filter mask is an
    output-class column mask, whose feature-level application
    ((x @ w + b) * m) is algebraically the param-level one
    (x @ (w * m) + b * m) — the same coupled-closure identity the CNN's
    feature-map masking relies on."""
    model, params, rounds = world
    colmask = np.asarray([1.0, 0.0, 1.0, 1.0], np.float32)
    masks = {"w": np.broadcast_to(colmask, (DIM, CLASSES)).copy(),
             "b": colmask.copy()}
    base = dict(lr=0.08, lr_decay=0.97, use_masks=True, **MODES[mode])
    cfg_k = EngineConfig(masked_compute="kernel", **base)
    cfg_p = EngineConfig(masked_compute="params", **base)

    def la_kernel(p, b, fm):
        return softmax_xent_acc((b[0] @ p["w"] + p["b"]) * fm["out"], b[1])

    def grad_kernel(p, b, fm):
        return jax.grad(lambda q: la_kernel(q, b, fm)[0])(p)

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rounds)
    state_k = engine.init_round_state(
        jax.tree.map(jnp.asarray, params), cfg_k,
        filter_masks={"out": jnp.ones((CLASSES,))})
    state_k["masks"] = jax.tree.map(jnp.asarray, masks)
    state_k["filter_masks"] = {"out": jnp.asarray(colmask)}

    @jax.jit
    def run_k(state, batches):
        def body(st, b):
            st, metrics = engine.round_core(cfg_k, grad_kernel, la_kernel,
                                            st, b)
            return st, metrics["tau_eff"]
        return jax.lax.scan(body, state, batches)

    state_k, taus_k = run_k(state_k, stacked)

    # params-mode engine on the same masks
    state_p = engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                      cfg_p)
    state_p["masks"] = jax.tree.map(jnp.asarray, masks)
    state_p, taus_p = _scan_engine(cfg_p, state_p, rounds)

    # f64 oracle (params-mode mask semantics — the ground truth for both)
    ref_state = ref_engine.ref_init_state(params, cfg_p, masks=masks)
    for b in rounds:
        ref_state, _ = ref_engine.ref_round(
            cfg_p, model.np_grad, model.np_loss_and_acc, ref_state, b)

    for lk, lp, lr_ in zip(jax.tree.leaves(state_k["params"]),
                           jax.tree.leaves(state_p["params"]),
                           jax.tree.leaves(ref_state["params"])):
        np.testing.assert_allclose(np.asarray(lk), np.asarray(lp), atol=1e-5,
                                   err_msg=f"kernel != params in mode={mode}")
        np.testing.assert_allclose(np.asarray(lk), lr_, atol=1e-5,
                                   err_msg=f"kernel != oracle in mode={mode}")
    np.testing.assert_allclose(np.asarray(taus_k), np.asarray(taus_p),
                               atol=1e-5)
    # pruned coordinates stay exactly zero through the kernel path
    for leaf, m in zip(jax.tree.leaves(state_k["params"]),
                       jax.tree.leaves(masks)):
        np.testing.assert_array_equal(np.asarray(leaf)[m == 0], 0.0)


def test_init_round_state_kernel_mode_requires_filter_masks(world):
    model, params, _ = world
    cfg = EngineConfig(use_masks=True, masked_compute="kernel")
    with pytest.raises(ValueError, match="filter_masks"):
        engine.init_round_state(jax.tree.map(jnp.asarray, params), cfg)
    with pytest.raises(ValueError, match="masked_compute"):
        EngineConfig(masked_compute="dense")


def test_all_ones_masks_equal_unmasked_engine(world):
    """use_masks with all-ones masks must be a numerical no-op, so a masked
    engine can be compiled up front and pruned mid-scan without a re-jit."""
    model, params, rounds = world
    base = dict(lr=0.08, lr_decay=0.97, use_server_update=True,
                local_momentum="restart", server_momentum=True)
    cfg_m = EngineConfig(use_masks=True, **base)
    cfg_u = EngineConfig(use_masks=False, **base)

    state_m, taus_m = _scan_engine(
        cfg_m, engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                       cfg_m), rounds)
    state_u, taus_u = _scan_engine(
        cfg_u, engine.init_round_state(jax.tree.map(jnp.asarray, params),
                                       cfg_u), rounds)
    for a, b in zip(jax.tree.leaves(state_m["params"]),
                    jax.tree.leaves(state_u["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(taus_m), np.asarray(taus_u))


def test_closed_form_gradient_matches_jax_grad(world):
    """The oracle's hand-written softmax CE gradient vs. jax.grad."""
    model, params, rounds = world
    b = jax.tree.map(lambda x: x[0, 0], rounds[0]["client"])
    g_np = model.np_grad(params, b)
    g_jax = jnp_grad(jax.tree.map(jnp.asarray, params), b)
    for k in g_np:
        np.testing.assert_allclose(np.asarray(g_jax[k]), g_np[k], atol=1e-6)


# ---------------------------------------------------------------------------
# Pod path vs. simulation path: the two public wirings of the one engine
# ---------------------------------------------------------------------------

class DictSoftmaxModel:
    """Batch-dict adapter (the pod path's model interface) for the toy."""

    def __init__(self, seed=7):
        self._np = SoftmaxRegression(dim=DIM, num_classes=CLASSES)
        self._seed = seed

    def init(self, rng):
        return jax.tree.map(jnp.asarray, self._np.init(seed=self._seed))

    def apply(self, params, batch):
        return batch["x"] @ params["w"] + params["b"], jnp.zeros(())

    def loss(self, params, batch):
        return softmax_xent_acc(self.apply(params, batch)[0],
                                batch["labels"])[0]


class XYSoftmaxModel:
    """(params, x, y) adapter (the simulation trainer's model interface)."""

    def __init__(self, seed=7):
        self._np = SoftmaxRegression(dim=DIM, num_classes=CLASSES)
        self._seed = seed

    def init(self, rng):
        return jax.tree.map(jnp.asarray, self._np.init(seed=self._seed))

    def loss_and_acc(self, params, x, y):
        return softmax_xent_acc(x @ params["w"] + params["b"], y)


def test_pod_step_matches_simulation_step(world):
    """make_fl_train_step (FLRunConfig wiring) and FederatedTrainer.round_step
    (FLConfig wiring) must produce identical params from identical inputs."""
    from repro.core.rounds import FederatedTrainer, FLConfig
    from repro.data.pipeline import FederatedData
    from repro.launch.steps import FLRunConfig, make_fl_train_step

    _, params, rounds = world
    lr = 0.08

    # pod path
    run_cfg = FLRunConfig(lr=lr, beta_local=0.9, beta_server=0.9,
                          eta_server=1.0, local_steps=STEPS, server_tau=TAU,
                          server_batch=SBATCH, use_server_update=True,
                          use_momentum=True)
    init_state, train_step = make_fl_train_step(
        None, run_cfg, CLIENTS, model=DictSoftmaxModel())
    pod_state = init_state(jax.random.key(0))
    pod_taus = []
    for b in rounds:
        pod_batch = {
            "client": {"x": jnp.asarray(b["client"][0]),
                       "labels": jnp.asarray(b["client"][1])},
            "server": {"x": jnp.asarray(b["server"][0]),
                       "labels": jnp.asarray(b["server"][1])},
            "sizes": jnp.asarray(b["sizes"]),
            "d_round": jnp.asarray(b["d_round"]),
            "d_server": jnp.asarray(b["d_server"]),
            "n0": jnp.asarray(b["n0"]),
        }
        pod_state, t_eff = jax.jit(train_step)(pod_state, pod_batch)
        pod_taus.append(float(t_eff))

    # simulation path: same algorithm through FLConfig + round_step
    model = XYSoftmaxModel()
    n_k = STEPS * BATCH  # one local epoch of STEPS batches
    data = FederatedData(
        client_x=np.zeros((CLIENTS, n_k, DIM), np.float32),
        client_y=np.zeros((CLIENTS, n_k), np.int64),
        sizes=np.asarray([40.0, 25.0, 35.0], np.float32),
        client_dists=np.full((CLIENTS, CLASSES), 0.25, np.float32),
        server_x=np.zeros((TAU * SBATCH, DIM), np.float32),
        server_y=np.zeros((TAU * SBATCH,), np.int64),
        server_dist=np.full((CLASSES,), 0.25, np.float32),
        test_x=np.zeros((4, DIM), np.float32),
        test_y=np.zeros((4,), np.int64))
    fl_cfg = FLConfig(num_clients=CLIENTS, clients_per_round=CLIENTS,
                      local_epochs=1, batch_size=BATCH, lr=lr, lr_decay=1.0,
                      use_server_update=True, local_momentum="restart",
                      server_momentum=True, server_epochs=1,
                      server_batch_size=SBATCH)
    trainer = FederatedTrainer(model, data, fl_cfg)
    sim_state = engine.init_round_state(model.init(None),
                                        trainer.engine_config)
    sim_taus = []
    for b in rounds:
        sim_batch = {
            "client": (jnp.asarray(b["client"][0]),
                       jnp.asarray(b["client"][1])),
            "server": (jnp.asarray(b["server"][0]),
                       jnp.asarray(b["server"][1])),
            "sizes": jnp.asarray(b["sizes"]),
            "d_round": jnp.asarray(b["d_round"]),
            "d_server": jnp.asarray(b["d_server"]),
            "n0": jnp.asarray(b["n0"]),
        }
        sim_state, metrics = trainer.round_step(sim_state, sim_batch)
        sim_taus.append(float(metrics["tau_eff"]))

    for a, b_ in zip(jax.tree.leaves(pod_state["params"]),
                     jax.tree.leaves(sim_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)
    np.testing.assert_allclose(pod_taus, sim_taus, rtol=1e-5)
