"""f64 model oracle locks: the jax f32 LM forward vs repro.models.ref64.

The NumPy float64 forward is the precision ground truth the f32 jax
model (which hard-casts rmsnorm/rope/softmax to f32) is measured
against.  Two claims:

* the WHOLE-MODEL f32 float error is bounded (2e-5 on logits, 1e-6 on
  the loss scalar — an order of magnitude of headroom over measured);
* in f64 the FedAP mask-mode and shrink-mode forwards are BIT-IDENTICAL
  (exact 0/1 masks, silu(0) = 0 through wo), proving any masked-vs-shrunk
  delta in the jax paths is f32 reassociation, not semantics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import pruning_lm
from repro.models import ref64
from repro.models.lm import LM

CFG = ModelConfig(name="dense-tiny", family="dense", rope="1d",
                  norm="rmsnorm", act="silu", param_dtype="float32",
                  remat="none", num_layers=2, d_model=64, num_heads=2,
                  num_kv_heads=1, d_ff=128, vocab_size=256)


@pytest.fixture(scope="module")
def world():
    model = LM(CFG)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(7)
    toks = rng.integers(0, CFG.vocab_size, (4, 16)).astype(np.int32)
    labels = rng.integers(0, CFG.vocab_size, (4, 16)).astype(np.int32)
    return model, params, toks, labels


class TestForwardLock:
    def test_dense_forward_within_budget(self, world):
        model, params, toks, _ = world
        want = ref64.forward_f64(CFG, params, toks)
        got, aux = model.apply(params, {"tokens": jnp.asarray(toks)})
        assert float(aux) == 0.0
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   atol=2e-5, rtol=0)

    def test_masked_forward_within_budget(self, world):
        model, params, toks, _ = world
        kept = model.decide_kept(params, 0.5, align=64)
        fmasks = model.filter_masks(params, kept)
        want = ref64.forward_f64(CFG, params, toks, masks=fmasks)
        got, _ = model.apply(params, {"tokens": jnp.asarray(toks)},
                             masks=fmasks)
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   atol=2e-5, rtol=0)

    def test_loss_and_acc_lock(self, world):
        model, params, toks, labels = world
        want_loss, want_acc = ref64.loss_and_acc_f64(CFG, params, toks,
                                                     labels)
        loss, acc = model.loss_and_acc(params, jnp.asarray(toks),
                                       jnp.asarray(labels))
        assert abs(float(loss) - want_loss) < 1e-6
        assert float(acc) == want_acc

    def test_gelu_variant(self, world):
        """The act='gelu' (no-gate) FFN leg of the oracle."""
        cfg = dataclasses.replace(CFG, act="gelu")
        model = LM(cfg)
        params = model.init(jax.random.key(2))
        toks = np.arange(32, dtype=np.int32).reshape(2, 16)
        want = ref64.forward_f64(cfg, params, toks)
        got, _ = model.apply(params, {"tokens": jnp.asarray(toks)})
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   atol=2e-5, rtol=0)


class TestMaskShrinkIdentity:
    def test_bit_identical_in_f64(self, world):
        """masked forward == structurally shrunk forward, EXACTLY, when
        both run in f64 — the semantic core of FedAP's mask mode."""
        model, params, toks, _ = world
        kept = model.decide_kept(params, 0.5, align=64)
        fmasks = model.filter_masks(params, kept)
        masked = ref64.forward_f64(CFG, params, toks, masks=fmasks)
        shrunk_params = pruning_lm.shrink_ffn_at(params, kept["mlp"])
        scfg = dataclasses.replace(
            CFG, d_ff=int(np.asarray(kept["mlp"]).shape[-1]))
        shrunk = ref64.forward_f64(scfg, shrunk_params, toks)
        assert np.array_equal(masked, shrunk)      # not allclose: equal


class TestScope:
    def test_unsupported_config_is_loud(self, world):
        _, params, toks, _ = world
        with pytest.raises(ValueError, match="dense"):
            ref64.forward_f64(dataclasses.replace(CFG, family="moe"),
                              params, toks)
        with pytest.raises(ValueError, match="rmsnorm"):
            ref64.forward_f64(dataclasses.replace(CFG, norm="layernorm"),
                              params, toks)
