"""Per-kernel allclose vs. the ref.py oracles, swept over shapes/dtypes.

All kernels run in interpret mode (pl.pallas_call(..., interpret=True)):
the kernel body executes in Python on CPU, which validates the block
decomposition, index maps, scratch accumulation, and masking logic.

Marked ``slow`` (interpret-mode sweeps take ~half a minute) — deselected
from the default tier-1 run; execute with ``-m slow`` or ``-m ""``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.masked_matmul import masked_matmul
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (1, 256, 4, 4, 64),     # MHA
        (2, 512, 8, 2, 64),     # GQA 4:1
        (1, 256, 8, 1, 128),    # MQA
        (2, 384, 6, 3, 32),     # non-pow2 seq (384 = 3 * 128)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal(self, b, s, h, kv, hd, dtype):
        q, k, v = (_rand((b, s, h, hd), dtype), _rand((b, s, kv, hd), dtype),
                   _rand((b, s, kv, hd), dtype))
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("window", [64, 128, 256])
    def test_sliding_window(self, window):
        q = _rand((1, 512, 4, 64), jnp.float32)
        k = _rand((1, 512, 2, 64), jnp.float32)
        v = _rand((1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=128, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        q = _rand((2, 256, 4, 64), jnp.float32)
        k = _rand((2, 256, 4, 64), jnp.float32)
        v = _rand((2, 256, 4, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_cross_lengths(self):
        q = _rand((1, 128, 4, 64), jnp.float32)
        k = _rand((1, 512, 2, 64), jnp.float32)
        v = _rand((1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("bq,bk", [(128, 256), (256, 128), (64, 64)])
    def test_block_shape_invariance(self, bq, bk):
        q = _rand((1, 512, 4, 64), jnp.float32)
        k = _rand((1, 512, 2, 64), jnp.float32)
        v = _rand((1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (2, 512, 8, 8, 64),
        (4, 1024, 8, 2, 128),
        (1, 256, 16, 1, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, s, h, kv, hd, dtype):
        q = _rand((b, 1, h, hd), dtype)
        k = _rand((b, s, kv, hd), dtype)
        v = _rand((b, s, kv, hd), dtype)
        out = decode_attention(q, k, v, block_k=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_consistent_with_flash_last_position(self):
        """Decoding the last token of a prefix == full attention's last row."""
        b, s, h, kv, hd = 1, 256, 4, 2, 64
        q = _rand((b, s, h, hd), jnp.float32)
        k = _rand((b, s, kv, hd), jnp.float32)
        v = _rand((b, s, kv, hd), jnp.float32)
        full = ref.flash_attention_ref(q, k, v, causal=True)
        dec = decode_attention(q[:, -1:], k, v, block_k=128, interpret=True)
        np.testing.assert_allclose(dec[:, 0], full[:, -1], atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("lengths", [[1, 128, 300, 512], [37, 512, 5, 100]])
    def test_ragged_lengths_match_ref(self, lengths):
        """Per-batch valid-prefix masking (the continuous-batching slot
        semantics), including lengths mid-block and whole kv blocks past
        the valid prefix."""
        b, s, h, kv, hd = 4, 512, 8, 2, 64
        q = _rand((b, 1, h, hd), jnp.float32)
        k = _rand((b, s, kv, hd), jnp.float32)
        v = _rand((b, s, kv, hd), jnp.float32)
        lens = jnp.asarray(lengths, jnp.int32)
        out = decode_attention(q, k, v, lens, block_k=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_full_lengths_equal_unmasked(self):
        """lengths == s_len must reproduce the unmasked kernel bit-exactly."""
        b, s, h, kv, hd = 2, 256, 4, 2, 64
        q = _rand((b, 1, h, hd), jnp.float32)
        k = _rand((b, s, kv, hd), jnp.float32)
        v = _rand((b, s, kv, hd), jnp.float32)
        full = decode_attention(q, k, v, jnp.full((b,), s, jnp.int32),
                                block_k=128, interpret=True)
        plain = decode_attention(q, k, v, block_k=128, interpret=True)
        assert np.array_equal(np.asarray(full), np.asarray(plain))

    def test_stale_rows_never_attended(self):
        """Garbage past a slot's valid prefix (a page's previous occupant)
        must not perturb the output at all."""
        b, s, h, kv, hd = 2, 256, 4, 2, 64
        q = _rand((b, 1, h, hd), jnp.float32)
        k = _rand((b, s, kv, hd), jnp.float32)
        v = _rand((b, s, kv, hd), jnp.float32)
        lens = jnp.asarray([100, 17], jnp.int32)
        clean = decode_attention(q, k, v, lens, block_k=128, interpret=True)
        pos = np.arange(s)[None, :, None, None] >= np.asarray(lens)[:, None,
                                                                    None, None]
        trash_k = jnp.where(pos, 1e4, k)
        trash_v = jnp.where(pos, -1e4, v)
        dirty = decode_attention(q, trash_k, trash_v, lens, block_k=128,
                                 interpret=True)
        assert np.array_equal(np.asarray(clean), np.asarray(dirty))


class TestSSDScan:
    @pytest.mark.parametrize("b,s,nh,p,n,chunk", [
        (2, 256, 4, 8, 16, 64),
        (1, 512, 2, 16, 8, 128),
        (3, 128, 8, 4, 4, 32),
    ])
    def test_matches_sequential_ref(self, b, s, nh, p, n, chunk):
        x = _rand((b, s, nh, p), jnp.float32)
        bm = _rand((b, s, n), jnp.float32)
        cm = _rand((b, s, n), jnp.float32)
        dt = _rand((b, s, nh), jnp.float32)
        al = _rand((nh,), jnp.float32) * 0.1
        d = jnp.ones((nh,))
        db = jnp.zeros((nh,))
        out = ssd_scan(x, bm, cm, dt, al, d, db, chunk=chunk, interpret=True)
        want = ref.ssd_scan_ref(x, bm, cm, dt, al, d, db)
        np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)

    def test_chunk_invariance(self):
        b, s, nh, p, n = 1, 256, 2, 4, 8
        args = (_rand((b, s, nh, p), jnp.float32), _rand((b, s, n), jnp.float32),
                _rand((b, s, n), jnp.float32), _rand((b, s, nh), jnp.float32),
                _rand((nh,), jnp.float32) * 0.1, jnp.ones((nh,)), jnp.zeros((nh,)))
        a = ssd_scan(*args, chunk=32, interpret=True)
        b_ = ssd_scan(*args, chunk=128, interpret=True)
        np.testing.assert_allclose(a, b_, atol=2e-4, rtol=2e-4)


class TestMaskedMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 256, 512), (256, 128, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, dtype):
        x = _rand((m, k), dtype)
        w = _rand((k, n), dtype)
        mask = jnp.asarray(RNG.integers(0, 2, n // 128), jnp.float32)
        out = masked_matmul(x, w, mask, interpret=True)
        want = ref.masked_matmul_ref(x, w, mask)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_all_kept_equals_dense(self):
        x = _rand((128, 128), jnp.float32)
        w = _rand((128, 256), jnp.float32)
        out = masked_matmul(x, w, jnp.ones((2,)), interpret=True)
        np.testing.assert_allclose(out, x @ w, atol=2e-4, rtol=2e-4)

    def test_all_pruned_is_zero(self):
        x = _rand((128, 128), jnp.float32)
        w = _rand((128, 256), jnp.float32)
        out = masked_matmul(x, w, jnp.zeros((2,)), interpret=True)
        assert float(jnp.max(jnp.abs(out))) == 0.0


class TestMaskedMatmulVJP:
    """The custom VJP: both backward Pallas kernels against the f64 NumPy
    reference, including partially-kept and fully-pruned blocks."""

    def _case(self, m, k, n, mask, scale=0.1):
        x = jnp.asarray(RNG.standard_normal((m, k)) * scale, jnp.float32)
        w = jnp.asarray(RNG.standard_normal((k, n)) * scale, jnp.float32)
        dy = jnp.asarray(RNG.standard_normal((m, n)) * scale, jnp.float32)
        return x, w, jnp.asarray(mask, jnp.float32), dy

    @pytest.mark.parametrize("m,k,n,mask", [
        (128, 256, 512, [1, 0, 1, 0]),
        (256, 128, 256, [0, 1]),
        (128, 128, 384, [1, 1, 1]),      # nothing pruned
        (128, 128, 256, [0, 0]),         # everything pruned
    ])
    def test_grads_match_f64_reference(self, m, k, n, mask):
        x, w, bmask, dy = self._case(m, k, n, mask)

        def f(x_, w_):
            return jnp.sum(masked_matmul(x_, w_, bmask, interpret=True) * dy)

        y = masked_matmul(x, w, bmask, interpret=True)
        dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
        y_ref = ref.masked_matmul_fwd_ref64(x, w, bmask)
        dx_ref, dw_ref = ref.masked_matmul_vjp_ref64(x, w, bmask, dy)
        np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(dx, np.float64), dx_ref,
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(dw, np.float64), dw_ref,
                                   atol=1e-5, rtol=1e-5)

    def test_pruned_dw_blocks_exactly_zero(self):
        """A pruned filter block receives an EXACT zero gradient (written
        by the kernel, not accumulated) — mask-mode training stays
        self-sustaining inside a compiled scan."""
        x, w, bmask, dy = self._case(128, 256, 512, [1, 0, 1, 0])

        def f(w_):
            return jnp.sum(masked_matmul(x, w_, bmask, interpret=True) * dy)

        dw = np.asarray(jax.grad(f)(w))
        assert np.abs(dw[:, 128:256]).max() == 0.0
        assert np.abs(dw[:, 384:]).max() == 0.0
        assert np.abs(dw[:, :128]).max() > 0.0

    def test_masked_dense_grads_with_partial_blocks(self):
        """Through the masked_dense routing (M-padding + elementwise
        re-mask): gradients with a PARTIALLY-kept block must equal the
        dense-masked reference — the fine-grained mask rides on top of the
        block-granular kernel."""
        from repro.models.cnn import masked_dense

        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((32, 256)) * 0.1, jnp.float32)
        w = jnp.asarray(rng.standard_normal((256, 256)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.standard_normal((256,)) * 0.1, jnp.float32)
        mask = np.ones((256,), np.float32)
        mask[128:] = 0.0          # second block fully pruned
        mask[5:40] = 0.0          # first block partially kept
        mask = jnp.asarray(mask)

        def f_kernel(x_, w_, b_):
            return jnp.sum(jnp.tanh(masked_dense(x_, w_, mask, b_)))

        def f_dense(x_, w_, b_):
            return jnp.sum(jnp.tanh(((x_ @ w_) + b_) * mask))

        out_k = f_kernel(x, w, b)
        out_d = f_dense(x, w, b)
        np.testing.assert_allclose(float(out_k), float(out_d), atol=1e-5,
                                   rtol=1e-5)
        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
        gd = jax.grad(f_dense, argnums=(0, 1, 2))(x, w, b)
        for a, b_ in zip(gk, gd):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=1e-5, rtol=1e-5)

    def test_value_errors_name_the_shapes(self):
        x = jnp.zeros((100, 256))
        w = jnp.zeros((256, 256))
        with pytest.raises(ValueError, match=r"\(100, 256\)"):
            masked_matmul(x, w, jnp.ones((2,)), interpret=True)
        with pytest.raises(ValueError, match="block_mask"):
            masked_matmul(jnp.zeros((128, 256)), w, jnp.ones((3,)),
                          interpret=True)
        with pytest.raises(ValueError, match="contraction"):
            masked_matmul(jnp.zeros((128, 128)), w, jnp.ones((2,)),
                          interpret=True)


class TestOpsDispatch:
    def test_ops_fallback_on_ragged_shapes(self):
        """Non-divisible shapes fall back to the oracle (still correct)."""
        q = _rand((1, 100, 4, 64), jnp.float32)
        k = _rand((1, 100, 2, 64), jnp.float32)
        v = _rand((1, 100, 2, 64), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)
