"""Per-kernel allclose vs. the ref.py oracles, swept over shapes/dtypes.

All kernels run in interpret mode (pl.pallas_call(..., interpret=True)):
the kernel body executes in Python on CPU, which validates the block
decomposition, index maps, scratch accumulation, and masking logic.

Marked ``slow`` (interpret-mode sweeps take ~half a minute) — deselected
from the default tier-1 run; execute with ``-m slow`` or ``-m ""``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.masked_matmul import masked_matmul
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (1, 256, 4, 4, 64),     # MHA
        (2, 512, 8, 2, 64),     # GQA 4:1
        (1, 256, 8, 1, 128),    # MQA
        (2, 384, 6, 3, 32),     # non-pow2 seq (384 = 3 * 128)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal(self, b, s, h, kv, hd, dtype):
        q, k, v = (_rand((b, s, h, hd), dtype), _rand((b, s, kv, hd), dtype),
                   _rand((b, s, kv, hd), dtype))
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("window", [64, 128, 256])
    def test_sliding_window(self, window):
        q = _rand((1, 512, 4, 64), jnp.float32)
        k = _rand((1, 512, 2, 64), jnp.float32)
        v = _rand((1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=128, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        q = _rand((2, 256, 4, 64), jnp.float32)
        k = _rand((2, 256, 4, 64), jnp.float32)
        v = _rand((2, 256, 4, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_cross_lengths(self):
        q = _rand((1, 128, 4, 64), jnp.float32)
        k = _rand((1, 512, 2, 64), jnp.float32)
        v = _rand((1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("bq,bk", [(128, 256), (256, 128), (64, 64)])
    def test_block_shape_invariance(self, bq, bk):
        q = _rand((1, 512, 4, 64), jnp.float32)
        k = _rand((1, 512, 2, 64), jnp.float32)
        v = _rand((1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (2, 512, 8, 8, 64),
        (4, 1024, 8, 2, 128),
        (1, 256, 16, 1, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, s, h, kv, hd, dtype):
        q = _rand((b, 1, h, hd), dtype)
        k = _rand((b, s, kv, hd), dtype)
        v = _rand((b, s, kv, hd), dtype)
        out = decode_attention(q, k, v, block_k=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_consistent_with_flash_last_position(self):
        """Decoding the last token of a prefix == full attention's last row."""
        b, s, h, kv, hd = 1, 256, 4, 2, 64
        q = _rand((b, s, h, hd), jnp.float32)
        k = _rand((b, s, kv, hd), jnp.float32)
        v = _rand((b, s, kv, hd), jnp.float32)
        full = ref.flash_attention_ref(q, k, v, causal=True)
        dec = decode_attention(q[:, -1:], k, v, block_k=128, interpret=True)
        np.testing.assert_allclose(dec[:, 0], full[:, -1], atol=2e-5, rtol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("b,s,nh,p,n,chunk", [
        (2, 256, 4, 8, 16, 64),
        (1, 512, 2, 16, 8, 128),
        (3, 128, 8, 4, 4, 32),
    ])
    def test_matches_sequential_ref(self, b, s, nh, p, n, chunk):
        x = _rand((b, s, nh, p), jnp.float32)
        bm = _rand((b, s, n), jnp.float32)
        cm = _rand((b, s, n), jnp.float32)
        dt = _rand((b, s, nh), jnp.float32)
        al = _rand((nh,), jnp.float32) * 0.1
        d = jnp.ones((nh,))
        db = jnp.zeros((nh,))
        out = ssd_scan(x, bm, cm, dt, al, d, db, chunk=chunk, interpret=True)
        want = ref.ssd_scan_ref(x, bm, cm, dt, al, d, db)
        np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)

    def test_chunk_invariance(self):
        b, s, nh, p, n = 1, 256, 2, 4, 8
        args = (_rand((b, s, nh, p), jnp.float32), _rand((b, s, n), jnp.float32),
                _rand((b, s, n), jnp.float32), _rand((b, s, nh), jnp.float32),
                _rand((nh,), jnp.float32) * 0.1, jnp.ones((nh,)), jnp.zeros((nh,)))
        a = ssd_scan(*args, chunk=32, interpret=True)
        b_ = ssd_scan(*args, chunk=128, interpret=True)
        np.testing.assert_allclose(a, b_, atol=2e-4, rtol=2e-4)


class TestMaskedMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 256, 512), (256, 128, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, dtype):
        x = _rand((m, k), dtype)
        w = _rand((k, n), dtype)
        mask = jnp.asarray(RNG.integers(0, 2, n // 128), jnp.float32)
        out = masked_matmul(x, w, mask, interpret=True)
        want = ref.masked_matmul_ref(x, w, mask)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_all_kept_equals_dense(self):
        x = _rand((128, 128), jnp.float32)
        w = _rand((128, 256), jnp.float32)
        out = masked_matmul(x, w, jnp.ones((2,)), interpret=True)
        np.testing.assert_allclose(out, x @ w, atol=2e-4, rtol=2e-4)

    def test_all_pruned_is_zero(self):
        x = _rand((128, 128), jnp.float32)
        w = _rand((128, 256), jnp.float32)
        out = masked_matmul(x, w, jnp.zeros((2,)), interpret=True)
        assert float(jnp.max(jnp.abs(out))) == 0.0


class TestOpsDispatch:
    def test_ops_fallback_on_ragged_shapes(self):
        """Non-divisible shapes fall back to the oracle (still correct)."""
        q = _rand((1, 100, 4, 64), jnp.float32)
        k = _rand((1, 100, 2, 64), jnp.float32)
        v = _rand((1, 100, 2, 64), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)
