"""The declarative TrainPlan API: compilation, execution, and the FedAP
mask/shrink equivalence that makes in-scan pruning trustworthy.

The heavyweight lock is ``test_masked_prune_matches_shrink``: a FedDUMAP
run with ``Prune(mode="mask")`` (every round inside compiled scan chunks,
no re-jit) must train EXACTLY like ``Prune(mode="shrink")`` (the legacy
re-materializing path) on a normalization-free model — compacting the
masked params at the kept indices reproduces the shrunk params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Callback,
    Eval,
    FedAPConfig,
    FederatedTrainer,
    FLConfig,
    Prune,
    Scan,
    Snapshot,
    TrainPlan,
    baselines,
    engine,
    fedap_plan,
    feddumap_config,
    pruning,
)
from repro.core.fedap import fedap_decision
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import SimpleCNN


# ---------------------------------------------------------------------------
# Plan construction / compilation (host-only, no jit)
# ---------------------------------------------------------------------------

class TestPlanCompilation:
    def test_consecutive_scans_merge(self):
        plan = TrainPlan(Scan(3), Scan(2), Eval(), Scan(1), Scan(1), Scan(1))
        assert plan.compiled() == (Scan(5), Eval(), Scan(3))
        assert plan.total_rounds == 8
        assert plan.chunk_lengths() == (3, 5)

    def test_nested_iterables_flatten(self):
        plan = TrainPlan([Scan(2), Eval()], Scan(2))
        assert plan.events == (Scan(2), Eval(), Scan(2))

    def test_event_validation(self):
        with pytest.raises(ValueError):
            Scan(0)
        with pytest.raises(ValueError):
            Prune(mode="sparsify")
        with pytest.raises(TypeError):
            TrainPlan(Scan(1), "eval")

    def test_uses_masks(self):
        assert TrainPlan(Scan(1), Prune(mode="mask")).uses_masks
        assert not TrainPlan(Scan(1), Prune(mode="shrink")).uses_masks

    def test_standard_builder_matches_legacy_eval_cadence(self):
        plan = TrainPlan.standard(7, eval_every=3)
        assert plan.events == (Scan(3), Eval(), Scan(3), Eval(),
                               Scan(1), Eval())

    def test_fedap_plan_schedules_prune_after_round(self):
        plan = fedap_plan(6, prune_round=2, mode="mask", eval_every=3)
        assert plan.events == (Scan(2), Prune(mode="mask"), Scan(1), Eval(),
                               Scan(3), Eval())
        with pytest.raises(ValueError):
            fedap_plan(6, prune_round=7)

    def test_with_callback_interleaves(self):
        fn = lambda tr, t, p: None
        plan = TrainPlan.with_callback(4, fn, every=2, eval_every=4)
        assert plan.events == (Scan(2), Callback(fn), Scan(2), Eval(),
                               Callback(fn))

    def test_eval_every_zero_means_no_evals(self):
        fn = lambda tr, t, p: None
        plan = TrainPlan.with_callback(3, fn, eval_every=0)
        assert not any(isinstance(e, Eval) for e in plan.events)
        with pytest.raises(ValueError, match="eval_every"):
            TrainPlan.standard(3, eval_every=0)
        with pytest.raises(ValueError, match="eval_every"):
            fedap_plan(4, prune_round=2, eval_every=0)


class TestFLConfigValidation:
    def test_bad_local_momentum_fails_at_construction(self):
        with pytest.raises(ValueError, match="local_momentum"):
            FLConfig(local_momentum="nesterov")

    def test_bad_sampling_fails_fast(self):
        with pytest.raises(ValueError, match="clients_per_round"):
            FLConfig(num_clients=5, clients_per_round=10)
        with pytest.raises(ValueError, match="batch_size"):
            FLConfig(batch_size=0)
        with pytest.raises(ValueError, match="lr"):
            FLConfig(lr=-0.1)


# ---------------------------------------------------------------------------
# Execution over the real engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_world():
    # build_federated_data holds out 1000 training samples for the server
    # pool, so train_size must exceed device_pool + 1000
    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=1600, test_size=100, noise_scale=0.5)
    data = build_federated_data(num_clients=6, server_fraction=0.1,
                                device_pool=600, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(4, 8, 8), fc_width=16)
    return data, model


CFG = dict(num_clients=6, clients_per_round=3, local_epochs=1,
           batch_size=10, lr=0.05)


class TestExecutor:
    def test_run_result_structure(self, tiny_world):
        data, model = tiny_world
        tr = FederatedTrainer(model, data, feddumap_config(**CFG))
        res = tr.run(TrainPlan(Scan(2), Snapshot(name="mid"), Scan(1),
                               Eval()))
        assert res.history["round"] == [2]
        assert np.isfinite(res.history["loss"][0])
        assert res.artifacts["mid"]["round"] == 2
        assert float(res.state["round"]) == 3.0
        # snapshot is a live copy, distinct from the final params
        assert (jax.tree.leaves(res.artifacts["mid"]["params"])[0]
                is not jax.tree.leaves(res.params)[0])

    def test_int_plan_equals_standard_plan(self, tiny_world):
        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        res_a = FederatedTrainer(model, data, cfg).run(4, eval_every=2)
        res_b = FederatedTrainer(model, data, cfg).run(
            TrainPlan.standard(4, eval_every=2))
        np.testing.assert_allclose(res_a.history["acc"], res_b.history["acc"])
        for a, b in zip(jax.tree.leaves(res_a.params),
                        jax.tree.leaves(res_b.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_callback_replacement_restarts_state(self, tiny_world):
        data, model = tiny_world
        seen = []

        def cb(trainer, t, params):
            seen.append(t)
            if t == 1:
                return jax.tree.map(jnp.zeros_like, params)
            return None

        tr = FederatedTrainer(model, data, feddumap_config(**CFG))
        res = tr.run(TrainPlan.with_callback(3, cb, eval_every=3))
        assert seen == [0, 1, 2]
        assert float(res.state["round"]) == 3.0   # counter survived restart

    def test_compiled_engine_cache_shared_across_trainers(self, tiny_world):
        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        tr_a = FederatedTrainer(model, data, cfg)
        tr_b = FederatedTrainer(model, data, cfg)
        assert tr_a._compiled() is tr_b._compiled()
        # different engine switches -> different compiled programs
        cfg2 = baselines.fedavg_config(**CFG)
        assert (FederatedTrainer(model, data, cfg2)._compiled()
                is not tr_a._compiled())


class TestFedAPPlan:
    @pytest.fixture(scope="class")
    def pruned_runs(self, tiny_world):
        data, model = tiny_world
        # min_rate forces a real compression budget: the pure eigen-gap rule
        # prunes nothing on this easy synthetic task, which would make the
        # equivalence below vacuous
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)

        def run(mode):
            tr = FederatedTrainer(model, data, cfg)
            plan = fedap_plan(4, prune_round=2, mode=mode, eval_every=2)
            return tr, plan, tr.run(plan)

        return run("mask"), run("shrink")

    def test_masked_prune_matches_shrink(self, tiny_world, pruned_runs):
        """Acceptance lock: the in-scan masked prune trains EXACTLY like the
        re-materializing prune on a norm-free model — compacting the masked
        params at the kept indices reproduces the shrunk params."""
        data, model = tiny_world
        (_, _, res_m), (_, _, res_s) = pruned_runs
        kept_m = res_m.artifacts["prune"]["kept"]
        kept_s = res_s.artifacts["prune"]["kept"]
        # the decision actually pruned (min_rate floor bit)
        assert sum(len(v) for v in kept_m.values()) < 4 + 8 + 8
        assert {k: v.tolist() for k, v in kept_m.items()} \
            == {k: v.tolist() for k, v in kept_s.items()}

        spec = model.prune_spec(res_m.params)
        compacted = pruning.shrink_params(res_m.params, spec, kept_m)
        for a, b in zip(jax.tree.leaves(compacted),
                        jax.tree.leaves(res_s.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)
        np.testing.assert_allclose(res_m.history["tau_eff"],
                                   res_s.history["tau_eff"], atol=1e-4)

    def test_masked_plan_never_rejits(self, tiny_world, pruned_runs):
        """Every round of the masked plan runs inside compiled scan chunks:
        the chunk program traces once per distinct chunk length and the
        prune event adds NO new trace (static shapes, masks in the carry)."""
        (tr, plan, _), _ = pruned_runs
        ce = tr._compiled(use_masks=True)
        assert ce.chunk._cache_size() == len(plan.chunk_lengths())

    def test_masked_artifacts_and_zeroed_params(self, pruned_runs):
        (_, _, res_m), _ = pruned_runs
        art = res_m.artifacts["prune"]
        assert art["mode"] == "mask"
        assert 0.0 <= art["p_star"] <= 0.9
        assert set(art["filter_masks"]) == set(art["kept"])
        for p, m in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_m.state["masks"])):
            np.testing.assert_array_equal(
                np.asarray(p)[np.asarray(m) == 0], 0.0)

    def test_callback_after_masked_prune_keeps_masks(self, tiny_world):
        """A Callback replacing params after a Prune(mode='mask') must not
        discard the masks: the decision stays in force across the state
        rebuild and the replacement params are re-masked."""
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=1, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)
        tr = FederatedTrainer(model, data, cfg)
        cb = lambda trainer, t, params: jax.tree.map(
            lambda p: p + 1.0, params)            # deliberately unmasked
        res = tr.run(TrainPlan(Scan(1), Prune(mode="mask"), Callback(cb),
                               Scan(1), Eval()))
        masked_coords = 0
        for p, m in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(res.state["masks"])):
            np.testing.assert_array_equal(
                np.asarray(p)[np.asarray(m) == 0], 0.0)
            masked_coords += int(np.sum(np.asarray(m) == 0))
        assert masked_coords > 0

    def test_shrink_records_params_before(self, pruned_runs):
        _, (_, _, res_s) = pruned_runs
        before = res_s.artifacts["prune"]["params_before"]
        assert (jax.tree.map(jnp.shape, before)
                != jax.tree.map(jnp.shape, res_s.params))

    def test_shrink_event_reproduces_legacy_hook_path(self, tiny_world):
        """Prune(mode="shrink") must produce exactly what the legacy
        ``on_round_end`` hook protocol produced: per-round chunks, FedAP
        decision on a copy of the params, shrink, momentum restart with the
        round counter preserved."""
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)

        tr = FederatedTrainer(model, data, cfg)
        res = tr.run(fedap_plan(4, prune_round=2, mode="shrink",
                                eval_every=4))

        # legacy emulation: length=1 chunks + host hook after every round
        tr2 = FederatedTrainer(model, data, cfg)
        ce = tr2._compiled()
        data_dev = tr2._device_data()
        params0 = model.init(jax.random.key(cfg.seed))
        init_params = jax.tree.map(jnp.copy, params0)
        state = engine.init_round_state(jax.tree.map(jnp.copy, params0),
                                        ce.eng)
        for t in range(4):
            state, tr2._key, _ = ce.chunk(state, tr2._key, data_dev,
                                          length=1)
            if t + 1 == apcfg.prune_round:
                params = jax.tree.map(jnp.copy, state["params"])
                dec = fedap_decision(model, data, apcfg, params,
                                     init_params=init_params,
                                     rng=np.random.default_rng(cfg.seed))
                spec = model.prune_spec(params)
                round_ = state["round"]
                state = engine.init_round_state(
                    pruning.shrink_params(params, spec, dec.kept), ce.eng)
                state["round"] = round_

        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMaskedModelRouting:
    def test_masked_apply_equals_masked_params(self, tiny_world):
        """Model-level mask routing (feature-map masking + masked_dense) is
        numerically the mask-multiplied parameter tree."""
        data, model = tiny_world
        params = model.init(jax.random.key(1))
        spec = model.prune_spec(params)
        kept = {l.name: np.sort(np.random.default_rng(0).choice(
            pruning.get_path(params, l.weight).shape[l.filter_axis],
            size=3, replace=False)) for l in spec.layers}
        fmask = pruning.filter_masks(params, spec, kept)
        pmask = pruning.param_masks(params, spec, kept)
        x = jnp.asarray(data.server_x[:4])

        via_masks = model.apply(params, x, masks=fmask)
        via_params = model.apply(engine.apply_masks(params, pmask), x)
        np.testing.assert_allclose(np.asarray(via_masks),
                                   np.asarray(via_params), atol=1e-6)

    def test_masked_dense_routes_pallas_when_aligned(self):
        """128-aligned shapes go through the Pallas masked_matmul kernel
        (interpret mode on CPU): fully-pruned column blocks are skipped,
        partially-kept blocks are re-masked elementwise — exact."""
        from repro.models import masked_dense

        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        mask = np.ones((256,), np.float32)
        mask[128:] = 0.0          # second block fully pruned
        mask[7] = 0.0             # first block partially pruned
        b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        out = masked_dense(x, w, jnp.asarray(mask), b)
        ref = (x @ w + b) * mask
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    def test_lenet_masked_fc_fallback(self):
        """LeNet's fc widths are not 128-aligned: masked_dense falls back to
        the XLA path and must still equal the mask-multiplied params."""
        from repro.models import LeNet5

        model = LeNet5(num_classes=10, image_shape=(8, 8, 3))
        params = model.init(jax.random.key(0))
        spec = model.prune_spec(params)
        kept = {"fc1": np.arange(0, 120, 2), "fc2": np.arange(0, 84, 3)}
        spec = type(spec)(layers=tuple(l for l in spec.layers
                                       if l.name in kept))
        fmask = pruning.filter_masks(params, spec, kept)
        pmask = pruning.param_masks(params, spec, kept)
        x = jnp.asarray(np.random.default_rng(1).standard_normal(
            (4, 8, 8, 3)), jnp.float32)
        via_masks = model.apply(params, x, masks=fmask)
        via_params = model.apply(engine.apply_masks(params, pmask), x)
        np.testing.assert_allclose(np.asarray(via_masks),
                                   np.asarray(via_params), atol=1e-5)
