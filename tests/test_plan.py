"""The declarative TrainPlan API: compilation, execution, and the FedAP
mask/shrink equivalence that makes in-scan pruning trustworthy.

The heavyweight lock is ``test_masked_prune_matches_shrink``: a FedDUMAP
run with ``Prune(mode="mask")`` (every round inside compiled scan chunks,
no re-jit) must train EXACTLY like ``Prune(mode="shrink")`` (the legacy
re-materializing path) on a normalization-free model — compacting the
masked params at the kept indices reproduces the shrunk params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Callback,
    Eval,
    FedAPConfig,
    FederatedTrainer,
    FLConfig,
    Prune,
    Scan,
    Snapshot,
    TrainPlan,
    baselines,
    engine,
    fedap_plan,
    feddumap_config,
    pruning,
)
from repro.analysis.compile_budget import expected_programs
from repro.core.fedap import fedap_decision
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import SimpleCNN


# ---------------------------------------------------------------------------
# Plan construction / compilation (host-only, no jit)
# ---------------------------------------------------------------------------

class TestPlanCompilation:
    def test_consecutive_scans_merge(self):
        plan = TrainPlan(Scan(3), Scan(2), Eval(), Scan(1), Scan(1), Scan(1))
        assert plan.compiled() == (Scan(5), Eval(), Scan(3))
        assert plan.total_rounds == 8
        assert plan.chunk_lengths() == (3, 5)

    def test_nested_iterables_flatten(self):
        plan = TrainPlan([Scan(2), Eval()], Scan(2))
        assert plan.events == (Scan(2), Eval(), Scan(2))

    def test_event_validation(self):
        with pytest.raises(ValueError):
            Scan(0)
        with pytest.raises(ValueError):
            Prune(mode="sparsify")
        with pytest.raises(TypeError):
            TrainPlan(Scan(1), "eval")

    def test_uses_masks(self):
        assert TrainPlan(Scan(1), Prune(mode="mask")).uses_masks
        assert not TrainPlan(Scan(1), Prune(mode="shrink")).uses_masks

    def test_standard_builder_matches_legacy_eval_cadence(self):
        plan = TrainPlan.standard(7, eval_every=3)
        assert plan.events == (Scan(3), Eval(), Scan(3), Eval(),
                               Scan(1), Eval())

    def test_fedap_plan_schedules_prune_after_round(self):
        plan = fedap_plan(6, prune_round=2, mode="mask", eval_every=3)
        assert plan.events == (Scan(2), Prune(mode="mask"), Scan(1), Eval(),
                               Scan(3), Eval())
        with pytest.raises(ValueError):
            fedap_plan(6, prune_round=7)

    def test_fedap_plan_shrink_round_schedules_reuse_shrink(self):
        """Mask-now-shrink-later: the prune round applies masks (inside
        the compiled scan) and ``shrink_round`` compacts to the SAME
        decision via Prune(mode="shrink", reuse="prune")."""
        plan = fedap_plan(6, prune_round=2, shrink_round=4, eval_every=2)
        assert plan.events == (
            Scan(2), Eval(), Prune(mode="mask"),
            Scan(2), Eval(), Prune(mode="shrink", reuse="prune",
                                   name="shrink"),
            Scan(2), Eval())
        assert plan.uses_masks
        with pytest.raises(ValueError, match="shrink_round"):
            fedap_plan(6, prune_round=2, shrink_round=2)
        with pytest.raises(ValueError, match="shrink_round"):
            fedap_plan(6, prune_round=2, shrink_round=7)
        with pytest.raises(ValueError, match="mask"):
            fedap_plan(6, prune_round=2, shrink_round=4, mode="shrink")

    def test_prune_reuse_validation(self):
        with pytest.raises(ValueError, match="reuse"):
            Prune(mode="mask", reuse="prune")
        assert Prune(mode="shrink", reuse="prune").reuse == "prune"

    def test_with_callback_interleaves(self):
        fn = lambda tr, t, p: None
        plan = TrainPlan.with_callback(4, fn, every=2, eval_every=4)
        assert plan.events == (Scan(2), Callback(fn), Scan(2), Eval(),
                               Callback(fn))

    def test_eval_every_zero_means_no_evals(self):
        fn = lambda tr, t, p: None
        plan = TrainPlan.with_callback(3, fn, eval_every=0)
        assert not any(isinstance(e, Eval) for e in plan.events)
        with pytest.raises(ValueError, match="eval_every"):
            TrainPlan.standard(3, eval_every=0)
        with pytest.raises(ValueError, match="eval_every"):
            fedap_plan(4, prune_round=2, eval_every=0)


class TestFLConfigValidation:
    def test_bad_local_momentum_fails_at_construction(self):
        with pytest.raises(ValueError, match="local_momentum"):
            FLConfig(local_momentum="nesterov")

    def test_bad_sampling_fails_fast(self):
        with pytest.raises(ValueError, match="clients_per_round"):
            FLConfig(num_clients=5, clients_per_round=10)
        with pytest.raises(ValueError, match="batch_size"):
            FLConfig(batch_size=0)
        with pytest.raises(ValueError, match="lr"):
            FLConfig(lr=-0.1)


# ---------------------------------------------------------------------------
# Execution over the real engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_world():
    # build_federated_data holds out 1000 training samples for the server
    # pool, so train_size must exceed device_pool + 1000
    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=1600, test_size=100, noise_scale=0.5)
    data = build_federated_data(num_clients=6, server_fraction=0.1,
                                device_pool=600, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(4, 8, 8), fc_width=16)
    return data, model


CFG = dict(num_clients=6, clients_per_round=3, local_epochs=1,
           batch_size=10, lr=0.05)


class TestExecutor:
    def test_run_result_structure(self, tiny_world):
        data, model = tiny_world
        tr = FederatedTrainer(model, data, feddumap_config(**CFG))
        res = tr.run(TrainPlan(Scan(2), Snapshot(name="mid"), Scan(1),
                               Eval()))
        assert res.history["round"] == [3]   # completed rounds at the Eval
        assert np.isfinite(res.history["loss"][0])
        assert res.artifacts["mid"]["round"] == 2
        assert float(res.state["round"]) == 3.0
        # snapshot is a live copy, distinct from the final params
        assert (jax.tree.leaves(res.artifacts["mid"]["params"])[0]
                is not jax.tree.leaves(res.params)[0])

    def test_snapshot_artifact_survives_donation(self, tiny_world):
        """The no-aliasing lock for the donation-aware snapshot buffer:
        the chunk jit donates its round state, so the Snapshot artifact
        must not alias the donated buffers — the Scans that follow have
        to leave it bit-identical to a run truncated at the snapshot
        point (an aliased artifact would be overwritten, or read back
        as a deleted donated array)."""
        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        res = FederatedTrainer(model, data, cfg).run(
            TrainPlan(Scan(2), Snapshot(name="mid"), Scan(3), Eval()))
        res_trunc = FederatedTrainer(model, data, cfg).run(
            TrainPlan(Scan(2)))
        for a, b in zip(jax.tree.leaves(res.artifacts["mid"]["params"]),
                        jax.tree.leaves(res_trunc.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # ... while the run itself genuinely moved on past the snapshot
        assert any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(
                jax.tree.leaves(res.artifacts["mid"]["params"]),
                jax.tree.leaves(res.params)))

    def test_int_plan_equals_standard_plan(self, tiny_world):
        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        res_a = FederatedTrainer(model, data, cfg).run(4, eval_every=2)
        res_b = FederatedTrainer(model, data, cfg).run(
            TrainPlan.standard(4, eval_every=2))
        np.testing.assert_allclose(res_a.history["acc"], res_b.history["acc"])
        for a, b in zip(jax.tree.leaves(res_a.params),
                        jax.tree.leaves(res_b.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_leading_eval_records_round_zero(self, tiny_world):
        """Evaluate-before-training: a plan starting with Eval() must log
        the true round count 0 (not the fabricated round -1 of the old
        ``t - 1`` bookkeeping) with tau_eff 0.0 (no round has run)."""
        data, model = tiny_world
        tr = FederatedTrainer(model, data, feddumap_config(**CFG))
        res = tr.run(TrainPlan(Eval(), Scan(2), Eval()))
        assert res.history["round"] == [0, 2]
        assert res.history["tau_eff"][0] == 0.0
        assert res.history["tau_eff"][1] > 0.0
        assert all(np.isfinite(res.history["loss"]))

    def test_callback_replacement_restarts_state(self, tiny_world):
        """Legacy-hook contract: the callback fires at segment boundaries
        with the TRUE completed-round count (the first post-round hook
        sees 1, mirroring the Eval round fix — the old ``t - 1``
        bookkeeping fabricated a round 0), and a non-None return restarts
        the round state with the counter preserved."""
        data, model = tiny_world
        seen = []

        def cb(trainer, t, params):
            seen.append(t)
            if t == 1:
                return jax.tree.map(jnp.zeros_like, params)
            return None

        tr = FederatedTrainer(model, data, feddumap_config(**CFG))
        res = tr.run(TrainPlan.with_callback(3, cb, eval_every=3))
        assert seen == [1, 2, 3]
        assert float(res.state["round"]) == 3.0   # counter survived restart

    def test_compiled_engine_cache_shared_across_trainers(self, tiny_world):
        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        tr_a = FederatedTrainer(model, data, cfg)
        tr_b = FederatedTrainer(model, data, cfg)
        assert tr_a._compiled() is tr_b._compiled()
        # different engine switches -> different compiled programs
        cfg2 = baselines.fedavg_config(**CFG)
        assert (FederatedTrainer(model, data, cfg2)._compiled()
                is not tr_a._compiled())


class TestFedAPPlan:
    @pytest.fixture(scope="class")
    def pruned_runs(self, tiny_world):
        data, model = tiny_world
        # min_rate forces a real compression budget: the pure eigen-gap rule
        # prunes nothing on this easy synthetic task, which would make the
        # equivalence below vacuous
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)

        def run(mode):
            tr = FederatedTrainer(model, data, cfg)
            plan = fedap_plan(4, prune_round=2, mode=mode, eval_every=2)
            return tr, plan, tr.run(plan)

        return run("mask"), run("shrink")

    def test_masked_prune_matches_shrink(self, tiny_world, pruned_runs):
        """Acceptance lock: the in-scan masked prune trains EXACTLY like the
        re-materializing prune on a norm-free model — compacting the masked
        params at the kept indices reproduces the shrunk params."""
        data, model = tiny_world
        (_, _, res_m), (_, _, res_s) = pruned_runs
        kept_m = res_m.artifacts["prune"]["kept"]
        kept_s = res_s.artifacts["prune"]["kept"]
        # the decision actually pruned (min_rate floor bit)
        assert sum(len(v) for v in kept_m.values()) < 4 + 8 + 8
        assert {k: v.tolist() for k, v in kept_m.items()} \
            == {k: v.tolist() for k, v in kept_s.items()}

        spec = model.prune_spec(res_m.params)
        compacted = pruning.shrink_params(res_m.params, spec, kept_m)
        for a, b in zip(jax.tree.leaves(compacted),
                        jax.tree.leaves(res_s.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)
        np.testing.assert_allclose(res_m.history["tau_eff"],
                                   res_s.history["tau_eff"], atol=1e-4)

    def test_masked_plan_never_rejits(self, tiny_world, pruned_runs):
        """Every round of the masked plan runs inside compiled scan chunks:
        the chunk program traces once per distinct chunk length and the
        prune event adds NO new trace (static shapes, masks in the carry).
        The expected count comes from the audited compile budget
        (repro/analysis/compile_budget.json), not an inline number."""
        (tr, plan, _), _ = pruned_runs
        ce = tr._compiled(use_masks=True)
        assert ce.chunk._cache_size() == expected_programs("local/prune_mask")
        assert expected_programs("local/prune_mask") \
            == len(plan.chunk_lengths())

    def test_masked_artifacts_and_zeroed_params(self, pruned_runs):
        (_, _, res_m), _ = pruned_runs
        art = res_m.artifacts["prune"]
        assert art["mode"] == "mask"
        assert 0.0 <= art["p_star"] <= 0.9
        assert set(art["filter_masks"]) == set(art["kept"])
        for p, m in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_m.state["masks"])):
            np.testing.assert_array_equal(
                np.asarray(p)[np.asarray(m) == 0], 0.0)

    def test_callback_after_masked_prune_keeps_masks(self, tiny_world):
        """A Callback replacing params after a Prune(mode='mask') must not
        discard the masks: the decision stays in force across the state
        rebuild and the replacement params are re-masked."""
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=1, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)
        tr = FederatedTrainer(model, data, cfg)
        cb = lambda trainer, t, params: jax.tree.map(
            lambda p: p + 1.0, params)            # deliberately unmasked
        res = tr.run(TrainPlan(Scan(1), Prune(mode="mask"), Callback(cb),
                               Scan(1), Eval()))
        masked_coords = 0
        for p, m in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(res.state["masks"])):
            np.testing.assert_array_equal(
                np.asarray(p)[np.asarray(m) == 0], 0.0)
            masked_coords += int(np.sum(np.asarray(m) == 0))
        assert masked_coords > 0

    def test_shrink_records_params_before(self, pruned_runs):
        _, (_, _, res_s) = pruned_runs
        before = res_s.artifacts["prune"]["params_before"]
        assert (jax.tree.map(jnp.shape, before)
                != jax.tree.map(jnp.shape, res_s.params))

    def test_shrink_event_reproduces_legacy_hook_path(self, tiny_world):
        """Prune(mode="shrink") must produce exactly what the legacy
        ``on_round_end`` hook protocol produced: per-round chunks, FedAP
        decision on a copy of the params, shrink, momentum restart with the
        round counter preserved."""
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)

        tr = FederatedTrainer(model, data, cfg)
        res = tr.run(fedap_plan(4, prune_round=2, mode="shrink",
                                eval_every=4))

        # legacy emulation: length=1 chunks + host hook after every round
        tr2 = FederatedTrainer(model, data, cfg)
        ce = tr2._compiled()
        data_dev = tr2._device_data()
        params0 = model.init(jax.random.key(cfg.seed))
        init_params = jax.tree.map(jnp.copy, params0)
        state = engine.init_round_state(jax.tree.map(jnp.copy, params0),
                                        ce.eng)
        for t in range(4):
            state, tr2._key, _ = ce.chunk(state, tr2._key, data_dev,
                                          length=1)
            if t + 1 == apcfg.prune_round:
                params = jax.tree.map(jnp.copy, state["params"])
                dec = fedap_decision(model, data, apcfg, params,
                                     init_params=init_params,
                                     rng=np.random.default_rng(cfg.seed))
                spec = model.prune_spec(params)
                round_ = state["round"]
                state = engine.init_round_state(
                    pruning.shrink_params(params, spec, dec.kept), ce.eng)
                state["round"] = round_

        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMaskNowShrinkLater:
    """fedap_plan(..., shrink_round=K): the prune round stays inside the
    compiled scan (mask), and K compacts to the SAME kept filters with the
    momentum buffers compacted, not restarted — so the trajectory equals
    shrink-from-the-start on a norm-free model while the steady-state
    rounds after K train the genuinely smaller model (the ROADMAP's
    warm-path gap)."""

    @pytest.fixture(scope="class")
    def runs(self, tiny_world):
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)

        def run(plan):
            return FederatedTrainer(model, data, cfg).run(plan)

        res_ms = run(fedap_plan(6, prune_round=2, shrink_round=4,
                                eval_every=2))
        res_s = run(fedap_plan(6, prune_round=2, mode="shrink",
                               eval_every=2))
        return res_ms, res_s

    def test_masked_then_shrunk_equals_shrink_from_start(self, runs):
        res_ms, res_s = runs
        kept = res_ms.artifacts["prune"]["kept"]
        assert {k: v.tolist() for k, v in kept.items()} \
            == {k: v.tolist()
                for k, v in res_s.artifacts["prune"]["kept"].items()}
        assert sum(len(v) for v in kept.values()) < 4 + 8 + 8   # real prune
        # compacted shapes from round 4 on — and the same numbers round 6
        assert (jax.tree.map(jnp.shape, res_ms.params)
                == jax.tree.map(jnp.shape, res_s.params))
        for a, b in zip(jax.tree.leaves(res_ms.params),
                        jax.tree.leaves(res_s.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)
        # momentum was COMPACTED at the shrink, not restarted
        for a, b in zip(jax.tree.leaves(res_ms.state["server_m"]),
                        jax.tree.leaves(res_s.state["server_m"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)
        np.testing.assert_allclose(res_ms.history["tau_eff"],
                                   res_s.history["tau_eff"], atol=1e-4)

    def test_shrink_artifact_records_reuse(self, runs):
        res_ms, _ = runs
        art = res_ms.artifacts["shrink"]
        assert art["mode"] == "shrink"
        assert art["reused"] == "prune"
        assert art["p_star"] == res_ms.artifacts["prune"]["p_star"]
        # the artifact has the same summary shape as a decision-backed
        # prune (consumers index kept_counts)
        assert art["kept_counts"] == {k: len(v)
                                      for k, v in art["kept"].items()}
        # one FedAP decision for the whole plan: the shrink carries the
        # mask event's kept indices verbatim
        assert {k: v.tolist() for k, v in art["kept"].items()} \
            == {k: v.tolist()
                for k, v in res_ms.artifacts["prune"]["kept"].items()}

    def test_reuse_resolves_most_recent_decision(self, tiny_world):
        """Two mask prunes then a reuse-shrink: record() files the second
        decision as 'prune#1', and the shrink must compact to THAT one —
        the decision actually in force — not the stale first artifact."""
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=1, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg)
        tr = FederatedTrainer(model, data, cfg)
        res = tr.run(TrainPlan(Scan(1), Prune(mode="mask"), Scan(1),
                               Prune(mode="mask"), Scan(1),
                               Prune(mode="shrink", reuse="prune",
                                     name="shrink"), Scan(1), Eval()))
        live = res.artifacts["prune#1"]["kept"]
        assert {k: v.tolist() for k, v in res.artifacts["shrink"]
                ["kept"].items()} \
            == {k: v.tolist() for k, v in live.items()}
        # the compacted shapes match the in-force decision's kept counts
        from repro.core.pruning import get_path
        spec = model.prune_spec(model.init(jax.random.key(0)))
        for layer in spec.layers:
            w = get_path(res.params, layer.weight)
            assert w.shape[layer.filter_axis] == len(live[layer.name])
        assert np.isfinite(res.history["loss"][-1])

    def test_reuse_without_prior_prune_fails(self, tiny_world):
        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        tr = FederatedTrainer(model, data, cfg)
        with pytest.raises(ValueError, match="reuse"):
            tr.run(TrainPlan(Scan(1),
                             Prune(mode="shrink", reuse="prune")))


class TestPrefetchSampling:
    """Double-buffered in-scan sampling must be a pure scheduling change:
    bit-identical history, params and key chain vs the serial draw."""

    def test_prefetch_bit_exact(self, tiny_world):
        import dataclasses as dc

        data, model = tiny_world
        plan = TrainPlan(Scan(2), Eval(), Scan(3), Eval())
        cfg_pf = feddumap_config(**CFG)
        cfg_serial = dc.replace(cfg_pf, prefetch_sampling=False)
        assert cfg_pf.prefetch_sampling        # the default
        res_pf = FederatedTrainer(model, data, cfg_pf).run(plan)
        res_serial = FederatedTrainer(model, data, cfg_serial).run(plan)
        assert res_pf.history["loss"] == res_serial.history["loss"]
        assert res_pf.history["acc"] == res_serial.history["acc"]
        assert res_pf.history["tau_eff"] == res_serial.history["tau_eff"]
        for a, b in zip(jax.tree.leaves(res_pf.params),
                        jax.tree.leaves(res_serial.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_prefetch_key_chain_identical(self, tiny_world):
        """The chunk consumes exactly one key split per round in BOTH
        modes, so a run split across chunk boundaries stays aligned."""
        import dataclasses as dc

        data, model = tiny_world
        cfg = feddumap_config(**CFG)
        tr_pf = FederatedTrainer(model, data, cfg)
        tr_serial = FederatedTrainer(
            model, data, dc.replace(cfg, prefetch_sampling=False))
        tr_pf.run(TrainPlan(Scan(3)))
        tr_serial.run(TrainPlan(Scan(3)))
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(tr_pf._key)),
            np.asarray(jax.random.key_data(tr_serial._key)))


class TestMaskedComputeKernel:
    """masked_compute="kernel": the engine threads filter masks into the
    model fns (differentiable Pallas masked_matmul under the masked dense
    layers) — and must train EXACTLY like the param-masking engine, which
    in turn equals the re-materializing shrink path on norm-free models."""

    @pytest.fixture(scope="class")
    def three_runs(self, tiny_world):
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=2,
                            min_rate=0.5)

        def run(mode, masked_compute):
            cfg = feddumap_config(**CFG, fedap=apcfg,
                                  masked_compute=masked_compute)
            tr = FederatedTrainer(model, data, cfg)
            plan = fedap_plan(4, prune_round=2, mode=mode, eval_every=2)
            return tr, plan, tr.run(plan)

        return (run("mask", "kernel"), run("mask", "params"),
                run("shrink", "params"))

    def test_kernel_equals_params_equals_shrink(self, tiny_world, three_runs):
        data, model = tiny_world
        (_, _, res_k), (_, _, res_p), (_, _, res_s) = three_runs
        kept = res_k.artifacts["prune"]["kept"]
        assert {k: v.tolist() for k, v in kept.items()} \
            == {k: v.tolist()
                for k, v in res_p.artifacts["prune"]["kept"].items()}
        # the decision pruned for real (min_rate floor bit)
        assert sum(len(v) for v in kept.values()) < 4 + 8 + 8
        for a, b in zip(jax.tree.leaves(res_k.params),
                        jax.tree.leaves(res_p.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        spec = model.prune_spec(res_k.params)
        compacted = pruning.shrink_params(res_k.params, spec, kept)
        for a, b in zip(jax.tree.leaves(compacted),
                        jax.tree.leaves(res_s.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)
        np.testing.assert_allclose(res_k.history["tau_eff"],
                                   res_p.history["tau_eff"], atol=1e-5)

    def test_kernel_mode_carries_filter_masks_without_rejit(self, three_runs):
        (tr, plan, res_k), _, _ = three_runs
        assert set(res_k.state["filter_masks"]) == {"conv1", "conv2", "conv3"}
        for name, fm in res_k.state["filter_masks"].items():
            np.testing.assert_array_equal(
                np.asarray(fm),
                np.asarray(res_k.artifacts["prune"]["filter_masks"][name]))
        # the prune event swapped carry contents only — one chunk program
        # (budgeted in repro/analysis/compile_budget.json)
        ce = tr._compiled(use_masks=True)
        assert ce.chunk._cache_size() \
            == expected_programs("local/prune_mask_kernel")

    def test_shrink_after_mask_in_kernel_mode(self, tiny_world):
        """The ROADMAP's mask-now-shrink-later pattern must run in kernel
        mode: the shrink event rebuilds the carry with all-ones filter
        masks at the SHRUNK shapes instead of crashing on the missing
        filter_masks slot."""
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=1, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg, masked_compute="kernel")
        tr = FederatedTrainer(model, data, cfg)
        res = tr.run(TrainPlan(Scan(1), Prune(mode="mask"), Scan(1),
                               Prune(mode="shrink"), Scan(1), Eval()))
        # compacted shapes after the shrink, all-ones filter masks
        assert (jax.tree.map(jnp.shape, res.params)
                != jax.tree.map(jnp.shape, res.artifacts["prune#1"]
                                ["params_before"]))
        for fm in res.state["filter_masks"].values():
            np.testing.assert_array_equal(np.asarray(fm), 1.0)
        assert np.isfinite(res.history["loss"][-1])

    def test_callback_preserves_filter_masks(self, tiny_world):
        data, model = tiny_world
        apcfg = FedAPConfig(prune_round=1, probe_size=8, participants=2,
                            min_rate=0.5)
        cfg = feddumap_config(**CFG, fedap=apcfg, masked_compute="kernel")
        tr = FederatedTrainer(model, data, cfg)
        cb = lambda trainer, t, params: jax.tree.map(lambda p: p + 1.0,
                                                     params)
        res = tr.run(TrainPlan(Scan(1), Prune(mode="mask"), Callback(cb),
                               Scan(1), Eval()))
        pruned_filters = sum(
            int(np.sum(np.asarray(m) == 0))
            for m in res.state["filter_masks"].values())
        assert pruned_filters > 0


class AlignedMLP:
    """192 -> 128 -> 128(prunable, masked_dense) -> 10 — a model whose
    masked layer IS 128-aligned, so kernel-mode training genuinely routes
    through the Pallas masked_matmul (SimpleCNN's prunable layers are all
    convs: its kernel mode only exercises feature-map masking)."""

    def init(self, rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        d = 8 * 8 * 3
        he = lambda k, s, fi: (jax.random.normal(k, s)
                               * (2.0 / fi) ** 0.5).astype(jnp.float32)
        return {"fc1": {"w": he(k1, (d, 128), d),
                        "b": jnp.zeros((128,), jnp.float32)},
                "fc2": {"w": he(k2, (128, 128), 128),
                        "b": jnp.zeros((128,), jnp.float32)},
                "out": {"w": he(k3, (128, 10), 128),
                        "b": jnp.zeros((10,), jnp.float32)}}

    def apply(self, params, x, *, collect=False, masks=None):
        from repro.models.cnn import masked_dense

        h = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
        fmaps = {"fc1": h}
        if masks is not None and "fc2" in masks:
            h = jax.nn.relu(masked_dense(h, params["fc2"]["w"],
                                         masks["fc2"], params["fc2"]["b"]))
        else:
            h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
        fmaps["fc2"] = h
        logits = h @ params["out"]["w"] + params["out"]["b"]
        return (logits, fmaps) if collect else logits

    def loss_and_acc(self, params, x, y, *, masks=None):
        from repro.models.cnn import softmax_xent_acc

        return softmax_xent_acc(self.apply(params, x, masks=masks), y)

    def feature_maps(self, params, x):
        return self.apply(params, x, collect=True)[1]

    def prune_spec(self, params):
        from repro.core.pruning import (CoupledParam, PrunableLayer,
                                        PruneSpec)

        return PruneSpec(layers=(
            PrunableLayer("fc2", ("fc2", "w"), 1,
                          (CoupledParam(("fc2", "b"), 0),
                           CoupledParam(("out", "w"), 0))),))


class TestKernelPathInsideEngine:
    """The Pallas masked_matmul must actually EXECUTE inside kernel-mode
    engine training (not just in unit tests), and still match params mode."""

    def test_kernel_routes_and_matches_params_mode(self, tiny_world,
                                                   monkeypatch):
        from repro.kernels import ops

        data, _ = tiny_world
        model = AlignedMLP()
        apcfg = FedAPConfig(prune_round=1, probe_size=8, participants=2,
                            min_rate=0.5)

        def run(mc):
            cfg = feddumap_config(**CFG, fedap=apcfg, masked_compute=mc)
            tr = FederatedTrainer(model, data, cfg)
            return tr.run(fedap_plan(3, prune_round=1, mode="mask",
                                     eval_every=3))

        calls = []
        real = ops.masked_matmul

        def spy(*a, **kw):
            calls.append(a[0].shape)
            return real(*a, **kw)

        monkeypatch.setattr(ops, "masked_matmul", spy)
        res_k = run("kernel")
        # the kernel branch was traced into the engine's compiled round —
        # local steps (B=10 -> padded 16) and server steps (B=32)
        assert calls, "masked_matmul never routed inside the engine"
        res_p = run("params")
        kept = res_k.artifacts["prune"]["kept"]["fc2"]
        assert 0 < len(kept) < 128            # the prune bit
        for a, b in zip(jax.tree.leaves(res_k.params),
                        jax.tree.leaves(res_p.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        for p, m in zip(jax.tree.leaves(res_k.params),
                        jax.tree.leaves(res_k.state["masks"])):
            np.testing.assert_array_equal(
                np.asarray(p)[np.asarray(m) == 0], 0.0)


class TestFedAPParticipantsClamp:
    def test_config_validates_at_construction(self):
        with pytest.raises(ValueError, match="participants"):
            FedAPConfig(participants=-1)
        with pytest.raises(ValueError, match="probe_size"):
            FedAPConfig(probe_size=0)

    def test_probe_draw_clamped_to_num_clients(self, tiny_world):
        """participants > num_clients must not crash with an opaque numpy
        error: the draw clamps to every available client, with a warning."""
        data, model = tiny_world
        apcfg = FedAPConfig(probe_size=8, participants=50, min_rate=0.5)
        params = model.init(jax.random.key(0))
        with pytest.warns(UserWarning, match="participants"):
            dec = fedap_decision(model, data, apcfg, params,
                                 init_params=params,
                                 rng=np.random.default_rng(0))
        assert 0.0 <= dec.p_star <= apcfg.max_rate


class TestMaskedModelRouting:
    def test_masked_apply_equals_masked_params(self, tiny_world):
        """Model-level mask routing (feature-map masking + masked_dense) is
        numerically the mask-multiplied parameter tree."""
        data, model = tiny_world
        params = model.init(jax.random.key(1))
        spec = model.prune_spec(params)
        kept = {l.name: np.sort(np.random.default_rng(0).choice(
            pruning.get_path(params, l.weight).shape[l.filter_axis],
            size=3, replace=False)) for l in spec.layers}
        fmask = pruning.filter_masks(params, spec, kept)
        pmask = pruning.param_masks(params, spec, kept)
        x = jnp.asarray(data.server_x[:4])

        via_masks = model.apply(params, x, masks=fmask)
        via_params = model.apply(engine.apply_masks(params, pmask), x)
        np.testing.assert_allclose(np.asarray(via_masks),
                                   np.asarray(via_params), atol=1e-6)

    def test_masked_dense_routes_pallas_when_aligned(self):
        """128-aligned shapes go through the Pallas masked_matmul kernel
        (interpret mode on CPU): fully-pruned column blocks are skipped,
        partially-kept blocks are re-masked elementwise — exact."""
        from repro.models import masked_dense

        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        mask = np.ones((256,), np.float32)
        mask[128:] = 0.0          # second block fully pruned
        mask[7] = 0.0             # first block partially pruned
        b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        out = masked_dense(x, w, jnp.asarray(mask), b)
        ref = (x @ w + b) * mask
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    def test_masked_dense_kernel_branch_taken_for_real_batch(self,
                                                            monkeypatch):
        """Regression: the Pallas branch used to be gated on ``m % block ==
        0``, so realistic batch sizes (10, 32) silently fell back to the
        dense XLA matmul.  The M-padding shim must route B=32 through the
        kernel — and still match the dense reference exactly."""
        from repro.kernels import ops
        from repro.models import masked_dense

        calls = []
        real = ops.masked_matmul

        def spy(x, w, block_mask, **kw):
            calls.append(x.shape)
            return real(x, w, block_mask, **kw)

        monkeypatch.setattr(ops, "masked_matmul", spy)
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        mask = np.ones((256,), np.float32)
        mask[128:] = 0.0
        b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        for batch, padded in [(32, 32), (10, 16)]:
            x = jnp.asarray(rng.standard_normal((batch, 256)), jnp.float32)
            out = masked_dense(x, w, jnp.asarray(mask), b)
            # padded only to the 8-row sublane multiple, not a full
            # 128-row block of wasted work — then sliced back
            assert calls[-1] == (padded, 256)
            assert out.shape == (batch, 256)
            ref = (x @ w + b) * mask
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-4)
        assert len(calls) == 2           # the kernel branch ran both times

    def test_masked_dense_threads_nondefault_block(self):
        """block=64 must thread into ALL of block_m/n/k, not just block_n
        (K=N=192 passes the 64-gate but is not 128-aligned)."""
        from repro.models import masked_dense

        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((10, 192)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((192, 192)), jnp.float32)
        mask = np.ones((192,), np.float32)
        mask[64:128] = 0.0
        out = masked_dense(x, w, jnp.asarray(mask), block=64)
        ref = (x @ w) * mask
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    def test_lenet_masked_fc_fallback(self):
        """LeNet's fc widths are not 128-aligned: masked_dense falls back to
        the XLA path and must still equal the mask-multiplied params."""
        from repro.models import LeNet5

        model = LeNet5(num_classes=10, image_shape=(8, 8, 3))
        params = model.init(jax.random.key(0))
        spec = model.prune_spec(params)
        kept = {"fc1": np.arange(0, 120, 2), "fc2": np.arange(0, 84, 3)}
        spec = type(spec)(layers=tuple(l for l in spec.layers
                                       if l.name in kept))
        fmask = pruning.filter_masks(params, spec, kept)
        pmask = pruning.param_masks(params, spec, kept)
        x = jnp.asarray(np.random.default_rng(1).standard_normal(
            (4, 8, 8, 3)), jnp.float32)
        via_masks = model.apply(params, x, masks=fmask)
        via_params = model.apply(engine.apply_masks(params, pmask), x)
        np.testing.assert_allclose(np.asarray(via_masks),
                                   np.asarray(via_params), atol=1e-5)
