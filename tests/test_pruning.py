"""FedAP (Algorithm 3): rates, threshold, HRank selection, shrink."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pruning import (
    CoupledParam,
    FedAPConfig,
    PrunableLayer,
    PruneSpec,
    aggregate_rates,
    expected_rate_from_spectrum,
    feature_map_ranks,
    filter_masks,
    global_threshold,
    per_layer_rates,
    select_filters,
    shrink_params,
)


class TestEigenGapRule:
    def test_clear_gap_selected(self):
        eigs = jnp.asarray([0.0, 0.1, 0.2, 10.0, 11.0])
        # gap 0.2 -> 10.0 = 9.8 > 4 * 1.0 at index m=3 (ascending)
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(1.0))
        assert float(rate) == pytest.approx(3 / 5)

    def test_first_gap_not_largest_index(self):
        """Paper: 'take the FIRST m_k' — two qualifying gaps, the earlier
        one wins (regression: max-index selection pruned ~90%)."""
        eigs = jnp.asarray([0.0, 0.1, 10.0, 10.1, 10.2, 50.0, 51.0, 52.0])
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(1.0))
        assert float(rate) == pytest.approx(2 / 8)

    def test_no_gap_means_no_pruning(self):
        eigs = jnp.linspace(0.0, 1.0, 10)
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(5.0))
        assert float(rate) == 0.0

    def test_capped_at_max_rate(self):
        eigs = jnp.asarray([0.0] * 9 + [1000.0])
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(0.001), max_rate=0.5)
        assert float(rate) <= 0.5


class TestFormula15:
    def test_low_niid_dominates(self):
        """A participant whose data is near-IID (small D) gets MORE weight."""
        rates = jnp.asarray([0.9, 0.1])
        sizes = jnp.asarray([100.0, 100.0])
        niid = jnp.asarray([1e-6, 1.0])      # first participant near-IID
        out = float(aggregate_rates(rates, sizes, niid))
        assert out > 0.8

    def test_size_weighting(self):
        rates = jnp.asarray([0.9, 0.1])
        sizes = jnp.asarray([1000.0, 1.0])
        niid = jnp.asarray([0.5, 0.5])
        assert float(aggregate_rates(rates, sizes, niid)) > 0.8

    @given(st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3))
    @settings(max_examples=30, deadline=None)
    def test_convex_combination(self, rates):
        sizes = jnp.asarray([10.0, 20.0, 30.0])
        niid = jnp.asarray([0.1, 0.2, 0.3])
        out = float(aggregate_rates(jnp.asarray(rates), sizes, niid))
        assert min(rates) - 1e-6 <= out <= max(rates) + 1e-6


class TestThresholdAndLayerRates:
    def _spec(self):
        return PruneSpec(layers=(
            PrunableLayer("a", ("a", "w"), 1),
            PrunableLayer("b", ("b", "w"), 1),
        ))

    def test_global_threshold_quantile(self):
        params = {"a": {"w": jnp.asarray([[0.1, 0.2, 0.3, 0.4]])},
                  "b": {"w": jnp.asarray([[0.5, 0.6, 0.7, 0.8]])}}
        thr = global_threshold(params, self._spec(), jnp.asarray(0.5))
        assert float(thr) == pytest.approx(0.5)

    def test_per_layer_rates_reflect_magnitudes(self):
        params = {"a": {"w": jnp.asarray([[0.01, 0.02, 0.9, 0.9]])},
                  "b": {"w": jnp.asarray([[0.9, 0.9, 0.9, 0.9]])}}
        rates = per_layer_rates(params, self._spec(), jnp.asarray(0.5))
        assert float(rates["a"]) == pytest.approx(0.5)
        assert float(rates["b"]) == pytest.approx(0.0)


class TestSelection:
    def test_keeps_highest_scores(self):
        scores = np.asarray([5.0, 1.0, 4.0, 2.0, 3.0, 0.0])
        kept = select_filters(scores, 0.5)
        assert set(kept) == {0, 2, 4}

    def test_alignment_prunes_less_never_more(self):
        scores = np.arange(256).astype(float)
        kept = select_filters(scores, 0.3, align=128)
        # 256 * 0.7 = 179.2 -> aligned UP to 256
        assert len(kept) == 256 or len(kept) % 128 == 0
        assert len(kept) >= 256 - int(0.3 * 256)

    @given(st.integers(4, 64), st.floats(0.0, 0.95))
    @settings(max_examples=40, deadline=None)
    def test_min_keep(self, d, rate):
        kept = select_filters(np.random.default_rng(0).random(d), rate)
        assert 1 <= len(kept) <= d


class TestShrink:
    def test_coupled_shapes(self):
        params = {
            "conv": {"w": jnp.zeros((3, 3, 4, 16)), "b": jnp.zeros((16,))},
            "next": {"w": jnp.zeros((3, 3, 16, 8))},
        }
        spec = PruneSpec(layers=(
            PrunableLayer("conv", ("conv", "w"), 3,
                          (CoupledParam(("conv", "b"), 0),
                           CoupledParam(("next", "w"), 2))),
        ))
        kept = {"conv": np.asarray([0, 3, 7, 11])}
        out = shrink_params(params, spec, kept)
        assert out["conv"]["w"].shape == (3, 3, 4, 4)
        assert out["conv"]["b"].shape == (4,)
        assert out["next"]["w"].shape == (3, 3, 4, 8)

    def test_masks_match_kept(self):
        params = {"conv": {"w": jnp.zeros((3, 3, 4, 8))}}
        spec = PruneSpec(layers=(PrunableLayer("conv", ("conv", "w"), 3),))
        masks = filter_masks(params, spec, {"conv": np.asarray([1, 2])})
        np.testing.assert_allclose(masks["conv"], [0, 1, 1, 0, 0, 0, 0, 0])


class TestHRankScores:
    def test_conv_rank_orders_by_information(self):
        rng = np.random.default_rng(0)
        b, hw, d = 4, 8, 3
        rank1 = np.outer(rng.standard_normal(hw), rng.standard_normal(hw))
        full = rng.standard_normal((hw, hw))
        fmap = np.stack([np.zeros((hw, hw)), rank1, full], axis=-1)
        fmap = np.broadcast_to(fmap, (b, hw, hw, d))
        scores = feature_map_ranks(jnp.asarray(fmap))
        assert float(scores[0]) < float(scores[1]) < float(scores[2])

    def test_fc_energy(self):
        fmap = jnp.asarray([[0.0, 1.0, 2.0]] * 5)
        scores = feature_map_ranks(fmap)
        assert float(scores[0]) < float(scores[1]) < float(scores[2])
