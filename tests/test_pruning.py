"""FedAP (Algorithm 3): rates, threshold, HRank selection, shrink."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pruning import (
    CoupledParam,
    FedAPConfig,
    PrunableLayer,
    PruneSpec,
    aggregate_rates,
    expected_rate_from_spectrum,
    feature_map_ranks,
    filter_masks,
    get_path,
    global_threshold,
    param_masks,
    per_layer_rates,
    select_filters,
    set_path,
    shrink_params,
)


class TestEigenGapRule:
    def test_clear_gap_selected(self):
        eigs = jnp.asarray([0.0, 0.1, 0.2, 10.0, 11.0])
        # gap 0.2 -> 10.0 = 9.8 > 4 * 1.0 at index m=3 (ascending)
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(1.0))
        assert float(rate) == pytest.approx(3 / 5)

    def test_first_gap_not_largest_index(self):
        """Paper: 'take the FIRST m_k' — two qualifying gaps, the earlier
        one wins (regression: max-index selection pruned ~90%)."""
        eigs = jnp.asarray([0.0, 0.1, 10.0, 10.1, 10.2, 50.0, 51.0, 52.0])
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(1.0))
        assert float(rate) == pytest.approx(2 / 8)

    def test_no_gap_means_no_pruning(self):
        eigs = jnp.linspace(0.0, 1.0, 10)
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(5.0))
        assert float(rate) == 0.0

    def test_capped_at_max_rate(self):
        eigs = jnp.asarray([0.0] * 9 + [1000.0])
        rate = expected_rate_from_spectrum(eigs, jnp.asarray(0.001), max_rate=0.5)
        assert float(rate) <= 0.5

    @pytest.mark.parametrize("pad", [0, 1, 4])
    def test_padded_spectrum_equals_unpadded(self, pad):
        """The ragged-probe contract: a spectrum padded with leading zero
        eigenvalues (what zeroed per-sample-gradient rows add to the Gram)
        searched with ``valid=d`` must give EXACTLY the unpadded rate —
        including the boundary gap between the last padding zero and the
        smallest valid eigenvalue, which must never qualify."""
        rng = np.random.default_rng(0)
        for lip in (0.01, 0.5, 5.0):
            eigs = jnp.asarray(np.sort(rng.gamma(1.0, 2.0, size=12)))
            base = expected_rate_from_spectrum(eigs, jnp.asarray(lip))
            padded = jnp.concatenate([jnp.zeros((pad,)), eigs])
            got = expected_rate_from_spectrum(padded, jnp.asarray(lip),
                                              valid=eigs.shape[0])
            assert float(got) == pytest.approx(float(base))

    def test_boundary_gap_excluded(self):
        """A huge jump from the padding zeros into the valid spectrum is
        NOT an eigen-gap (the host path has no gap before valid[0])."""
        eigs = jnp.asarray([100.0, 101.0, 102.0, 103.0])  # no internal gap
        base = expected_rate_from_spectrum(eigs, jnp.asarray(1.0))
        padded = jnp.concatenate([jnp.zeros((3,)), eigs])
        got = expected_rate_from_spectrum(padded, jnp.asarray(1.0), valid=4)
        assert float(base) == float(got) == 0.0


class TestFormula15:
    def test_low_niid_dominates(self):
        """A participant whose data is near-IID (small D) gets MORE weight."""
        rates = jnp.asarray([0.9, 0.1])
        sizes = jnp.asarray([100.0, 100.0])
        niid = jnp.asarray([1e-6, 1.0])      # first participant near-IID
        out = float(aggregate_rates(rates, sizes, niid))
        assert out > 0.8

    def test_size_weighting(self):
        rates = jnp.asarray([0.9, 0.1])
        sizes = jnp.asarray([1000.0, 1.0])
        niid = jnp.asarray([0.5, 0.5])
        assert float(aggregate_rates(rates, sizes, niid)) > 0.8

    @given(st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3))
    @settings(max_examples=30, deadline=None)
    def test_convex_combination(self, rates):
        sizes = jnp.asarray([10.0, 20.0, 30.0])
        niid = jnp.asarray([0.1, 0.2, 0.3])
        out = float(aggregate_rates(jnp.asarray(rates), sizes, niid))
        assert min(rates) - 1e-6 <= out <= max(rates) + 1e-6


class TestThresholdAndLayerRates:
    def _spec(self):
        return PruneSpec(layers=(
            PrunableLayer("a", ("a", "w"), 1),
            PrunableLayer("b", ("b", "w"), 1),
        ))

    def test_global_threshold_quantile(self):
        params = {"a": {"w": jnp.asarray([[0.1, 0.2, 0.3, 0.4]])},
                  "b": {"w": jnp.asarray([[0.5, 0.6, 0.7, 0.8]])}}
        thr = global_threshold(params, self._spec(), jnp.asarray(0.5))
        assert float(thr) == pytest.approx(0.5)

    def test_per_layer_rates_reflect_magnitudes(self):
        params = {"a": {"w": jnp.asarray([[0.01, 0.02, 0.9, 0.9]])},
                  "b": {"w": jnp.asarray([[0.9, 0.9, 0.9, 0.9]])}}
        rates = per_layer_rates(params, self._spec(), jnp.asarray(0.5))
        assert float(rates["a"]) == pytest.approx(0.5)
        assert float(rates["b"]) == pytest.approx(0.0)


class TestSelection:
    def test_keeps_highest_scores(self):
        scores = np.asarray([5.0, 1.0, 4.0, 2.0, 3.0, 0.0])
        kept = select_filters(scores, 0.5)
        assert set(kept) == {0, 2, 4}

    def test_alignment_prunes_less_never_more(self):
        scores = np.arange(256).astype(float)
        kept = select_filters(scores, 0.3, align=128)
        # 256 * 0.7 = 179.2 -> aligned UP to 256
        assert len(kept) == 256 or len(kept) % 128 == 0
        assert len(kept) >= 256 - int(0.3 * 256)

    @given(st.integers(4, 64), st.floats(0.0, 0.95))
    @settings(max_examples=40, deadline=None)
    def test_min_keep(self, d, rate):
        kept = select_filters(np.random.default_rng(0).random(d), rate)
        assert 1 <= len(kept) <= d


class TestShrink:
    def test_coupled_shapes(self):
        params = {
            "conv": {"w": jnp.zeros((3, 3, 4, 16)), "b": jnp.zeros((16,))},
            "next": {"w": jnp.zeros((3, 3, 16, 8))},
        }
        spec = PruneSpec(layers=(
            PrunableLayer("conv", ("conv", "w"), 3,
                          (CoupledParam(("conv", "b"), 0),
                           CoupledParam(("next", "w"), 2))),
        ))
        kept = {"conv": np.asarray([0, 3, 7, 11])}
        out = shrink_params(params, spec, kept)
        assert out["conv"]["w"].shape == (3, 3, 4, 4)
        assert out["conv"]["b"].shape == (4,)
        assert out["next"]["w"].shape == (3, 3, 4, 8)

    def test_masks_match_kept(self):
        params = {"conv": {"w": jnp.zeros((3, 3, 4, 8))}}
        spec = PruneSpec(layers=(PrunableLayer("conv", ("conv", "w"), 3),))
        masks = filter_masks(params, spec, {"conv": np.asarray([1, 2])})
        np.testing.assert_allclose(masks["conv"], [0, 1, 1, 0, 0, 0, 0, 0])

    def test_param_masks_zero_exactly_the_shrunk_slices(self):
        params = {
            "conv": {"w": jnp.ones((3, 3, 4, 16)), "b": jnp.ones((16,))},
            "next": {"w": jnp.ones((3, 3, 16, 8))},
        }
        spec = PruneSpec(layers=(
            PrunableLayer("conv", ("conv", "w"), 3,
                          (CoupledParam(("conv", "b"), 0),
                           CoupledParam(("next", "w"), 2))),
        ))
        kept = {"conv": np.asarray([0, 3, 7, 11])}
        masks = param_masks(params, spec, kept)
        keep = np.zeros(16)
        keep[kept["conv"]] = 1.0
        np.testing.assert_allclose(masks["conv"]["b"], keep)
        np.testing.assert_allclose(masks["conv"]["w"],
                                   np.broadcast_to(keep, (3, 3, 4, 16)))
        np.testing.assert_allclose(
            masks["next"]["w"],
            np.broadcast_to(keep[None, None, :, None], (3, 3, 16, 8)))
        # the dual invariant: shrinking the mask-multiplied params drops
        # only ones, shrinking the complement drops only zeros
        masked = jax.tree.map(lambda p, m: p * m, params, masks)
        shrunk = shrink_params(masked, spec, kept)
        assert all(bool(jnp.all(x == 1)) for x in jax.tree.leaves(shrunk))


class TestPathAddressing:
    """get_path/set_path go through jax.tree_util key-paths, so PruneSpec
    works on non-dict pytrees (lists, tuples, namedtuples, registered
    dataclasses) — regression for the dict-only implementation."""

    def test_list_and_tuple_pytrees(self):
        tree = [{"w": jnp.ones((2, 4))}, ({"w": jnp.zeros((4, 3))},)]
        assert get_path(tree, (0, "w")).shape == (2, 4)
        out = set_path(tree, (1, 0, "w"), jnp.ones((4, 3)))
        assert float(jnp.sum(out[1][0]["w"])) == 12.0
        assert float(jnp.sum(tree[1][0]["w"])) == 0.0   # functional update
        assert isinstance(out[1], tuple)                # structure kept

    def test_missing_path_raises(self):
        with pytest.raises(KeyError):
            get_path({"a": {"w": jnp.zeros(2)}}, ("a", "nope"))
        with pytest.raises(KeyError):
            set_path({"a": {"w": jnp.zeros(2)}}, ("b",), jnp.zeros(2))

    def test_shrink_on_dataclass_pytree(self):
        import dataclasses

        @dataclasses.dataclass
        class Block:
            w: object
            b: object

        jax.tree_util.register_dataclass(Block, data_fields=["w", "b"],
                                         meta_fields=[])
        params = [Block(w=jnp.zeros((3, 3, 4, 16)), b=jnp.zeros((16,))),
                  Block(w=jnp.zeros((3, 3, 16, 8)), b=jnp.zeros((8,)))]
        spec = PruneSpec(layers=(
            PrunableLayer("conv", (0, "w"), 3,
                          (CoupledParam((0, "b"), 0),
                           CoupledParam((1, "w"), 2))),
        ))
        kept = {"conv": np.asarray([0, 3, 7, 11])}
        out = shrink_params(params, spec, kept)
        assert out[0].w.shape == (3, 3, 4, 4)
        assert out[0].b.shape == (4,)
        assert out[1].w.shape == (3, 3, 4, 8)
        masks = param_masks(params, spec, kept)
        assert masks[0].w.shape == (3, 3, 4, 16)
        assert float(jnp.sum(masks[0].b)) == 4.0


class TestHRankScores:
    def test_conv_rank_orders_by_information(self):
        rng = np.random.default_rng(0)
        b, hw, d = 4, 8, 3
        rank1 = np.outer(rng.standard_normal(hw), rng.standard_normal(hw))
        full = rng.standard_normal((hw, hw))
        fmap = np.stack([np.zeros((hw, hw)), rank1, full], axis=-1)
        fmap = np.broadcast_to(fmap, (b, hw, hw, d))
        scores = feature_map_ranks(jnp.asarray(fmap))
        assert float(scores[0]) < float(scores[1]) < float(scores[2])

    def test_fc_energy(self):
        fmap = jnp.asarray([[0.0, 1.0, 2.0]] * 5)
        scores = feature_map_ranks(fmap)
        assert float(scores[0]) < float(scores[1]) < float(scores[2])
