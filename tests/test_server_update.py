"""FedDU (Formulas 4-7): tau_eff dynamics + normalized-gradient identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.server_update import (
    FedDUConfig,
    f_prime,
    feddu_apply,
    normalized_server_gradient,
    normalized_server_gradient_scan,
    tau_eff,
)


def _te(cfg=FedDUConfig(), **kw):
    base = dict(acc=0.5, round_idx=0, n0=2000.0, n_prime=4000.0,
                d_round=0.3, d_server=0.01, tau=100)
    base.update(kw)
    return float(tau_eff(cfg, **base))


class TestTauEff:
    def test_decays_geometrically(self):
        cfg = FedDUConfig(decay=0.9)
        vals = [_te(cfg, round_idx=t) for t in range(5)]
        ratios = [vals[i + 1] / vals[i] for i in range(4)]
        np.testing.assert_allclose(ratios, 0.9, rtol=1e-5)

    def test_high_accuracy_shrinks_update(self):
        assert _te(acc=0.9) < _te(acc=0.1)

    def test_iid_server_data_gets_more_steps(self):
        # smaller D(P0) -> larger tau_eff (server data closer to global dist)
        assert _te(d_server=1e-6) > _te(d_server=0.5)

    def test_skewed_round_gets_more_server_help(self):
        # larger D(Pbar'): the selected devices are unrepresentative
        assert _te(d_round=0.6) > _te(d_round=0.05)

    def test_bounded_by_C_decay_tau(self):
        cfg = FedDUConfig(C=1.0, decay=0.99)
        assert _te(cfg, acc=0.0, d_server=0.0, round_idx=0) <= 100.0 + 1e-5

    def test_static_override(self):
        cfg = FedDUConfig(static_tau_eff=7.0)
        assert _te(cfg, acc=0.123, round_idx=9) == pytest.approx(7.0)

    @given(st.floats(0.0, 1.0), st.integers(0, 200))
    @settings(max_examples=40, deadline=None)
    def test_nonnegative(self, acc, t):
        assert _te(acc=acc, round_idx=t) >= 0.0

    def test_f_prime_variants(self):
        assert float(f_prime(0.3, "1-acc")) == pytest.approx(0.7)
        assert float(f_prime(0.5, "inv")) == pytest.approx(2.0, rel=1e-4)


class TestTauEffProperties:
    """Property-based guarantees behind the Section-3.2 convergence claim."""

    @given(st.floats(0.0, 1.0), st.floats(0.01, 0.6), st.integers(0, 100))
    @settings(max_examples=40, deadline=None)
    def test_monotone_decay_in_t(self, acc, d_round, t):
        cfg = FedDUConfig(decay=0.95)
        a = _te(cfg, acc=acc, d_round=d_round, round_idx=t)
        b = _te(cfg, acc=acc, d_round=d_round, round_idx=t + 1)
        assert b <= a + 1e-7
        if a > 0:
            assert b < a          # strictly decaying while non-zero

    @given(st.floats(0.0, 1.0), st.floats(0.9, 0.999))
    @settings(max_examples=40, deadline=None)
    def test_decays_to_fedavg_limit(self, acc, decay):
        """tau_eff -> 0: FedDU provably degrades to plain FedAvg."""
        cfg = FedDUConfig(decay=decay)
        assert _te(cfg, acc=acc, round_idx=20000) < 1e-4
        # and the server correction vanishes with it
        w = {"w": jnp.ones((3,))}
        g = {"w": jnp.full((3,), 5.0)}
        out = feddu_apply(w, g, t_eff=_te(cfg, acc=acc, round_idx=20000),
                          eta=0.1)
        np.testing.assert_allclose(out["w"], w["w"], atol=1e-4)

    @given(st.integers(1, 6), st.floats(-1.0, 1.0))
    @settings(max_examples=30, deadline=None)
    def test_formula6_invariant_to_tau_rescaling(self, mult, gval):
        """FedNova normalization (Formula 6): on a constant gradient field
        the normalized server gradient must NOT depend on tau, so a larger
        server dataset cannot drag the objective toward the server
        distribution (objective inconsistency)."""
        def grad_fn(p, batch):
            return jax.tree.map(lambda x: jnp.full_like(x, gval), p)

        params = {"w": jnp.ones((4,))}
        tau = 3
        short = [jnp.zeros(())] * tau
        long_ = [jnp.zeros(())] * (tau * mult)
        a = normalized_server_gradient(params, short, grad_fn, 0.05)
        b = normalized_server_gradient(params, long_, grad_fn, 0.05)
        np.testing.assert_allclose(a["w"], jnp.full((4,), gval), atol=1e-5)
        np.testing.assert_allclose(a["w"], b["w"], atol=1e-5)


class TestNormalizedGradient:
    def _setup(self):
        def grad_fn(p, batch):
            return jax.tree.map(lambda x: x * 0.1 + batch, p)
        params = {"w": jnp.ones((3,))}
        batches = [jnp.asarray(0.5), jnp.asarray(-0.2), jnp.asarray(0.1)]
        return params, batches, grad_fn

    def test_telescoping_equals_mean_gradient_path(self):
        """(w0 - w_end)/(tau*eta) == mean of per-step gradients (exact for SGD)."""
        params, batches, grad_fn = self._setup()
        eta = 0.01
        g = normalized_server_gradient(params, batches, grad_fn, eta)
        # explicit path
        w = params
        gs = []
        for b in batches:
            gi = grad_fn(w, b)
            gs.append(gi)
            w = jax.tree.map(lambda p, x: p - eta * x, w, gi)
        mean = jax.tree.map(lambda *xs: sum(xs) / len(xs), *gs)
        np.testing.assert_allclose(g["w"], mean["w"], rtol=1e-5)

    def test_scan_variant_matches_loop(self):
        params, batches, grad_fn = self._setup()
        stack = jnp.stack(batches)
        a = normalized_server_gradient(params, batches, grad_fn, 0.05)
        b = normalized_server_gradient_scan(params, stack, grad_fn, 0.05)
        np.testing.assert_allclose(a["w"], b["w"], rtol=1e-5)

    def test_feddu_apply_direction(self):
        w = {"w": jnp.ones((2,))}
        g = {"w": jnp.ones((2,))}
        out = feddu_apply(w, g, t_eff=2.0, eta=0.1)
        np.testing.assert_allclose(out["w"], 1.0 - 0.2, rtol=1e-6)

    def test_zero_tau_eff_is_identity(self):
        w = {"w": jnp.ones((2,))}
        g = {"w": jnp.full((2,), 13.0)}
        out = feddu_apply(w, g, t_eff=0.0, eta=0.1)
        np.testing.assert_allclose(out["w"], w["w"], rtol=1e-6)
