"""§Perf attention variants: tree decomposition, head padding, windows.

Marked ``slow`` (long-sequence attention sweeps dominate the default run) —
deselected from tier-1; execute with ``-m slow`` or ``-m ""``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

import repro.models.layers as L
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models.api import build_model, input_specs

RNG = np.random.default_rng(3)


def _qkv(b, s, h, kv, hd, dtype=jnp.float32):
    return (jnp.asarray(RNG.standard_normal((b, s, h, hd)), dtype),
            jnp.asarray(RNG.standard_normal((b, s, kv, hd)), dtype),
            jnp.asarray(RNG.standard_normal((b, s, kv, hd)), dtype))


class TestTreeAttention:
    @pytest.mark.parametrize("s,leaf", [(2048, 512), (4096, 1024), (1536, 512)])
    def test_matches_blocked(self, s, leaf):
        q, k, v = _qkv(1, s, 4, 2, 64)
        want = L.attention_blocked(q, k, v, causal=True, block_q=512)
        out, _ = L._attention_tree(q, k, v, leaf=leaf)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_lse_matches_logsumexp(self):
        q, k, v = _qkv(1, 256, 2, 2, 32)
        _, lse = L._attention_lse(q, k, v, causal=True, window=None, q_offset=0)
        # direct logsumexp of the causal scores
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k) / np.sqrt(32)
        mask = jnp.tril(jnp.ones((256, 256)))
        scores = jnp.where(mask[None, None] > 0, scores, -jnp.inf)
        want = jax.nn.logsumexp(scores, axis=-1).transpose(0, 2, 1)
        np.testing.assert_allclose(lse, want, atol=1e-4, rtol=1e-4)

    def test_merge_is_softmax_exact(self):
        q, k, v = _qkv(2, 128, 2, 1, 32)
        full = L.attention_ref(q, k, v, causal=False)
        a = L._attention_lse(q, k[:, :48], v[:, :48], causal=False,
                             window=None, q_offset=0)
        b = L._attention_lse(q, k[:, 48:], v[:, 48:], causal=False,
                             window=None, q_offset=0)
        merged, _ = L._merge_partial([a, b])
        np.testing.assert_allclose(merged, full, atol=2e-5, rtol=2e-5)

    def test_dispatch_uses_tree_for_long_causal(self):
        q, k, v = _qkv(1, 4096, 2, 1, 32)
        old = L.ATTN_MODE
        try:
            L.ATTN_MODE = "tree"
            out_tree = L.attention(q, k, v, causal=True)
            L.ATTN_MODE = "blocked"
            out_blk = L.attention(q, k, v, causal=True)
        finally:
            L.ATTN_MODE = old
        np.testing.assert_allclose(out_tree, out_blk, atol=3e-5, rtol=3e-5)


class TestHeadPadding:
    def test_padded_init_shapes(self):
        cfg = get_config("arctic-480b")          # 56 heads, pad to 64
        p, _ = L.init_attention(jax.random.key(0), cfg, jnp.float32)
        assert p["wq"].shape[1] == 64
        assert p["wo"].shape[0] == 64
        # dead heads' output rows are exactly zero
        assert float(jnp.sum(jnp.abs(p["wo"][56:]))) == 0.0

    def test_mha_arch_pads_kv_too(self):
        cfg = get_config("whisper-small")        # 12 MHA heads -> 16/16
        assert cfg.padded_num_heads == 16
        assert cfg.padded_num_kv_heads == 16

    def test_gqa_arch_keeps_kv(self):
        cfg = get_config("arctic-480b")          # kv=8 divides 64
        assert cfg.padded_num_heads == 64
        assert cfg.padded_num_kv_heads == 8

    def test_no_padding_when_divisible(self):
        cfg = get_config("llama3-405b")
        assert cfg.padded_num_heads == cfg.num_heads == 128

    def test_padded_forward_finite_and_head_masked(self):
        """Dead heads must not contribute: zeroing live wo rows zeroes the
        whole attention output."""
        cfg = get_config("qwen2-vl-7b").reduced(pad_heads_to=16)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        batch = input_specs(cfg, InputShape("t", 64, 2, "train"), abstract=False)
        loss = model.loss(params, batch)
        assert bool(jnp.isfinite(loss))
        live = cfg.num_heads
        wo = params["layers"]["attn"]["wo"]
        assert float(jnp.sum(jnp.abs(wo[:, live:]))) == 0.0


class TestWindows:
    def test_window_equals_full_when_large(self):
        q, k, v = _qkv(1, 512, 4, 2, 64)
        a = L.attention_ref(q, k, v, causal=True, window=4096)
        b = L.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_small_window_restricts(self):
        q, k, v = _qkv(1, 256, 2, 2, 32)
        a = L.attention_ref(q, k, v, causal=True, window=1)
        # window=1: each position attends only itself -> output = v
        np.testing.assert_allclose(a, v, atol=1e-5, rtol=1e-5)
