"""Fault-tolerance locks: health guards, checkpoint/resume, fault harness.

Every reliability claim is proven against an oracle rather than asserted
in isolation:

* **Guard == surviving-client oracle.**  A guarded round where an
  injected fault NaNs one selected client's update must match — <= 1e-5,
  per round, on both engine legs — the f64 reference round run WITHOUT
  the fault but with that client dropped (``active=0``): rejection is
  exactly the PR 6 zero-weight dropout semantics, discovered on device.
* **skip_round is a no-op.**  A guarded-faulted round under
  ``guard="skip_round"`` leaves params/momentum BIT-identical to the
  round-start state while the round counter still advances.
* **Kill-and-resume is bit-identical.**  A run killed by the fault
  harness (``KillAfterChunk``) and resumed from its chunk-boundary
  checkpoint produces the SAME history, params and key chain as the
  uninterrupted run — on the local and the mesh backend.
* **Guards add zero programs.**  The guarded scenarios in
  ``compile_budget.json`` budget exactly the guard-off program count,
  and a guarded trainer session is measured against that budget here.
* **Serving stays up.**  Non-finite logits retire ONE slot with
  ``status="error"`` while co-batched requests complete token-for-token
  as in a fault-free session; ``max_queue`` backpressure raises or
  counts-and-drops per config.
"""
import dataclasses
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.compile_budget import expected_programs
from repro.core import (
    Callback,
    Eval,
    FederatedTrainer,
    Scan,
    Snapshot,
    TrainPlan,
    engine,
    feddumap_config,
    ref_engine,
)
from repro.core.backend import PlanExecutor
from repro.core.engine import EngineConfig
from repro.core.plan import CheckpointError, RunResult, load_artifact
from repro.core.ref_engine import SoftmaxRegression
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import SimpleCNN
from repro.models.cnn import softmax_xent_acc
from repro.reliability import (
    CorruptUpdate,
    FaultPlan,
    KillAfterChunk,
    NaNGrad,
    NaNLogits,
    SimulatedCrash,
    latest_checkpoint,
    load_checkpoint,
    plan_from_spec,
    plan_spec,
    save_checkpoint,
)

# ---------------------------------------------------------------------------
# Engine-level world: explicit batches through round_core, like
# tests/test_engine_diff.py — selection is explicit (batch["sel"]), so the
# faulted client is chosen deterministically.
# ---------------------------------------------------------------------------

DIM, CLASSES = 6, 4
CLIENTS, STEPS, BATCH = 3, 2, 5
TAU, SBATCH = 3, 5
ROUNDS = 3
N_TOTAL = 6
SELS = np.asarray([[4, 1, 3], [0, 2, 5], [5, 0, 2]], np.int32)
VICTIM = 2             # client id; slot 1 of round 1's selection
FAULT_ROUND = 1


@pytest.fixture(scope="module")
def eng_world():
    model = SoftmaxRegression(dim=DIM, num_classes=CLASSES)
    rng = np.random.default_rng(42)
    params = model.init(seed=7)

    def batches(lead):
        x = rng.standard_normal(lead + (DIM,)).astype(np.float32)
        y = rng.integers(0, CLASSES, lead).astype(np.int32)
        return x, y

    rounds = []
    for r in range(ROUNDS):
        cx, cy = batches((CLIENTS, STEPS, BATCH))
        sx, sy = batches((TAU, SBATCH))
        rounds.append({
            "client": (cx, cy),
            "sizes": np.asarray([40.0, 25.0, 35.0], np.float32),
            "sel": SELS[r],
            "server": (sx, sy),
            "d_round": np.float32(0.3),
            "d_server": np.float32(0.02),
            "n0": np.float32(500.0),
        })
    return model, params, rounds


def jnp_loss_and_acc(params, b):
    logits = b[0] @ params["w"] + params["b"]
    return softmax_xent_acc(logits, b[1])


def jnp_grad(params, b):
    return jax.grad(lambda p: jnp_loss_and_acc(p, b)[0])(params)


def _scan_history(cfg, state0, rounds):
    """round_core under scan+jit; per-round (params, tau, health)."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[jax.tree.map(jnp.asarray, b) for b in rounds])

    @jax.jit
    def run(state, batches):
        def body(st, b):
            st, met = engine.round_core(cfg, jnp_grad, jnp_loss_and_acc,
                                        st, b)
            return st, (st["params"], met["tau_eff"], met["health"])
        return jax.lax.scan(body, state, batches)

    state, (phist, taus, health) = run(jax.tree.map(jnp.asarray, state0),
                                       stacked)
    return state, phist, np.asarray(taus), np.asarray(health)


def _ref_history(cfg, model, params, rounds):
    ref = ref_engine.ref_init_state(params, cfg, num_clients=N_TOTAL)
    phist, taus, health = [], [], []
    for b in rounds:
        ref, met = ref_engine.ref_round(cfg, model.np_grad,
                                        model.np_loss_and_acc, ref, b)
        phist.append(ref["params"])
        taus.append(met["tau_eff"])
        health.append(met.get("health", 0.0))
    return ref, phist, np.asarray(taus), np.asarray(health)


MODES = {
    "feddu": dict(use_server_update=True, local_momentum="none",
                  server_momentum=False),
    "feddum": dict(use_server_update=True, local_momentum="restart",
                   server_momentum=True),
    "fedda": dict(use_server_update=True, local_momentum="communicated",
                  server_momentum=True),
}
ALGOS = {
    "fedavg": {},
    "feddyn": dict(algorithm="feddyn",
                   feddyn=engine.FedDynConfig(alpha=0.05)),
}
GUARD_TABLE = [("fedavg", "feddu"), ("fedavg", "fedda"),
               ("feddyn", "feddum")]


class TestHealthGuards:
    @pytest.mark.parametrize("algo,mode", GUARD_TABLE,
                             ids=[f"{a}-{m}" for a, m in GUARD_TABLE])
    def test_reject_matches_surviving_client_oracle(self, eng_world, algo,
                                                    mode):
        """THE acceptance lock: a guarded round with one client's update
        NaN'd equals the f64 oracle round run without the fault but with
        that client dropped (active=0) — rejection IS dropout."""
        model, params, rounds = eng_world
        fault = NaNGrad(client=VICTIM, round=FAULT_ROUND)
        cfg = EngineConfig(lr=0.08, lr_decay=0.97, guard="reject_client",
                           faults=(fault,), **ALGOS[algo], **MODES[mode])
        state0 = engine.init_round_state(
            jax.tree.map(jnp.asarray, params), cfg, num_clients=N_TOTAL)
        _, phist, taus, health = _scan_history(cfg, state0, rounds)
        np.testing.assert_array_equal(health, [0.0, 1.0, 0.0])

        # oracle: NO fault, NO guard — the victim simply inactive
        ocfg = dataclasses.replace(cfg, guard="off", faults=())
        oracle_rounds = []
        for r, b in enumerate(rounds):
            b = dict(b)
            b["active"] = np.asarray(
                [0.0 if (r == FAULT_ROUND and c == VICTIM) else 1.0
                 for c in SELS[r]], np.float32)
            oracle_rounds.append(b)
        _, ref_p, ref_taus, _ = _ref_history(ocfg, model, params,
                                             oracle_rounds)
        for r in range(ROUNDS):
            for leaf, ref_leaf in zip(jax.tree.leaves(
                    jax.tree.map(lambda l: l[r], phist)),
                    jax.tree.leaves(ref_p[r])):
                np.testing.assert_allclose(
                    np.asarray(leaf), ref_leaf, atol=1e-5,
                    err_msg=f"{algo}-{mode}: guarded params diverged from "
                            f"the surviving-client oracle at round {r}")
        np.testing.assert_allclose(taus, ref_taus, atol=1e-5)

    @pytest.mark.parametrize("algo,mode", GUARD_TABLE,
                             ids=[f"{a}-{m}" for a, m in GUARD_TABLE])
    def test_ref_engine_mirrors_guard(self, eng_world, algo, mode):
        """The f64 reference engine runs the SAME fault + guard and must
        track the device engine — the mirror every scenario-matrix
        comparison relies on."""
        model, params, rounds = eng_world
        fault = NaNGrad(client=VICTIM, round=FAULT_ROUND)
        cfg = EngineConfig(lr=0.08, lr_decay=0.97, guard="reject_client",
                           faults=(fault,), **ALGOS[algo], **MODES[mode])
        state0 = engine.init_round_state(
            jax.tree.map(jnp.asarray, params), cfg, num_clients=N_TOTAL)
        _, phist, taus, health = _scan_history(cfg, state0, rounds)
        _, ref_p, ref_taus, ref_health = _ref_history(cfg, model, params,
                                                      rounds)
        np.testing.assert_array_equal(health, ref_health)
        for r in range(ROUNDS):
            for leaf, ref_leaf in zip(jax.tree.leaves(
                    jax.tree.map(lambda l: l[r], phist)),
                    jax.tree.leaves(ref_p[r])):
                np.testing.assert_allclose(np.asarray(leaf), ref_leaf,
                                           atol=1e-5)
        np.testing.assert_allclose(taus, ref_taus, atol=1e-5)

    def test_skip_round_is_bitexact_noop(self, eng_world):
        """Under guard='skip_round' ANY rejection discards the whole
        round: params bit-identical to round start, counter advanced,
        tau_eff zeroed, health recording the rejection."""
        model, params, rounds = eng_world
        fault = NaNGrad(client=VICTIM, round=FAULT_ROUND)
        cfg = EngineConfig(lr=0.08, lr_decay=0.97, guard="skip_round",
                           faults=(fault,), use_server_update=True,
                           local_momentum="restart", server_momentum=True)
        state0 = engine.init_round_state(
            jax.tree.map(jnp.asarray, params), cfg, num_clients=N_TOTAL)
        state, phist, taus, health = _scan_history(cfg, state0, rounds)
        np.testing.assert_array_equal(health, [0.0, 1.0, 0.0])
        assert taus[FAULT_ROUND] == 0.0
        for leaf in jax.tree.leaves(phist):
            np.testing.assert_array_equal(
                np.asarray(leaf[FAULT_ROUND]),
                np.asarray(leaf[FAULT_ROUND - 1]),
                err_msg="skipped round moved params")
        assert float(state["round"]) == float(ROUNDS)
        # ... and the run kept training afterwards
        assert any(
            not np.array_equal(np.asarray(l[FAULT_ROUND]),
                               np.asarray(l[FAULT_ROUND + 1]))
            for l in jax.tree.leaves(phist))

    def test_fully_bad_round_discarded(self, eng_world):
        """reject_client with EVERY selected client non-finite: no
        survivors, so the round is a no-op (not a NaN'd model)."""
        model, params, rounds = eng_world
        fault = CorruptUpdate(scale=float("nan"), round=FAULT_ROUND)
        cfg = EngineConfig(lr=0.08, lr_decay=0.97, guard="reject_client",
                           faults=(fault,), use_server_update=True)
        state0 = engine.init_round_state(
            jax.tree.map(jnp.asarray, params), cfg, num_clients=N_TOTAL)
        state, phist, taus, health = _scan_history(cfg, state0, rounds)
        np.testing.assert_array_equal(health, [0.0, float(CLIENTS), 0.0])
        for leaf in jax.tree.leaves(phist):
            assert np.isfinite(np.asarray(leaf)).all()
            np.testing.assert_array_equal(
                np.asarray(leaf[FAULT_ROUND]),
                np.asarray(leaf[FAULT_ROUND - 1]))

    def test_guard_on_no_fault_matches_guard_off(self, eng_world):
        """A guard that never fires must not change training (the guarded
        leg runs the delta-form aggregation, so agreement is numerical,
        not bit-level)."""
        model, params, rounds = eng_world
        base = EngineConfig(lr=0.08, lr_decay=0.97, use_server_update=True,
                            local_momentum="restart", server_momentum=True)
        state0 = engine.init_round_state(
            jax.tree.map(jnp.asarray, params), base, num_clients=N_TOTAL)
        _, p_off, t_off, h_off = _scan_history(base, state0, rounds)
        guarded = dataclasses.replace(base, guard="reject_client")
        _, p_on, t_on, h_on = _scan_history(guarded, state0, rounds)
        np.testing.assert_array_equal(h_off, 0.0)
        np.testing.assert_array_equal(h_on, 0.0)
        for a, b in zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        np.testing.assert_allclose(t_off, t_on, atol=1e-6)


# ---------------------------------------------------------------------------
# Trainer-level world (the tier-1 CNN fixture shape)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_world():
    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=1600, test_size=100, noise_scale=0.5)
    data = build_federated_data(num_clients=6, server_fraction=0.1,
                                device_pool=600, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(4, 8, 8), fc_width=16)
    return data, model


CFG = dict(num_clients=6, clients_per_round=3, local_epochs=1,
           batch_size=10, lr=0.05)
BACKENDS = ("local", "mesh")


def _histories_equal(a, b):
    for k in a:
        if k == "time":     # wall-clock is the one permitted difference
            continue
        assert a[k] == b[k], f"history[{k!r}] diverged"
    assert set(a) == set(b)


class TestKillAndResume:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_resume_is_bit_identical(self, tiny_world, tmp_path, backend):
        """Kill after chunk 2, resume from disk in a FRESH trainer: the
        stitched run equals the uninterrupted run bit-for-bit — params,
        every history column, and the key chain."""
        data, model = tiny_world
        plan_events = (Scan(2), Eval(), Scan(2), Eval(), Scan(2), Eval())
        ckpt = tmp_path / f"ckpt-{backend}"

        base_cfg = feddumap_config(**CFG)
        ref = FederatedTrainer(model, data, base_cfg, backend=backend)
        full = ref.run(TrainPlan(*plan_events))

        kill_cfg = feddumap_config(**CFG, faults=(KillAfterChunk(2),))
        tr = FederatedTrainer(model, data, kill_cfg, backend=backend)
        with pytest.raises(SimulatedCrash):
            tr.run(TrainPlan(*plan_events, checkpoint_dir=ckpt))

        fresh = FederatedTrainer(model, data, base_cfg, backend=backend)
        res = fresh.resume(ckpt)
        _histories_equal(res.history, full.history)
        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(full.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(fresh._key)),
            np.asarray(jax.random.key_data(ref._key)))

    def test_resumed_run_does_not_redie(self, tiny_world, tmp_path):
        """KillAfterChunk counts chunks over the WHOLE run, so resuming
        with the fault still configured must not crash again at the same
        relative position."""
        data, model = tiny_world
        ckpt = tmp_path / "ckpt-redie"
        cfg = feddumap_config(**CFG, faults=(KillAfterChunk(1),))
        tr = FederatedTrainer(model, data, cfg)
        with pytest.raises(SimulatedCrash):
            tr.run(TrainPlan(Scan(1), Scan(1), Eval(),
                             checkpoint_dir=ckpt))
        res = FederatedTrainer(model, data, cfg).resume(ckpt)
        assert res.history["round"] == [2]

    def test_resume_wrong_backend_fails(self, tiny_world, tmp_path):
        data, model = tiny_world
        ckpt = tmp_path / "ckpt-backend"
        cfg = feddumap_config(**CFG, faults=(KillAfterChunk(1),))
        with pytest.raises(SimulatedCrash):
            FederatedTrainer(model, data, cfg).run(
                TrainPlan(Scan(1), Scan(1), checkpoint_dir=ckpt))
        other = FederatedTrainer(model, data, feddumap_config(**CFG),
                                 backend="mesh")
        with pytest.raises(CheckpointError, match="backend"):
            other.resume(ckpt)

    def test_resume_plan_mismatch_fails(self, tiny_world, tmp_path):
        data, model = tiny_world
        ckpt = tmp_path / "ckpt-plan"
        cfg = feddumap_config(**CFG, faults=(KillAfterChunk(1),))
        with pytest.raises(SimulatedCrash):
            FederatedTrainer(model, data, cfg).run(
                TrainPlan(Scan(1), Scan(1), checkpoint_dir=ckpt))
        tr = FederatedTrainer(model, data, feddumap_config(**CFG))
        with pytest.raises(CheckpointError, match="plan"):
            tr.resume(ckpt, plan=TrainPlan(Scan(3), Eval()))

    def test_guarded_trainer_records_health(self, tiny_world):
        """End-to-end: an all-clients NaN round under the real sampler is
        discarded; history['health'] pins which round and how many."""
        data, model = tiny_world
        cfg = feddumap_config(
            **CFG, guard="reject_client",
            faults=(CorruptUpdate(scale=float("nan"), round=1),))
        res = FederatedTrainer(model, data, cfg).run(
            TrainPlan(Scan(3), Eval()))
        assert res.history["health"] == [0.0, 3.0, 0.0]
        for leaf in jax.tree.leaves(res.params):
            assert np.isfinite(np.asarray(leaf)).all()
        res_off = FederatedTrainer(model, data, feddumap_config(**CFG)).run(
            TrainPlan(Scan(3), Eval()))
        assert res_off.history["health"] == [0.0, 0.0, 0.0]


class TestGuardCompileBudget:
    def test_guard_scenarios_budget_zero_extra(self):
        """compile_budget.json is the single source of truth: guard-on
        budgets EQUAL the guard-off scan_eval budget on both backends."""
        for backend in BACKENDS:
            base = expected_programs(f"{backend}/scan_eval")
            for g in ("reject", "skip"):
                assert expected_programs(f"{backend}/guard_{g}") == base

    def test_guarded_session_lowers_budgeted_count(self, tiny_world):
        data, model = tiny_world
        cfg = feddumap_config(**CFG, guard="reject_client")
        tr = FederatedTrainer(model, data, cfg)
        be = tr.backend(use_masks=False)
        executor = PlanExecutor(be, trainer=tr)
        executor.run(TrainPlan(Eval(), Scan(2), Eval(), Scan(2), Eval()),
                     params=model.init(jax.random.key(cfg.seed)),
                     key=jax.random.key(cfg.seed + 1))
        assert (int(be.chunk._cache_size())
                == expected_programs("local/guard_reject"))


# ---------------------------------------------------------------------------
# Checkpoint format + atomicity
# ---------------------------------------------------------------------------

class TestCheckpointStore:
    def _payload(self, cursor):
        return {
            "cursor": cursor,
            "state": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                      "nested": {"b": np.float32(2.5), "n": None}},
            "key_data": np.asarray([0, 7], np.uint32),
            "history": {"acc": [0.1, 0.2], "round": [1, 2]},
            "plan": [{"type": "Scan", "rounds": 2}],
            "meta": ("tuple", 3),
        }

    def test_round_trip_and_latest(self, tmp_path):
        save_checkpoint(tmp_path, self._payload(1))
        p2 = save_checkpoint(tmp_path, self._payload(2))
        assert latest_checkpoint(tmp_path) == pathlib.Path(p2)
        back = load_checkpoint(tmp_path)
        assert back["cursor"] == 2
        np.testing.assert_array_equal(back["state"]["w"],
                                      self._payload(2)["state"]["w"])
        assert back["state"]["nested"]["n"] is None
        assert back["meta"] == ("tuple", 3)       # tuples survive as tuples
        assert back["history"]["acc"] == [0.1, 0.2]
        # atomic writes leave no temp debris
        assert not [p for p in os.listdir(tmp_path) if ".tmp" in p]

    def test_named_errors(self, tmp_path):
        with pytest.raises(CheckpointError, match="no run checkpoint"):
            load_checkpoint(tmp_path / "nowhere")
        step = pathlib.Path(save_checkpoint(tmp_path, self._payload(1)))
        (step / "arrays.npz").unlink()
        with pytest.raises(CheckpointError, match="partial"):
            load_checkpoint(tmp_path)
        # CheckpointError stays a ValueError for legacy handlers
        assert issubclass(CheckpointError, ValueError)

    def test_plan_spec_round_trip(self):
        plan = TrainPlan(Eval(), Scan(2), Snapshot(name="s"), Scan(1),
                         Eval(name="final"))
        spec = plan_spec(plan)
        rebuilt = plan_from_spec(spec, checkpoint_every=1,
                                 checkpoint_dir="d")
        assert plan_spec(rebuilt) == spec
        assert rebuilt.checkpoint_every == 1

    def test_callback_plans_need_the_original(self):
        spec = plan_spec(TrainPlan(Scan(1), Callback(lambda *_: None,
                                                     name="cb")))
        with pytest.raises(CheckpointError, match="Callback"):
            plan_from_spec(spec)

    def test_trainplan_checkpoint_validation(self, tmp_path):
        with pytest.raises(ValueError, match="checkpoint_dir"):
            TrainPlan(Scan(1), checkpoint_every=2)
        with pytest.raises(ValueError, match="checkpoint_every"):
            TrainPlan(Scan(1), checkpoint_every=0,
                      checkpoint_dir=tmp_path)
        p = TrainPlan(Scan(1), checkpoint_dir=tmp_path)
        assert p.checkpoint_every == 1
        q = TrainPlan(Scan(1)).with_checkpointing(tmp_path, every=2)
        assert (q.checkpoint_every, str(q.checkpoint_dir)) == \
            (2, str(tmp_path))
        # equality is over the schedule, not the durability knobs
        assert TrainPlan(Scan(1)) == p

    def test_run_result_save_is_atomic_and_errors_named(self, tmp_path):
        res = RunResult(params={"w": np.ones((2,), np.float32)},
                        state={}, history={}, artifacts={})
        out = tmp_path / "artifact"
        res.save(out)
        assert not [p for p in os.listdir(out) if ".tmp" in p]
        with pytest.raises(CheckpointError, match="meta.json"):
            load_artifact(tmp_path / "empty")
        (out / "arrays.npz").write_bytes(b"garbage")
        with pytest.raises(CheckpointError, match="corrupt"):
            load_artifact(out)


# ---------------------------------------------------------------------------
# Fault-plan plumbing
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_device_host_split_and_hashability(self):
        plan = FaultPlan(NaNGrad(client=0, round=1), KillAfterChunk(2),
                         CorruptUpdate(scale=2.0))
        assert [type(f).__name__ for f in plan.device] == \
            ["NaNGrad", "CorruptUpdate"]
        assert [type(f).__name__ for f in plan.host] == ["KillAfterChunk"]
        hash(plan)                     # rides frozen EngineConfig jit keys
        with pytest.raises(ValueError):
            KillAfterChunk(0)

    def test_config_validation(self):
        from repro.core.rounds import FLConfig

        with pytest.raises(ValueError, match="guard"):
            FLConfig(guard="sometimes")
        with pytest.raises(ValueError, match="fault"):
            FLConfig(faults=("not a fault",))
        with pytest.raises(ValueError, match="guard"):
            EngineConfig(guard="maybe")
        # host faults never reach the engine config
        with pytest.raises(ValueError, match="host"):
            EngineConfig(faults=(KillAfterChunk(1),))


# ---------------------------------------------------------------------------
# Serving: backpressure + error-slot retirement
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_world():
    from repro.configs.base import ModelConfig
    from repro.models.lm import LM

    model = LM(ModelConfig(name="dense-tiny", family="dense", rope="1d",
                           norm="rmsnorm", act="silu",
                           param_dtype="float32", remat="none",
                           num_layers=2, d_model=128, num_heads=4,
                           num_kv_heads=2, d_ff=512, vocab_size=2048))
    return model, model.init(jax.random.key(0))


def _prompts(n, max_prompt=8, vocab=2048, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab,
                         size=int(rng.integers(1, max_prompt + 1)))
            .astype(np.int32) for _ in range(n)]


class TestServingReliability:
    def test_queue_full_raises(self, lm_world):
        from repro.serving import DecodeEngine, QueueFull, ServeConfig

        model, params = lm_world
        eng = DecodeEngine(model, params, ServeConfig(
            slots=2, cache_len=32, max_prompt=8, max_new_tokens=4,
            steps_per_wave=4, max_queue=3))
        ps = _prompts(4)
        for p in ps[:3]:
            assert eng.submit(p) is not None
        with pytest.raises(QueueFull, match="max_queue=3"):
            eng.submit(ps[3])
        assert len(eng.run()) == 3

    def test_queue_full_reject_counts(self, lm_world):
        from repro.serving import DecodeEngine, ServeConfig

        model, params = lm_world
        eng = DecodeEngine(model, params, ServeConfig(
            slots=2, cache_len=32, max_prompt=8, max_new_tokens=4,
            steps_per_wave=4, max_queue=2, on_full="reject"))
        uids = [eng.submit(p) for p in _prompts(5)]
        assert uids[2:] == [None, None, None] and eng.rejected == 3
        done = eng.run()
        assert sorted(c.uid for c in done) == [u for u in uids if u
                                               is not None]
        assert all(c.status == "ok" for c in done)

    def test_serve_config_validation(self):
        from repro.serving import ServeConfig

        with pytest.raises(ValueError, match="max_queue"):
            ServeConfig(max_queue=0)
        with pytest.raises(ValueError, match="on_full"):
            ServeConfig(on_full="drop")

    def test_nan_logits_retire_slot_not_batch(self, lm_world):
        """The serving guard: a slot whose logits go non-finite completes
        with status='error' and frees its slot, while every co-batched
        request emits token-for-token what the fault-free session emits
        — and the session still compiles exactly two programs."""
        from repro.serving import DecodeEngine, ServeConfig

        model, params = lm_world
        cfg = ServeConfig(slots=2, cache_len=32, max_prompt=8,
                          max_new_tokens=4, steps_per_wave=4)
        ps = _prompts(4)
        clean = {c.uid: c for c in DecodeEngine(model, params, cfg).run(ps)}
        assert all(c.status == "ok" for c in clean.values())
        eng = DecodeEngine(model, params, cfg,
                           faults=(NaNLogits(slot=0, n_out=1),))
        faulted = {c.uid: c for c in eng.run(ps)}
        assert set(faulted) == set(clean)
        errs = {u for u, c in faulted.items() if c.status == "error"}
        assert errs, "no slot was retired"
        for u, c in faulted.items():
            if u in errs:      # retired early: a prefix, never garbage
                assert len(c.tokens) <= len(clean[u].tokens)
            else:
                np.testing.assert_array_equal(c.tokens, clean[u].tokens)
        assert eng.program_counts() == {"admit": 1, "wave": 1}
