import os

# Kernel tests run the TPU kernels in interpret mode on CPU.
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
# Keep tests on the single real device (the dry-run sets 512 host devices
# ONLY inside repro.launch.dryrun, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
