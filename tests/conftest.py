import importlib.util
import os
import sys

# The benchmark regression tests import the `benchmarks` namespace package
# from the repo root (tests usually run with only PYTHONPATH=src).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# Kernel tests run the TPU kernels in interpret mode on CPU.
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
# Keep tests on the single real device (the dry-run sets 512 host devices
# ONLY inside repro.launch.dryrun, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The property-based tests import `hypothesis`; the container may not ship
# it (tier-1 must not pip install).  Fall back to the deterministic shim so
# those modules still collect AND run — see tests/_hypothesis_shim.py and
# requirements-dev.txt.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies
