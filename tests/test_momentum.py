"""FedDUM (Formulas 8/11/12): decoupled momentum semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.momentum import (
    FedDUMConfig,
    init_local_momentum,
    init_server_momentum,
    local_sgdm_step,
    server_momentum_step,
    server_pseudo_gradient,
)


class TestLocalMomentum:
    def test_restart_is_zero(self):
        p = {"w": jnp.ones((3,))}
        m = init_local_momentum(p)
        assert float(jnp.sum(jnp.abs(m["w"]))) == 0.0

    def test_damped_form_matches_formula_11(self):
        """m' = b m + (1-b) g ; w' = w - eta m'."""
        p = {"w": jnp.asarray([1.0])}
        m = {"w": jnp.asarray([0.5])}
        g = {"w": jnp.asarray([2.0])}
        p2, m2 = local_sgdm_step(p, m, g, beta=0.9, eta=0.1)
        assert float(m2["w"][0]) == pytest.approx(0.9 * 0.5 + 0.1 * 2.0)
        assert float(p2["w"][0]) == pytest.approx(1.0 - 0.1 * float(m2["w"][0]))


class TestServerMomentum:
    def test_beta0_eta1_reduces_to_feddu(self):
        """With beta=0, eta_s=1 the momentum path must return EXACTLY the
        FedDU proposal — this locks the Formula-12 sign convention (the
        paper's printed '+' must act as descent; see momentum.py)."""
        cfg = FedDUMConfig(beta_server=0.0, eta_server=1.0)
        w_prev = {"w": jnp.asarray([1.0, 2.0])}
        proposed = {"w": jnp.asarray([0.8, 1.9])}   # FedDU output
        m = init_server_momentum(w_prev)
        pseudo = server_pseudo_gradient(w_prev, proposed)
        w_new, _ = server_momentum_step(w_prev, m, pseudo, cfg)
        np.testing.assert_allclose(w_new["w"], proposed["w"], rtol=1e-6)

    def test_momentum_accumulates_across_rounds(self):
        cfg = FedDUMConfig(beta_server=0.9, eta_server=1.0)
        w = {"w": jnp.asarray([1.0])}
        m = init_server_momentum(w)
        # constant improvement direction: proposal always w - 0.1
        for _ in range(3):
            proposed = {"w": w["w"] - 0.1}
            pseudo = server_pseudo_gradient(w, proposed)
            w, m = server_momentum_step(w, m, pseudo, cfg)
        # with beta=0.9 updates are *damped* early: first step = 0.01
        assert float(w["w"][0]) < 1.0
        assert float(m["w"][0]) > 0.0

    def test_pseudo_gradient_sign(self):
        w_prev = {"w": jnp.asarray([1.0])}
        better = {"w": jnp.asarray([0.5])}          # descent direction
        g = server_pseudo_gradient(w_prev, better)
        assert float(g["w"][0]) > 0.0               # positive pseudo-grad => descend


class TestEquivalenceWithCentralized:
    def test_single_client_full_batch_equals_sgdm(self):
        """One client, full participation, E=1, server update off: FedDUM's
        composition must equal centralized SGDM with the server's beta."""
        cfg = FedDUMConfig(beta_server=0.9, eta_server=1.0)
        rng = np.random.default_rng(0)
        w_c = {"w": jnp.asarray(rng.standard_normal(4), jnp.float32)}
        w_f = jax.tree.map(jnp.copy, w_c)
        m_c = init_server_momentum(w_c)
        m_f = init_server_momentum(w_f)
        eta = 0.05

        def grad(w):
            return {"w": w["w"] * 0.3 + 1.0}

        for _ in range(5):
            # centralized damped SGDM with effective step eta
            g = grad(w_c)
            m_c = jax.tree.map(lambda m, gi: 0.9 * m + 0.1 * gi, m_c, g)
            w_c = jax.tree.map(lambda w, m: w - m * eta, w_c, m_c)

            # FedDUM: local E=1 restart-SGDM -> pseudo grad -> server SGDM
            m0 = init_local_momentum(w_f)
            local, _ = local_sgdm_step(w_f, m0, grad(w_f), beta=0.9, eta=eta)
            # with restart, m^{t,1} = (1-b) g, so local moves by eta*(1-b)*g;
            # compensate with 1/(1-b) local lr to match the centralized unit
            local = jax.tree.map(lambda w, l: w + (l - w) / 0.1, w_f, local)
            pseudo = server_pseudo_gradient(w_f, local)
            m_f = jax.tree.map(lambda m, gi: 0.9 * m + 0.1 * gi, m_f, pseudo)
            w_f = jax.tree.map(lambda w, m: (w - m).astype(w.dtype), w_f, m_f)
        np.testing.assert_allclose(w_c["w"], w_f["w"], rtol=1e-4)
