"""MeshBackend parity + sharded-state round-trip tests.

The client-sharded execution backend must be NUMERICALLY the local scan
backend: per-round parity local == mesh == f64 oracle (<= 1e-5), a full
TrainPlan (Scan/Eval/Prune(mode="mask")/Snapshot/Callback) with the FedAP
decision computed POD-SIDE and applied mid-run without re-lowering the
chunk program, and `launch.steps.with_masks` round-tripping a genuinely
sharded SPMD round state with shardings and the compiled program intact.

Multi-device intent: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``mesh-backend`` job does) so the mesh is a real 8-way client axis.  The
tests adapt to the available device count, so under plain tier-1 (one
device) they still execute the mesh code path on a 1-way mesh.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    FedAPConfig,
    FederatedTrainer,
    Callback,
    Eval,
    Prune,
    Scan,
    Snapshot,
    TrainPlan,
    engine,
    ref_engine,
    feddumap_config,
)
from repro.analysis.compile_budget import expected_programs
from repro.core.backend import sim_sample_kw
from repro.core.fedap import fedap_decision, fedap_decision_sharded
from repro.core.ref_engine import SoftmaxRegression
from repro.core.rounds import engine_config
from repro.data import build_federated_data
from repro.data.pipeline import FederatedData
from repro.data.synthetic import SyntheticSpec
from repro.launch.mesh import make_host_mesh
from repro.models import SimpleCNN
from repro.models.cnn import softmax_xent_acc


N_DEV = len(jax.devices())


def host_mesh():
    """The mesh the backend would build: every local device on the client
    ('data') axis — 8-way under the CI mesh-backend job's XLA_FLAGS."""
    return make_host_mesh(model=1)


# ---------------------------------------------------------------------------
# Per-round parity: mesh == local == f64 oracle through the FULL path
# (device-side sampling included), on the closed-form softmax toy
# ---------------------------------------------------------------------------

DIM, CLASSES = 6, 4
N_CLIENTS, N_K = 8, 20
# 16 server rows / server_batch 8: the per-step server batch dim divides
# the CI job's 8-way client axis, so the FedDU server scan GENUINELY
# shards in these parity tests; 12 test rows do NOT divide 8, so the
# sharded eval's pad-and-correct path is exercised against the oracle too
N_SERVER, N_TEST = 16, 12
ROUNDS = 4


class OracleSoftmaxModel:
    """Trainer-interface adapter around the oracle's SoftmaxRegression:
    jnp loss for the engine, closed-form NumPy grads for ref_engine."""

    def __init__(self):
        self._np = SoftmaxRegression(dim=DIM, num_classes=CLASSES)

    def init(self, rng):
        return jax.tree.map(jnp.asarray, self._np.init(seed=7))

    def loss_and_acc(self, params, x, y):
        return softmax_xent_acc(x @ params["w"] + params["b"], y)

    def np_init(self):
        return self._np.init(seed=7)

    def np_grad(self, params, batch):
        return self._np.np_grad(params, batch)

    def np_loss_and_acc(self, params, batch):
        return self._np.np_loss_and_acc(params, batch)


@pytest.fixture(scope="module")
def softmax_world():
    rng = np.random.default_rng(11)
    x = lambda *lead: rng.standard_normal(lead + (DIM,)).astype(np.float32)
    y = lambda *lead: rng.integers(0, CLASSES, lead).astype(np.int64)
    dists = np.full((N_CLIENTS, CLASSES), 1.0 / CLASSES, np.float32)
    data = FederatedData(
        client_x=x(N_CLIENTS, N_K), client_y=y(N_CLIENTS, N_K),
        sizes=np.full(N_CLIENTS, float(N_K), np.float32),
        client_dists=dists,
        server_x=x(N_SERVER), server_y=y(N_SERVER),
        server_dist=np.full((CLASSES,), 1.0 / CLASSES, np.float32),
        test_x=x(N_TEST), test_y=y(N_TEST))
    cfg = feddumap_config(
        num_clients=N_CLIENTS, clients_per_round=N_CLIENTS, local_epochs=1,
        batch_size=5, lr=0.08, lr_decay=0.97, server_batch_size=8)
    return data, OracleSoftmaxModel(), cfg


def per_round_plan(rounds):
    return TrainPlan([e for _ in range(rounds) for e in (Scan(1), Eval())])


def oracle_run(data, model, cfg, rounds):
    """The f64 oracle driven by the SAME device-side sampling key chain the
    backends consume (one split per round)."""
    eng = engine_config(cfg)
    data_dev = data.device_arrays()
    kw = sim_sample_kw(cfg, data)
    key = jax.random.key(cfg.seed)
    state = ref_engine.ref_init_state(model.np_init(), eng,
                                      num_clients=data.client_x.shape[0])
    hist = {"loss": [], "acc": [], "tau_eff": []}
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        batch = jax.tree.map(np.asarray,
                             engine.sample_round_batches(sub, data_dev, **kw))
        state, metrics = ref_engine.ref_round(
            eng, model.np_grad, model.np_loss_and_acc, state, batch)
        loss, acc = model.np_loss_and_acc(state["params"],
                                          (data.test_x, data.test_y))
        hist["loss"].append(loss)
        hist["acc"].append(acc)
        hist["tau_eff"].append(metrics["tau_eff"])
    return state, hist


class TestMeshOracleParity:
    def test_mesh_equals_local_equals_oracle_per_round(self, softmax_world):
        data, model, cfg = softmax_world
        plan = per_round_plan(ROUNDS)
        res_l = FederatedTrainer(model, data, cfg).run(plan)
        res_m = FederatedTrainer(model, data, cfg, backend="mesh").run(plan)
        ref_state, ref_hist = oracle_run(data, model, cfg, ROUNDS)

        for res, tag in ((res_l, "local"), (res_m, "mesh")):
            np.testing.assert_allclose(res.history["loss"], ref_hist["loss"],
                                       atol=1e-5, err_msg=f"{tag} vs oracle")
            np.testing.assert_allclose(res.history["acc"], ref_hist["acc"],
                                       atol=1e-5, err_msg=f"{tag} vs oracle")
            np.testing.assert_allclose(res.history["tau_eff"],
                                       ref_hist["tau_eff"], atol=1e-5)
            for leaf, ref_leaf in zip(jax.tree.leaves(res.params),
                                      jax.tree.leaves(ref_state["params"])):
                np.testing.assert_allclose(np.asarray(leaf), ref_leaf,
                                           atol=1e-5, err_msg=tag)
        # mesh vs local directly (tighter than through the oracle)
        np.testing.assert_allclose(res_m.history["loss"],
                                   res_l.history["loss"], atol=1e-6)
        for a, b in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_l.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_momentum_state_tracks_oracle(self, softmax_world):
        data, model, cfg = softmax_world
        res_m = FederatedTrainer(model, data, cfg,
                                 backend="mesh").run(per_round_plan(ROUNDS))
        ref_state, _ = oracle_run(data, model, cfg, ROUNDS)
        for leaf, ref_leaf in zip(jax.tree.leaves(res_m.state["server_m"]),
                                  jax.tree.leaves(ref_state["server_m"])):
            np.testing.assert_allclose(np.asarray(leaf), ref_leaf, atol=1e-5)

    @pytest.mark.parametrize("local_m,server_m",
                             [("none", False), ("communicated", True)])
    def test_all_momentum_modes_sharded_server_scan(self, softmax_world,
                                                    local_m, server_m):
        """mesh == local == f64 oracle per round with the batch-sharded
        FedDU server scan (and sharded eval) enabled, for the momentum
        modes the module fixture (restart + server momentum) does not
        cover.  tau_eff rides on the first-step server_acc gate, so its
        parity transitively checks the sharded first server forward."""
        data, model, cfg = softmax_world
        cfg = dataclasses.replace(cfg, local_momentum=local_m,
                                  server_momentum=server_m)
        rounds = 3
        plan = per_round_plan(rounds)
        res_l = FederatedTrainer(model, data, cfg).run(plan)
        res_m = FederatedTrainer(model, data, cfg, backend="mesh").run(plan)
        ref_state, ref_hist = oracle_run(data, model, cfg, rounds)
        for res, tag in ((res_l, "local"), (res_m, "mesh")):
            np.testing.assert_allclose(res.history["loss"], ref_hist["loss"],
                                       atol=1e-5, err_msg=tag)
            np.testing.assert_allclose(res.history["tau_eff"],
                                       ref_hist["tau_eff"], atol=1e-5,
                                       err_msg=tag)
        for a, b in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(ref_state["params"])):
            np.testing.assert_allclose(np.asarray(a), b, atol=1e-5)
        if local_m == "communicated":
            for a, b in zip(jax.tree.leaves(res_m.state["global_m"]),
                            jax.tree.leaves(ref_state["global_m"])):
                np.testing.assert_allclose(np.asarray(a), b, atol=1e-5)

    @pytest.mark.parametrize("algo,overrides", [
        ("fedprox", dict(algorithm="fedprox",
                         fedprox=engine.FedProxConfig(mu=0.05))),
        ("feddyn", dict(algorithm="feddyn",
                        feddyn=engine.FedDynConfig(alpha=0.05))),
    ])
    def test_client_state_algorithms_mesh_equals_oracle(self, softmax_world,
                                                        algo, overrides):
        """FedProx/FedDyn through the FULL trainer path: the client_state
        slot rides the mesh carry (per-client FedDyn corrections sharded
        over the 8-way client axis in CI) and both backends must track the
        f64 oracle per round."""
        data, model, cfg = softmax_world
        cfg = dataclasses.replace(cfg, **overrides)
        rounds = 3
        plan = per_round_plan(rounds)
        res_l = FederatedTrainer(model, data, cfg).run(plan)
        res_m = FederatedTrainer(model, data, cfg, backend="mesh").run(plan)
        ref_state, ref_hist = oracle_run(data, model, cfg, rounds)
        for res, tag in ((res_l, "local"), (res_m, "mesh")):
            np.testing.assert_allclose(res.history["loss"], ref_hist["loss"],
                                       atol=1e-5, err_msg=f"{algo} {tag}")
            for a, b in zip(jax.tree.leaves(res.params),
                            jax.tree.leaves(ref_state["params"])):
                np.testing.assert_allclose(np.asarray(a), b, atol=1e-5,
                                           err_msg=f"{algo} {tag}")
        if algo == "feddyn":
            # the [N, ...] correction state itself must track the oracle —
            # on the mesh it lived sharded over the client axis all run
            for a, b in zip(jax.tree.leaves(res_m.state["client_state"]),
                            jax.tree.leaves(ref_state["client_state"])):
                np.testing.assert_allclose(np.asarray(a), b, atol=1e-5,
                                           err_msg="feddyn client_state")

    def test_straggler_dropout_mesh_equals_local_equals_oracle(
            self, softmax_world):
        """dropout_rate > 0: dropped clients contribute ZERO aggregation
        weight (delta form) on every backend, and the shared key chain
        keeps local == mesh == oracle sampling identical."""
        data, model, cfg = softmax_world
        cfg = dataclasses.replace(cfg, dropout_rate=0.4)
        rounds = 3
        plan = per_round_plan(rounds)
        res_l = FederatedTrainer(model, data, cfg).run(plan)
        res_m = FederatedTrainer(model, data, cfg, backend="mesh").run(plan)
        ref_state, ref_hist = oracle_run(data, model, cfg, rounds)
        for res, tag in ((res_l, "local"), (res_m, "mesh")):
            np.testing.assert_allclose(res.history["loss"], ref_hist["loss"],
                                       atol=1e-5, err_msg=tag)
            for a, b in zip(jax.tree.leaves(res.params),
                            jax.tree.leaves(ref_state["params"])):
                np.testing.assert_allclose(np.asarray(a), b, atol=1e-5,
                                           err_msg=tag)
        for a, b in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_l.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


# ---------------------------------------------------------------------------
# Full TrainPlan on the mesh: Scan/Eval/Prune(mask)/Snapshot/Callback with a
# pod-side FedAP decision applied mid-run, no re-lower
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cnn_world():
    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=1700, test_size=100, noise_scale=0.5)
    data = build_federated_data(num_clients=8, server_fraction=0.1,
                                device_pool=640, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(4, 8, 8), fc_width=16)
    # participants=7 (+1 server) = 8 probe sets — divisible over the CI
    # job's 8-way client axis, so the pod-side decision genuinely shards
    apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=7,
                        min_rate=0.5)
    cfg = feddumap_config(num_clients=8, clients_per_round=8, local_epochs=1,
                          batch_size=10, lr=0.05, fedap=apcfg)
    return data, model, cfg


FULL_PLAN = TrainPlan(Eval(), Scan(2), Eval(), Prune(mode="mask"),
                      Snapshot(), Scan(2), Eval())


class TestMeshFullPlan:
    @pytest.fixture(scope="class")
    def runs(self, cnn_world):
        data, model, cfg = cnn_world
        tr_m = FederatedTrainer(model, data, cfg, backend="mesh")
        res_m = tr_m.run(FULL_PLAN)
        res_l = FederatedTrainer(model, data, cfg).run(FULL_PLAN)
        return tr_m, res_m, res_l

    def test_per_round_parity_and_pod_side_decision(self, runs):
        _, res_m, res_l = runs
        np.testing.assert_allclose(res_m.history["loss"],
                                   res_l.history["loss"], atol=1e-5)
        np.testing.assert_allclose(res_m.history["acc"],
                                   res_l.history["acc"], atol=1e-5)
        np.testing.assert_allclose(res_m.history["tau_eff"],
                                   res_l.history["tau_eff"], atol=1e-5)
        # the sharded (pod-side) decision picked the same filters as the
        # host decision on the local path
        kept_m = res_m.artifacts["prune"]["kept"]
        kept_l = res_l.artifacts["prune"]["kept"]
        assert {k: v.tolist() for k, v in kept_m.items()} \
            == {k: v.tolist() for k, v in kept_l.items()}
        assert sum(len(v) for v in kept_m.values()) < 4 + 8 + 8  # real prune
        for a, b in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_l.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        # masked coordinates are exactly zero through the post-prune rounds
        for p, m in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_m.state["masks"])):
            np.testing.assert_array_equal(np.asarray(p)[np.asarray(m) == 0],
                                          0.0)

    def test_prune_applied_without_relowering(self, runs):
        """ONE chunk trace covers the whole plan: the mid-run mask
        injection (steps.with_masks) must not re-lower the mesh program."""
        tr_m, res_m, _ = runs
        be = tr_m.backend(use_masks=True)
        # budgeted in repro/analysis/compile_budget.json: the mask-mode
        # prune adds ZERO mesh programs
        assert be.chunk._cache_size() == expected_programs("mesh/prune_mask")
        assert expected_programs("mesh/prune_mask") \
            == len(FULL_PLAN.chunk_lengths())

    def test_state_and_data_shardings(self, runs):
        tr_m, res_m, _ = runs
        be = tr_m.backend(use_masks=True)
        mesh = be.mesh
        # global state replicated over the mesh
        for leaf in jax.tree.leaves(res_m.state["params"]):
            assert leaf.sharding == NamedSharding(mesh, P())
        # per-client data sharded over the client axis (divisible: 8 clients)
        d = be.device_data()
        if N_CLIENTS % mesh.shape["data"] == 0 and mesh.shape["data"] > 1:
            assert d["client_x"].sharding.spec == P("data")
        # server POOL replicated (per-step server batches are sharded
        # in-scan instead); TEST split padded to the axis size and sharded
        # on its batch dim — eval is no longer a replicated full-test pass
        assert d["server_x"].sharding == NamedSharding(mesh, P())
        size = mesh.shape["data"]
        n_test = be.data.test_x.shape[0]
        assert d["test_x"].shape[0] == n_test + (-n_test % size)
        if size > 1:
            assert d["test_x"].sharding.spec == P("data")
            assert d["test_y"].sharding.spec == P("data")

    def test_snapshot_and_callback_round_indices(self, cnn_world):
        data, model, cfg = cnn_world
        seen = []
        cb = lambda trainer, t, params: seen.append(t)
        plan = TrainPlan(Scan(2), Callback(cb), Scan(1), Snapshot(),
                         Callback(cb), Eval())
        res = FederatedTrainer(model, data, cfg, backend="mesh").run(plan)
        assert seen == [2, 3]                     # true completed rounds
        assert res.artifacts["snapshot"]["round"] == 3
        assert res.history["round"] == [3]


class TestShardedDecisionMatchesHost:
    def test_sharded_rates_close_to_host(self, cnn_world):
        """Step 1 pod-side vs host-side: the eigen-gap rate is a DISCRETE
        index search, so float noise between the sequential eager path and
        the vmapped sharded program may flip single indices — the aggregate
        rate must agree to within one flipped index per participant
        (1/probe_size after the Formula-15 weighting)."""
        data, model, cfg = cnn_world
        params = model.init(jax.random.key(3))
        kw = dict(init_params=model.init(jax.random.key(0)))
        host = fedap_decision(model, data, cfg.fedap, params,
                              rng=np.random.default_rng(5), **kw)
        pod = fedap_decision_sharded(model, data, cfg.fedap, params,
                                     rng=np.random.default_rng(5),
                                     mesh=host_mesh(), client_axes=("data",),
                                     **kw)
        assert abs(host.p_star - pod.p_star) <= 1.0 / cfg.fedap.probe_size

    def test_sharded_equals_host_at_compression_floor(self, cnn_world):
        """With the compression-budget floor binding (the production FedAP
        configuration), steps 2-4 see the identical clipped p*, so the two
        entry points must pick EXACTLY the same filters."""
        data, model, cfg = cnn_world
        apcfg = dataclasses.replace(cfg.fedap, min_rate=0.7)
        params = model.init(jax.random.key(3))
        kw = dict(init_params=model.init(jax.random.key(0)))
        host = fedap_decision(model, data, apcfg, params,
                              rng=np.random.default_rng(5), **kw)
        pod = fedap_decision_sharded(model, data, apcfg, params,
                                     rng=np.random.default_rng(5),
                                     mesh=host_mesh(), client_axes=("data",),
                                     **kw)
        assert host.p_star == pytest.approx(pod.p_star, abs=1e-6)
        assert host.layer_rates == pytest.approx(pod.layer_rates, abs=1e-6)
        assert {k: v.tolist() for k, v in host.kept.items()} \
            == {k: v.tolist() for k, v in pod.kept.items()}

    def test_ragged_probe_equals_host(self, cnn_world):
        """Ragged probe sets (server pool smaller than the requested probe,
        clients larger): the sharded path pads the stacked probe to
        rectangular and masks the padded rows out of the Fisher/Lipschitz
        statistics, so each participant's rate is computed over exactly
        the samples the host path probes.  With the compression floor
        binding the two entry points pick IDENTICAL filters (the same
        contract the rectangular floor test locks)."""
        data, model, cfg = cnn_world
        n0, n_k = data.server_x.shape[0], data.client_x.shape[1]
        probe = n_k - 4          # > n0 (=64) but <= n_k (=80): truly ragged
        assert n0 < probe <= n_k
        apcfg = dataclasses.replace(cfg.fedap, probe_size=probe,
                                    min_rate=0.7)
        params = model.init(jax.random.key(3))
        kw = dict(init_params=model.init(jax.random.key(0)))
        host = fedap_decision(model, data, apcfg, params,
                              rng=np.random.default_rng(5), **kw)
        pod = fedap_decision_sharded(model, data, apcfg, params,
                                     rng=np.random.default_rng(5),
                                     mesh=host_mesh(), client_axes=("data",),
                                     **kw)
        assert host.p_star == pytest.approx(pod.p_star, abs=1e-6)
        assert host.layer_rates == pytest.approx(pod.layer_rates, abs=1e-6)
        assert {k: v.tolist() for k, v in host.kept.items()} \
            == {k: v.tolist() for k, v in pod.kept.items()}

    def test_ragged_probe_rates_close_to_host(self, cnn_world):
        """Off the floor, the padded/masked step-1 statistics must stay
        within the discrete eigen-index tolerance of the host path (same
        contract as the rectangular closeness test)."""
        data, model, cfg = cnn_world
        probe = data.client_x.shape[1] - 4
        apcfg = dataclasses.replace(cfg.fedap, probe_size=probe)
        params = model.init(jax.random.key(3))
        kw = dict(init_params=model.init(jax.random.key(0)))
        host = fedap_decision(model, data, apcfg, params,
                              rng=np.random.default_rng(5), **kw)
        pod = fedap_decision_sharded(model, data, apcfg, params,
                                     rng=np.random.default_rng(5),
                                     mesh=host_mesh(), client_axes=("data",),
                                     **kw)
        # one flipped eigen index per participant at most, over the
        # SMALLEST actual probe (the server's n0 rows)
        assert abs(host.p_star - pod.p_star) <= 1.0 / data.server_x.shape[0]


# ---------------------------------------------------------------------------
# Batch-sharded evaluation: sharded eval == replicated eval on the same
# params (pad-and-correct path included), built without lowering the chunk
# ---------------------------------------------------------------------------

class TestShardedEval:
    def test_sharded_eval_equals_replicated(self, cnn_world):
        """The sharded eval program — test batch padded (100 -> 104 on the
        8-way axis) and sharded over the mesh — must score the SAME params
        like the replicated full-test pass, the padded rows corrected out
        exactly (up to f32 association)."""
        from repro.core.backend import MeshBackend

        data, model, cfg = cnn_world
        mesh = host_mesh()
        be_s = MeshBackend(model, data, cfg, mesh=mesh)
        be_r = MeshBackend(model, data, cfg, mesh=mesh, shard_eval=False,
                           shard_server=False)
        state = be_s.init_state(model.init(jax.random.key(1)))
        loss_s, acc_s = be_s.evaluate(state)
        loss_r, acc_r = be_r.evaluate(state)
        np.testing.assert_allclose(float(loss_s), float(loss_r), atol=1e-6)
        np.testing.assert_allclose(float(acc_s), float(acc_r), atol=1e-6)

    def test_evaluate_does_not_lower_chunk(self, cnn_world):
        """`evaluate` on a FRESH backend must not pay the full chunk
        lowering — eval-program construction is factored out of
        `_programs` (the `self._programs()`-for-side-effect satellite)."""
        from repro.core.backend import MeshBackend

        data, model, cfg = cnn_world
        be = MeshBackend(model, data, cfg, mesh=host_mesh())
        state = be.init_state(model.init(jax.random.key(1)))
        loss, acc = be.evaluate(state)
        assert np.isfinite(float(loss)) and np.isfinite(float(acc))
        assert be._chunk is None, \
            "evaluate() lowered the chunk program as a side effect"


# ---------------------------------------------------------------------------
# Shard-local shrink compaction: no host round-trip, values == host shrink
# (params AND momentum), outputs mesh-committed NamedShardings
# ---------------------------------------------------------------------------

class TestShardedShrink:
    @pytest.fixture()
    def masked_state(self, cnn_world):
        """A mesh round state two rounds in with a mask decision applied —
        the state a reuse-shrink compacts."""
        data, model, cfg = cnn_world
        tr = FederatedTrainer(model, data, cfg, backend="mesh")
        res = tr.run(TrainPlan(Scan(2), Prune(mode="mask")))
        be = tr.backend(use_masks=True)
        return be, res.state, res.artifacts["prune"]["kept"]

    def test_sharded_shrink_matches_host_and_stays_on_mesh(self,
                                                           masked_state):
        from repro.core import backend as backend_mod

        be, state, kept = masked_state
        # the host (base-class) path on the same state — the "before"
        host_state, host_extra = backend_mod._EngineBackend.apply_prune(
            be, state, "shrink", kept, compact_existing=True)

        # the sharded path may not re-place any STATE array via
        # jax.device_put (the compaction is one jitted program whose
        # out_shardings pin the mesh placement); the only device_put
        # traffic allowed is the trace-time conversion of the tiny static
        # kept-INDEX constants
        calls = []
        orig = jax.device_put
        jax.device_put = lambda x, *a, **k: calls.append(x) or orig(x, *a, **k)
        try:
            new_state, extra = be.apply_prune(state, "shrink", kept,
                                              compact_existing=True)
        finally:
            jax.device_put = orig
        for x in calls:
            assert np.issubdtype(np.asarray(x).dtype, np.integer) \
                and np.asarray(x).ndim <= 1, \
                f"sharded shrink re-placed a state array via device_put: " \
                f"{np.asarray(x).dtype} {np.asarray(x).shape}"

        # params AND momentum leaf-equal to the host shrink (pure gathers
        # of identical inputs -> exact), round preserved
        for (p1, l1), (p2, l2) in zip(
                jax.tree_util.tree_leaves_with_path(host_state),
                jax.tree_util.tree_leaves_with_path(new_state)):
            assert p1 == p2
            assert l1.shape == l2.shape, p1
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2),
                                          err_msg=str(p1))
        # every leaf is a mesh-committed NamedSharding output of the jitted
        # compaction — the acceptance-criterion placement assertion
        for path, leaf in jax.tree_util.tree_leaves_with_path(new_state):
            assert isinstance(leaf.sharding, NamedSharding), path
            assert leaf.sharding.mesh == be.mesh, path
        # artifact contract unchanged
        assert set(extra) == set(host_extra) == {"params_before"}

    def test_mask_then_shrink_plan_parity(self, cnn_world):
        """Full executor path: Scan/Prune(mask)/Scan/Prune(shrink,
        reuse)/Scan/Eval on the mesh == local, params and compacted
        momentum within 1e-5; one chunk program per shape (the shrink's
        re-trace is the shape change, nothing else re-lowers)."""
        data, model, cfg = cnn_world
        plan = TrainPlan(Scan(2), Prune(mode="mask"), Scan(2),
                         Prune(mode="shrink", reuse="prune", name="shrink"),
                         Scan(2), Eval())
        tr_m = FederatedTrainer(model, data, cfg, backend="mesh")
        res_m = tr_m.run(plan)
        res_l = FederatedTrainer(model, data, cfg).run(plan)
        np.testing.assert_allclose(res_m.history["loss"],
                                   res_l.history["loss"], atol=1e-5)
        np.testing.assert_allclose(res_m.history["acc"],
                                   res_l.history["acc"], atol=1e-5)
        for a, b in zip(jax.tree.leaves(res_m.params),
                        jax.tree.leaves(res_l.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        for a, b in zip(jax.tree.leaves(res_m.state["server_m"]),
                        jax.tree.leaves(res_l.state["server_m"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        be = tr_m.backend(use_masks=True)
        # pre-shrink + post-shrink, budgeted in compile_budget.json
        assert be.chunk._cache_size() \
            == expected_programs("mesh/mask_then_shrink")


# ---------------------------------------------------------------------------
# with_masks on a GENUINELY sharded SPMD round state: shardings and the
# compiled program survive the injection (satellite: sharded round-trip)
# ---------------------------------------------------------------------------

class ShardedDictModel:
    """Pod-interface toy whose hidden dim shards over the 'model' axis."""

    D_IN, D_H, D_OUT = 6, 2 * max(1, N_DEV), 4

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": jax.random.normal(k1, (self.D_IN, self.D_H)) * 0.3,
                "w2": jax.random.normal(k2, (self.D_H, self.D_OUT)) * 0.3}

    def apply(self, params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"])
        return h @ params["w2"], jnp.zeros(())

    def loss(self, params, batch):
        return softmax_xent_acc(self.apply(params, batch)[0],
                                batch["labels"])[0]


class TestWithMasksShardedRoundTrip:
    def test_sharded_state_roundtrip_no_relower(self):
        from repro.launch.steps import FLRunConfig, make_fl_train_step, \
            with_masks
        from repro.sharding.fl_specs import fl_state_specs
        from repro.sharding.specs import MeshPlan

        # every device on the MODEL axis: the w1/w2 hidden dim genuinely
        # shards (8-way under the CI job), clients are explicit batch rows
        mesh = jax.make_mesh((1, N_DEV), ("data", "model"))
        plan = MeshPlan(mesh=mesh, multi_pod=False, client_axes=(),
                        fsdp_axes=(), tp_axes=("model",), batch_axes=("data",),
                        num_clients=1)
        model = ShardedDictModel()
        run = FLRunConfig(lr=0.05, local_steps=2, server_tau=2,
                          server_batch=4, use_masks=True)
        init_state, train_step = make_fl_train_step(None, run, 3, model=model)
        state = init_state(jax.random.key(0))
        axes = {"w1": ("embed", "mlp"), "w2": ("mlp", "vocab_small")}
        specs = fl_state_specs(state, axes, plan)
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(state, shardings)
        # the hidden dim really shards when more than one device is present
        if N_DEV > 1:
            assert state["params"]["w1"].sharding.spec == P(None, "model")

        rng = np.random.default_rng(0)
        batch = {
            "client": {"x": jnp.asarray(rng.standard_normal(
                (3, 2, 4, model.D_IN)), jnp.float32),
                "labels": jnp.asarray(rng.integers(0, model.D_OUT, (3, 2, 4)))},
            "server": {"x": jnp.asarray(rng.standard_normal(
                (2, 4, model.D_IN)), jnp.float32),
                "labels": jnp.asarray(rng.integers(0, model.D_OUT, (2, 4)))},
            "sizes": jnp.asarray([4.0, 4.0, 4.0]),
            "d_round": jnp.float32(0.3), "d_server": jnp.float32(0.02),
            "n0": jnp.float32(100.0),
        }
        step = jax.jit(train_step)
        compiled = step.lower(state, batch).compile()
        state1, _ = compiled(state, batch)

        # inject a decision mid-run: mask half of w1's output filters (and
        # w2's matching input rows — the coupled closure)
        m = np.ones((model.D_H,), np.float32)
        m[model.D_H // 2:] = 0.0
        masks = {"w1": jnp.asarray(np.broadcast_to(m, (model.D_IN,
                                                       model.D_H)).copy()),
                 "w2": jnp.asarray(np.broadcast_to(m[:, None],
                                                   (model.D_H,
                                                    model.D_OUT)).copy())}
        state2 = with_masks(state1, masks)

        # shardings unchanged leaf-for-leaf
        flat1 = jax.tree_util.tree_leaves_with_path(state1)
        flat2 = jax.tree_util.tree_leaves_with_path(state2)
        for (p1, l1), (p2, l2) in zip(flat1, flat2):
            assert p1 == p2
            assert l1.sharding == l2.sharding, p1
            assert l1.shape == l2.shape
        # momentum restarted, params masked — the value contract
        for leaf in jax.tree.leaves(state2["server_m"]):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)
        np.testing.assert_array_equal(
            np.asarray(state2["params"]["w1"])[:, model.D_H // 2:], 0.0)

        # the PRE-PRUNE compiled executable keeps running on the new state:
        # no re-lower, and the masked coordinates stay zero
        state3, tau = compiled(state2, batch)
        assert step._cache_size() <= 1
        np.testing.assert_array_equal(
            np.asarray(state3["params"]["w1"])[:, model.D_H // 2:], 0.0)
        assert np.isfinite(float(tau))
