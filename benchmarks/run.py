"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Micro-benchmarks measure the
paper's operational pieces on this host (CPU); the large-architecture
numbers come from the dry-run roofline records (benchmarks/roofline.py),
and the accuracy tables from benchmarks/paper_experiments.py.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# --------------------------------------------------------------------------
# Formulas 2-3: non-IID degree computation
# --------------------------------------------------------------------------

def bench_niid():
    from repro.core import niid

    rng = np.random.default_rng(0)
    dists = jnp.asarray(rng.dirichlet(np.ones(100), size=100), jnp.float32)
    sizes = jnp.ones((100,), jnp.float32) * 400
    p_bar = niid.global_distribution(dists, sizes)
    fn = jax.jit(lambda d: niid.non_iid_degree(d, p_bar))
    us = _timeit(fn, dists)
    _row("niid_degree_100clients_100classes", us, f"degrees/s={1e6 / us:.0f}")


# --------------------------------------------------------------------------
# Formula 7: tau_eff schedule
# --------------------------------------------------------------------------

def bench_tau_eff():
    from repro.core.server_update import FedDUConfig, tau_eff

    cfg = FedDUConfig()
    fn = jax.jit(lambda t: tau_eff(cfg, acc=jnp.float32(0.5), round_idx=t,
                                   n0=2000.0, n_prime=4000.0, d_round=0.3,
                                   d_server=0.01, tau=100))
    us = _timeit(fn, jnp.float32(10))
    _row("tau_eff_schedule", us, "per-round scalar")


# --------------------------------------------------------------------------
# Tables 10-13 operational core: one FL round step (CNN, vmapped clients)
# --------------------------------------------------------------------------

def bench_round_step():
    from repro.core import FederatedTrainer, baselines, feddumap_config
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec
    from repro.models import SimpleCNN

    spec = SyntheticSpec(num_classes=10, image_shape=(10, 10, 3),
                         train_size=2600, test_size=200)
    data = build_federated_data(num_clients=10, server_fraction=0.1,
                                device_pool=2000, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(10, 10, 3))
    from repro.core import engine

    # One base key; init/sampling streams derived by fold_in so every
    # algorithm variant sees identical params and batches.
    base_key = jax.random.key(0)
    k_init = jax.random.fold_in(base_key, 0)
    k_sample = jax.random.fold_in(base_key, 1)
    for name, cfg in [
        ("fedavg", baselines.fedavg_config(num_clients=10, clients_per_round=5,
                                           local_epochs=1, batch_size=10)),
        ("feddu", baselines.feddu_config(num_clients=10, clients_per_round=5,
                                         local_epochs=1, batch_size=10)),
        ("feddum", feddumap_config(num_clients=10, clients_per_round=5,
                                   local_epochs=1, batch_size=10)),
    ]:
        tr = FederatedTrainer(model, data, cfg)
        params = model.init(k_init)
        state = engine.init_round_state(params, tr.engine_config)
        data_dev = tr._device_data()
        n_k = data.client_x.shape[1]
        n0 = data.server_x.shape[0]
        batch = engine.sample_round_batches(
            k_sample, data_dev,
            clients_per_round=cfg.clients_per_round,
            batch_size=cfg.batch_size,
            local_steps=max(1, n_k // cfg.batch_size) * cfg.local_epochs,
            server_batch=cfg.server_batch_size,
            server_tau=max(1, n0 // cfg.server_batch_size) * cfg.server_epochs)
        us = _timeit(lambda s, b: tr.round_step(s, b)[0]["params"],
                     state, batch, iters=5, warmup=2)
        _row(f"fl_round_{name}", us, f"rounds/s={1e6 / us:.2f}")


# --------------------------------------------------------------------------
# Tables 6-9: FedAP pruning pipeline cost + FLOP reduction
# --------------------------------------------------------------------------

def bench_fedap():
    from repro.core.pruning import (feature_map_ranks, global_threshold,
                                    per_layer_rates, select_filters, shrink_params)
    from repro.models import SimpleCNN

    model = SimpleCNN(num_classes=10, image_shape=(16, 16, 3))
    params = model.init(jax.random.key(0))
    spec = model.prune_spec(params)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((32, 16, 16, 3)),
                    jnp.float32)

    fn = jax.jit(lambda p: global_threshold(p, spec, jnp.float32(0.4)))
    us = _timeit(fn, params)
    _row("fedap_global_threshold", us, "once per prune round")

    fmaps = model.feature_maps(params, x)
    us = _timeit(jax.jit(feature_map_ranks), fmaps["conv2"])
    _row("fedap_hrank_scores_conv", us, "per layer, once")

    thr = fn(params)
    rates = per_layer_rates(params, spec, thr)
    kept = {l.name: select_filters(np.asarray(feature_map_ranks(fmaps[l.name])),
                                   float(rates[l.name]))
            for l in spec.layers}
    t0 = time.perf_counter()
    pruned = shrink_params(params, spec, kept)
    us = (time.perf_counter() - t0) * 1e6
    before = model.flops_per_example(params, (16, 16, 3))
    after = model.flops_per_example(pruned, (16, 16, 3))
    _row("fedap_shrink_params", us, f"mflops {before / 1e6:.2f}->{after / 1e6:.2f}")


# --------------------------------------------------------------------------
# Attention: materialized vs blocked (flash-style) XLA implementations
# --------------------------------------------------------------------------

def bench_attention():
    from repro.models.layers import attention_blocked, attention_ref

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 2048, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2048, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2048, 2, 64)), jnp.float32)
    f_ref = jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True))
    f_blk = jax.jit(lambda a, b, c: attention_blocked(a, b, c, causal=True))
    us_ref = _timeit(f_ref, q, k, v, iters=5)
    us_blk = _timeit(f_blk, q, k, v, iters=5)
    _row("attention_ref_2k", us_ref, "materialized scores")
    _row("attention_blocked_2k", us_blk,
         f"flash-style; ratio={us_ref / us_blk:.2f}x")


def bench_ssd():
    from repro.models.layers import _ssd_chunk_scan

    rng = np.random.default_rng(0)
    b, s, nh, p, n = 2, 2048, 8, 64, 64
    x = jnp.asarray(rng.standard_normal((b, s, nh, p)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    dt = jnp.asarray(rng.standard_normal((b, s, nh)), jnp.float32)
    al = jnp.zeros((nh,))
    d = jnp.ones((nh,))
    db = jnp.zeros((nh,))
    fn = jax.jit(lambda a1, a2, a3, a4: _ssd_chunk_scan(
        (a1, a2, a3, a4), al, d, db, None, 256))
    us = _timeit(fn, x, bm, cm, dt, iters=3)
    tokens_per_s = b * s / (us / 1e6)
    _row("ssd_chunk_scan_2k", us, f"tokens/s={tokens_per_s:.0f}")


# --------------------------------------------------------------------------
# Roofline summary (from dry-run records, if present)
# --------------------------------------------------------------------------

def bench_roofline_summary():
    import json
    from pathlib import Path

    d = Path("benchmarks/results/dryrun")
    if not d.exists():
        return
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    ok = [r for r in recs if r.get("ok")]
    census = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        census[b] = census.get(b, 0) + 1
    _row("dryrun_pairs_compiled", 0.0,
         f"{len(ok)}/{len(recs)} ok; census={census}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_niid()
    bench_tau_eff()
    bench_fedap()
    bench_attention()
    bench_ssd()
    bench_round_step()
    bench_roofline_summary()


if __name__ == "__main__":
    main()
