"""§Roofline report: read the dry-run records and emit the three-term table.

  PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/results/dryrun]

Terms (per device; the partitioned HLO module is the per-device program):
  compute_s    = HLO_FLOPs / 197 TFLOP/s (bf16)
  memory_s     = HLO_bytes / 819 GB/s
  collective_s = ring-adjusted wire bytes / 50 GB/s per link
plus MODEL_FLOPS = 6ND (train) / 2ND (inference), N_active for MoE, and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_records(d: Path):
    recs = []
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("ok"):
            recs.append(r)
    return recs


def fmt_row(r):
    t = r["roofline"]
    return (f"{r['arch']:<26} {r['shape']:<12} {r['mesh']:<8} "
            f"{t['compute_s']:>10.3f} {t['memory_s']:>10.3f} "
            f"{t['collective_s']:>12.3f} {t['bottleneck']:<10} "
            f"{(r.get('useful_flops_ratio') or 0):>6.2f} "
            f"{r['compile_s']:>7.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load_records(Path(args.dir))
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
              "useful_ratio,model_flops,hlo_flops_per_device")
        for r in recs:
            t = r["roofline"]
            print(f"{r['arch']},{r['shape']},{r['mesh']},{t['compute_s']:.4f},"
                  f"{t['memory_s']:.4f},{t['collective_s']:.4f},{t['bottleneck']},"
                  f"{(r.get('useful_flops_ratio') or 0):.3f},"
                  f"{r['model_flops']:.3e},{r['hlo_flops_per_device']:.3e}")
        return
    print(f"{'arch':<26} {'shape':<12} {'mesh':<8} {'compute_s':>10} "
          f"{'memory_s':>10} {'collective_s':>12} {'bottleneck':<10} "
          f"{'useful':>6} {'cmpl_s':>7}")
    for r in recs:
        print(fmt_row(r))
    # summary: bottleneck census
    census = {}
    for r in recs:
        census[r["roofline"]["bottleneck"]] = census.get(r["roofline"]["bottleneck"], 0) + 1
    print("\nbottleneck census:", census)


if __name__ == "__main__":
    main()
