"""§Perf hillclimb runner: re-lower one (arch, shape, mesh) with a named
optimization variant and diff the roofline terms against the baseline.

  PYTHONPATH=src python -m benchmarks.perf_iter \
      --arch llama3-405b --shape prefill_32k \
      --variant tree_attn --out benchmarks/results/perf

Variants (environment/config knobs; see EXPERIMENTS.md §Perf):
  baseline    — as-committed defaults
  tree_attn   — REPRO_ATTN_MODE=tree (binary-tree causal attention)
  p_bf16      — REPRO_ATTN_P_BF16=1 (bf16 probabilities for P @ V)
  tree+p_bf16 — both
  remat_dots  — cfg.remat='dots' (save matmul outputs in the bwd)
  moe_cap1    — MoE capacity_factor 1.0 (vs 1.25)
  block2k     — attention q-block 2048 (vs 1024)
"""
import argparse
import dataclasses
import json
import os
import sys
from pathlib import Path

VARIANTS = {
    "baseline": {},
    "tree_attn": {"env": {"REPRO_ATTN_MODE": "tree"}},
    "p_bf16": {"env": {"REPRO_ATTN_P_BF16": "1"}},
    "tree+p_bf16": {"env": {"REPRO_ATTN_MODE": "tree", "REPRO_ATTN_P_BF16": "1"}},
    "remat_dots": {"cfg": {"remat": "dots"}},
    "moe_cap1": {"moe": {"capacity_factor": 1.0}},
    "block2k": {"env": {"REPRO_ATTN_BLOCK_Q": "2048"}},
    "pad_heads": {"env": {"REPRO_ATTN_REPEAT_KV": "1", "REPRO_PAD_HEADS": "16"}},
    "pad_heads+tree": {"env": {"REPRO_ATTN_REPEAT_KV": "1", "REPRO_PAD_HEADS": "16",
                               "REPRO_ATTN_MODE": "tree"}},
    "moe_cap1+pad_heads": {"env": {"REPRO_ATTN_REPEAT_KV": "1",
                                   "REPRO_PAD_HEADS": "16"},
                           "moe": {"capacity_factor": 1.0}},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/perf")
    args = ap.parse_args()

    spec = VARIANTS[args.variant]
    for k, v in spec.get("env", {}).items():
        os.environ[k] = v

    # XLA device count must be set before jax import — same as dryrun
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.configs import _ARCHS  # noqa: F401  (triggers config import)
    import repro.configs as C
    from repro.launch import dryrun as D

    cfg_overrides = dict(spec.get("cfg", {}))
    moe_overrides = dict(spec.get("moe", {}))
    if cfg_overrides or moe_overrides:
        # monkey-patch get_config so dryrun_pair sees the variant config
        base_get = C.get_config

        def patched(name):
            cfg = base_get(name)
            if moe_overrides and cfg.moe:
                cfg = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
            if cfg_overrides:
                cfg = dataclasses.replace(cfg, **cfg_overrides)
            return cfg

        C.get_config = patched
        D.get_config = patched

    rec = D.dryrun_pair(args.arch, args.shape, multi_pod=args.multipod)
    rec["variant"] = args.variant
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    mesh = "2x16x16" if args.multipod else "16x16"
    path = out / f"{args.arch}__{args.shape}__{mesh}__{args.variant}.json"
    path.write_text(json.dumps(rec, indent=2))
    t = rec["roofline"]
    print(f"{args.variant}: compute {t['compute_s']:.2f}s  memory "
          f"{t['memory_s']:.2f}s  collective {t['collective_s']:.2f}s  "
          f"bottleneck={t['bottleneck']}  flops/dev={rec['hlo_flops_per_device']:.3e}")


if __name__ == "__main__":
    main()
