"""§Perf hillclimb runner: re-lower one (arch, shape, mesh) with a named
optimization variant and diff the roofline terms against the baseline.

  PYTHONPATH=src python -m benchmarks.perf_iter \
      --arch llama3-405b --shape prefill_32k \
      --variant tree_attn --out benchmarks/results/perf

Variants (environment/config knobs; see EXPERIMENTS.md §Perf):
  baseline    — as-committed defaults
  tree_attn   — REPRO_ATTN_MODE=tree (binary-tree causal attention)
  p_bf16      — REPRO_ATTN_P_BF16=1 (bf16 probabilities for P @ V)
  tree+p_bf16 — both
  remat_dots  — cfg.remat='dots' (save matmul outputs in the bwd)
  moe_cap1    — MoE capacity_factor 1.0 (vs 1.25)
  block2k     — attention q-block 2048 (vs 1024)

FL engine benchmark (no arch/shape needed; emits BENCH_fl_engine.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --fl-engine

compares rounds/sec of the pre-refactor architecture (host-side NumPy
client sampling + one jitted round dispatch per round) against the
scan-compiled engine (device-side sampling, one lax.scan program for the
whole run) on the simulation-scale FedDUMAP configuration.

FedAP scheduling benchmark (emits BENCH_fedap_plan.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --fedap-plan

compares rounds/sec of the TrainPlan masked mode (Prune(mode="mask"):
keep-masks in the scan carry, every round inside compiled scan chunks)
against the legacy hook-based architecture (length=1 chunks so the hook
observes every round + structural re-materialize at the prune round).

Mesh-backend benchmark (emits BENCH_mesh_backend.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --mesh-backend

rounds/sec of one FedDUMAP TrainPlan through the LocalScanBackend vs the
client-sharded MeshBackend at several client counts (the process forces 8
virtual CPU devices, so the mesh is a real 8-way client axis); the local
column also records the double-buffered-sampling delta (prefetch on/off).
On this CPU container the mesh numbers measure GSPMD partitioning
overhead, not a speedup — 8 virtual devices share the same cores; the
hardware claim is that the client axis (sampling, local epochs, FedAvg
reduction) partitions across real devices with bit-compatible numerics
(tests/test_mesh_backend.py locks mesh == local == f64 oracle).

Sharded-server/eval benchmark (emits BENCH_mesh_server_eval.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --mesh-server-eval

per-round time of the MeshBackend with the FedDU server-update scan and
the test-split evaluation batch-SHARDED over the mesh data axis (the
default) vs REPLICATED on every device (backend_opts={"shard_server":
False, "shard_eval": False}), at tau in {5, 20} server steps per round,
plus the Prune(mode="shrink") state-compaction time: the jitted
shard-local gather vs the legacy host re-materialize + re-place.  The
same CPU caveat as BENCH_mesh_backend.json applies: 8 virtual devices
share this container's cores, so sharded-vs-replicated here measures
GSPMD partitioning overhead rather than the multi-device win; the parity
tests carry the correctness claim and the record carries the scaling
shape.

Masked-training-compute benchmark (emits BENCH_masked_train.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --masked-train

one SGD training step (fwd + custom-VJP bwd) of a 128-aligned MLP with
half its filter blocks pruned: the Pallas masked_matmul path
(masked_compute="kernel") vs the dense-masked path (masked_compute=
"params": full-density XLA matmuls, mask applied elementwise).  On this
CPU container the kernel runs in INTERPRET mode, so wall times measure
dispatch overhead, not MXU work — the hardware claim is the analytic
FLOP reduction, which the record carries alongside the timings.

Masked-LM-training benchmark (emits BENCH_masked_lm_train.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --masked-lm-train

the same kernel-vs-dense-masked split on the 128-aligned tiny
transformer at FedAP prune rate 0.5: the FFN wi/wg matmuls route
through the block-skipping masked_dense with the keep-masks riding the
layer scan.  Same CPU-interpret timing caveat.

Guarded-training benchmark (emits BENCH_guarded_train.json):

  PYTHONPATH=src python -m benchmarks.perf_iter --guarded-train

warm rounds/s of the same FedDUMAP plan with the in-scan health guard
off vs guard="reject_client" vs guard="skip_round": the cost of the
per-round finiteness checks, rejected-client scrubbing and discard
data-flow (all inside the ONE chunk program — zero extra traces, locked
by the guard_* compile-budget scenarios).  On this CPU container the
guard's elementwise isfinite reductions compete with the matmuls for the
same two cores, so the measured overhead is an upper bound — on real
accelerators the checks are bandwidth-trivial next to the client matmuls.
"""
import argparse
import dataclasses
import json
import os
import sys
from pathlib import Path

VARIANTS = {
    "baseline": {},
    "tree_attn": {"env": {"REPRO_ATTN_MODE": "tree"}},
    "p_bf16": {"env": {"REPRO_ATTN_P_BF16": "1"}},
    "tree+p_bf16": {"env": {"REPRO_ATTN_MODE": "tree", "REPRO_ATTN_P_BF16": "1"}},
    "remat_dots": {"cfg": {"remat": "dots"}},
    "moe_cap1": {"moe": {"capacity_factor": 1.0}},
    "block2k": {"env": {"REPRO_ATTN_BLOCK_Q": "2048"}},
    "pad_heads": {"env": {"REPRO_ATTN_REPEAT_KV": "1", "REPRO_PAD_HEADS": "16"}},
    "pad_heads+tree": {"env": {"REPRO_ATTN_REPEAT_KV": "1", "REPRO_PAD_HEADS": "16",
                               "REPRO_ATTN_MODE": "tree"}},
    "moe_cap1+pad_heads": {"env": {"REPRO_ATTN_REPEAT_KV": "1",
                                   "REPRO_PAD_HEADS": "16"},
                           "moe": {"capacity_factor": 1.0}},
}


def bench_fl_engine(out_dir: str, *, num_rounds: int = 30) -> dict:
    """Rounds/sec: per-round Python dispatch (the pre-refactor driver
    architecture: host np.random batch sampling + one jitted call per
    round) vs. the scan-compiled engine (device-side sampling, one
    lax.scan program).

    Two workloads bracket the regimes:
      cnn  — the paper's simulation CNN; per-round compute dominates, so
             the two architectures tie on a single CPU device (the scan
             win here is on accelerators, where every host round-trip
             stalls the device);
      mlp  — a tiny model where per-round compute is ~ms; orchestration
             (host sampling, H2D transfers, dispatch) dominates and the
             scan engine's advantage is directly visible.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import engine, feddumap_config, FederatedTrainer
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec
    from repro.models import SimpleCNN

    class TinyMLP:
        """192 -> 32 -> 10 MLP over the flattened synthetic images."""

        def init(self, rng):
            k1, k2 = jax.random.split(rng)
            d = 8 * 8 * 3
            return {
                "w1": jax.random.normal(k1, (d, 32)) * (2.0 / d) ** 0.5,
                "b1": jnp.zeros((32,)),
                "w2": jax.random.normal(k2, (32, 10)) * 0.25,
                "b2": jnp.zeros((10,)),
            }

        def loss_and_acc(self, params, x, y):
            from repro.models.cnn import softmax_xent_acc
            h = jax.nn.relu(x.reshape(x.shape[0], -1) @ params["w1"]
                            + params["b1"])
            return softmax_xent_acc(h @ params["w2"] + params["b2"], y)

    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=3000, test_size=300, noise_scale=0.5)
    data = build_federated_data(num_clients=20, server_fraction=0.1,
                                device_pool=2000, spec=spec)
    cnn = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                    channels=(8, 8, 8), fc_width=16)
    cfg = feddumap_config(num_clients=20, clients_per_round=5, local_epochs=1,
                          batch_size=10, lr=0.05)

    def one_workload(model):
        trainer = FederatedTrainer(model, data, cfg)

        # scan engine: one compiled lax.scan over all rounds
        trainer.run(num_rounds, eval_every=num_rounds)          # compile
        t0 = time.perf_counter()
        trainer.run(num_rounds, eval_every=num_rounds)
        scan_s = time.perf_counter() - t0

        # legacy architecture: host np.random sampling + one round_step
        # dispatch per round (what core/rounds.py did before the refactor)
        rng = np.random.default_rng(cfg.seed)
        n_k = data.client_x.shape[1]
        steps = max(1, n_k // cfg.batch_size) * cfg.local_epochs
        n0 = data.server_x.shape[0]
        tau = max(1, n0 // cfg.server_batch_size) * cfg.server_epochs
        d_dev = trainer._device_data()

        def host_round_batch():
            from repro.core import niid
            sel = rng.choice(cfg.num_clients, cfg.clients_per_round,
                             replace=False)
            xs, ys = [], []
            for k in sel:
                idx = np.concatenate([rng.permutation(n_k)
                                      for _ in range(cfg.local_epochs + 1)]
                                     )[: steps * cfg.batch_size]
                xs.append(data.client_x[k][idx].reshape(
                    steps, cfg.batch_size, *data.client_x.shape[2:]))
                ys.append(data.client_y[k][idx].reshape(steps, cfg.batch_size))
            sidx = np.concatenate([rng.permutation(n0)
                                   for _ in range(cfg.server_epochs + 1)]
                                  )[: tau * cfg.server_batch_size]
            p_round = niid.round_distribution(d_dev["client_dists"],
                                              d_dev["sizes"], jnp.asarray(sel))
            return {
                "client": (jnp.asarray(np.stack(xs)),
                           jnp.asarray(np.stack(ys))),
                "sizes": jnp.asarray(data.sizes[sel], jnp.float32),
                "server": (jnp.asarray(data.server_x[sidx].reshape(
                    tau, cfg.server_batch_size, *data.server_x.shape[1:])),
                    jnp.asarray(data.server_y[sidx].reshape(
                        tau, cfg.server_batch_size), jnp.int32)),
                "d_round": niid.non_iid_degree(p_round, d_dev["p_bar"]),
                "d_server": d_dev["d_server"],
                "n0": jnp.asarray(float(n0), jnp.float32),
            }

        params = model.init(jax.random.key(cfg.seed))
        state = engine.init_round_state(params, trainer.engine_config)
        state, _ = trainer.round_step(state, host_round_batch())    # compile
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(num_rounds):
            state, _ = trainer.round_step(state, host_round_batch())
        jax.block_until_ready(state)
        loop_s = time.perf_counter() - t0

        return {
            "local_steps": steps, "server_tau": tau,
            "python_loop_rounds_per_s": num_rounds / loop_s,
            "scan_rounds_per_s": num_rounds / scan_s,
            "speedup": loop_s / scan_s,
        }

    rec = {
        "bench": "fl_engine",
        "num_rounds": num_rounds,
        "config": {"num_clients": cfg.num_clients,
                   "clients_per_round": cfg.clients_per_round,
                   "algorithm": "feddumap"},
        "workloads": {"cnn": one_workload(cnn), "mlp": one_workload(TinyMLP())},
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_fl_engine.json"
    path.write_text(json.dumps(rec, indent=2))
    for name, w in rec["workloads"].items():
        print(f"fl_engine[{name}]: python-loop "
              f"{w['python_loop_rounds_per_s']:.2f} rounds/s  scan "
              f"{w['scan_rounds_per_s']:.2f} rounds/s  "
              f"speedup {w['speedup']:.2f}x")
    print(f"-> {path}")
    return rec


def bench_fedap_plan(out_dir: str, *, rounds: int = 24,
                     prune_round: int = 12) -> dict:
    """Rounds/sec of a FedDUMAP run with FedAP at ``prune_round``:

      masked — TrainPlan with Prune(mode="mask"): keep-masks enter the scan
               carry, EVERY round runs inside compiled scan chunks (no
               length=1 fallback, no re-jit at the prune round);
      hook   — the legacy architecture: per-round length=1 chunks (a hook
               had to observe every round) + host gate + structural
               re-materialize at the prune round.

    Both paths run the identical FedAP decision once.  The headline metric
    is COLD end-to-end wall time (compile caches cleared), because that is
    how a federated training run actually executes: programs compile once,
    and the hook path pays its re-trace at the prune round IN-BAND.  Warm
    (steady-state) numbers are recorded too — there the hook path benefits
    from training a genuinely smaller model after the shrink.

    A third schedule closes that warm-path trade: ``masked_then_shrink``
    (``fedap_plan(..., shrink_round=...)``) masks at the prune round (no
    mid-scan re-jit) and compacts to the SAME decision at a later segment
    boundary, so the steady-state rounds train the genuinely smaller
    model — the mask path's cold win AND the shrink path's warm win.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        FedAPConfig,
        FederatedTrainer,
        engine,
        fedap_plan,
        feddumap_config,
        pruning,
    )
    from repro.core.fedap import fedap_decision
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec
    from repro.models import SimpleCNN

    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=3000, test_size=300, noise_scale=0.5)
    data = build_federated_data(num_clients=20, server_fraction=0.1,
                                device_pool=2000, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(8, 8, 8), fc_width=16)
    # min_rate guarantees the prune actually bites (the eigen-gap rule can
    # decide "prune nothing" on the synthetic task, which would let the
    # hook path skip its re-jit and make this comparison vacuous)
    apcfg = FedAPConfig(prune_round=prune_round, probe_size=8,
                        participants=2, min_rate=0.5)
    cfg = feddumap_config(num_clients=20, clients_per_round=5, local_epochs=1,
                          batch_size=10, lr=0.05, fedap=apcfg)

    from repro.core.rounds import clear_compiled_cache

    # Pre-warm the work BOTH paths run identically — the process-global
    # first-compile (backend init) and the FedAP decision's eager-op
    # compiles (per-sample grads, eigvalsh, HRank SVDs) — so the comparison
    # isolates the SCHEDULING architectures, not which path ran first.
    jax.jit(lambda x: x * 2.0)(jnp.ones((8, 8))).block_until_ready()
    _p0 = model.init(jax.random.key(0))
    fedap_decision(model, data, apcfg, _p0, init_params=_p0,
                   rng=np.random.default_rng(0))

    # --- masked plan: the prune round runs inside the compiled scan --------
    # prune_round == rounds/2 makes both Scan segments the same length, so
    # the plan compiler needs exactly ONE chunk program for the whole run
    plan = fedap_plan(rounds, prune_round=prune_round, mode="mask",
                      eval_every=rounds)

    def masked_run(trainer):
        res = trainer.run(plan)
        jax.block_until_ready(res.params)

    # --- mask now, shrink later: compact to the same decision at the next
    # --- segment boundary; the tail rounds train the smaller model
    shrink_round = (prune_round + rounds) // 2
    plan_ms = fedap_plan(rounds, prune_round=prune_round,
                         shrink_round=shrink_round, eval_every=rounds)

    def masked_shrink_run(trainer):
        res = trainer.run(plan_ms)
        jax.block_until_ready(res.params)

    # --- legacy hook architecture: length=1 chunks + re-materialize --------
    def legacy_run(trainer):
        ce = trainer._compiled()
        data_dev = trainer._device_data()
        params0 = model.init(jax.random.key(cfg.seed))
        init_params = jax.tree.map(jnp.copy, params0)
        state = engine.init_round_state(jax.tree.map(jnp.copy, params0),
                                        ce.eng)
        for t in range(rounds):
            state, trainer._key, _ = ce.chunk(state, trainer._key, data_dev,
                                              length=1)
            if t + 1 == prune_round:
                params = jax.tree.map(jnp.copy, state["params"])
                dec = fedap_decision(model, data, apcfg, params,
                                     init_params=init_params,
                                     rng=np.random.default_rng(cfg.seed))
                pspec = model.prune_spec(params)
                round_ = state["round"]
                # the shrink forces the chunk program to RE-TRACE at the
                # pruned shapes — mid-training, in-band
                state = engine.init_round_state(
                    pruning.shrink_params(params, pspec, dec.kept), ce.eng)
                state["round"] = round_
        jax.block_until_ready(state["params"])

    def cold_and_warm(run_fn):
        clear_compiled_cache()
        trainer = FederatedTrainer(model, data, cfg)
        t0 = time.perf_counter()
        run_fn(trainer)
        cold = time.perf_counter() - t0
        # the trainer's key advances across runs, so run 2 can still pay a
        # one-off re-trace when its (data-dependent) FedAP decision shrinks
        # to different shapes than run 1 — time the STEADY state, run 3
        run_fn(trainer)
        t0 = time.perf_counter()
        run_fn(trainer)
        warm = time.perf_counter() - t0
        return cold, warm

    masked_cold, masked_warm = cold_and_warm(masked_run)
    hook_cold, hook_warm = cold_and_warm(legacy_run)
    ms_cold, ms_warm = cold_and_warm(masked_shrink_run)

    rec = {
        "bench": "fedap_plan",
        "rounds": rounds,
        "prune_round": prune_round,
        "shrink_round": shrink_round,
        "config": {"num_clients": cfg.num_clients,
                   "clients_per_round": cfg.clients_per_round,
                   "algorithm": "feddumap"},
        # headline: end-to-end including compilation — a training run pays
        # the hook path's prune-round re-jit exactly once, in-band
        "masked_rounds_per_s": rounds / masked_cold,
        "hook_rounds_per_s": rounds / hook_cold,
        "masked_then_shrink_rounds_per_s": rounds / ms_cold,
        "cold_note": "masked_then_shrink compiles three chunk programs "
                     "(pre-prune, masked, shrunk) where the masked plan "
                     "compiles one — a fixed cost that amortizes over "
                     "long runs; its win is the warm column",
        "speedup": hook_cold / masked_cold,
        "warm": {"masked_rounds_per_s": rounds / masked_warm,
                 "hook_rounds_per_s": rounds / hook_warm,
                 "masked_then_shrink_rounds_per_s": rounds / ms_warm,
                 "note": "steady-state; masked_then_shrink recovers the "
                         "hook path's smaller-model warm advantage while "
                         "keeping the prune round inside the compiled "
                         "scan (the ROADMAP warm-path gap)"},
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_fedap_plan.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"fedap_plan (cold, end-to-end): hook-rematerialize "
          f"{rec['hook_rounds_per_s']:.2f} rounds/s  masked-plan "
          f"{rec['masked_rounds_per_s']:.2f} rounds/s  masked-then-shrink "
          f"{rec['masked_then_shrink_rounds_per_s']:.2f} rounds/s  "
          f"speedup {rec['speedup']:.2f}x")
    print(f"fedap_plan (warm): hook {rec['warm']['hook_rounds_per_s']:.2f} "
          f"masked {rec['warm']['masked_rounds_per_s']:.2f} "
          f"masked-then-shrink "
          f"{rec['warm']['masked_then_shrink_rounds_per_s']:.2f} rounds/s")
    print(f"-> {path}")
    return rec


def bench_mesh_backend(out_dir: str, *, rounds: int = 12) -> dict:
    """Rounds/sec of one FedDUMAP plan: LocalScanBackend vs MeshBackend
    (client axis sharded over 8 virtual devices) at several client counts,
    plus the local backend's prefetch on/off delta.

    Timings are WARM (second run of the same trainer: programs compiled,
    data resident) — the quantity a long federated run actually pays per
    round.  On this CPU container the virtual devices share two cores, so
    the mesh column measures partitioning overhead, not speedup; the
    parity tests carry the correctness claim and this record carries the
    scaling shape.
    """
    import dataclasses as dc
    import time

    import jax

    from repro.core import FederatedTrainer, feddumap_config
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec
    from repro.models import SimpleCNN

    n_dev = len(jax.devices())
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(8, 8, 8), fc_width=16)

    def timed_run(trainer):
        trainer.run(rounds, eval_every=rounds)          # compile + data
        t0 = time.perf_counter()
        trainer.run(rounds, eval_every=rounds)
        return rounds / (time.perf_counter() - t0)

    scenarios = []
    for num_clients, cpr in [(16, 8), (32, 16), (64, 32)]:
        spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                             train_size=num_clients * 100 + 1100,
                             test_size=200, noise_scale=0.5)
        data = build_federated_data(num_clients=num_clients,
                                    server_fraction=0.1,
                                    device_pool=num_clients * 100, spec=spec)
        cfg = feddumap_config(num_clients=num_clients, clients_per_round=cpr,
                              local_epochs=1, batch_size=10, lr=0.05)
        local = timed_run(FederatedTrainer(model, data, cfg))
        serial = timed_run(FederatedTrainer(
            model, data, dc.replace(cfg, prefetch_sampling=False)))
        mesh = timed_run(FederatedTrainer(model, data, cfg, backend="mesh"))
        scenarios.append({
            "num_clients": num_clients,
            "clients_per_round": cpr,
            "local_rounds_per_s": local,
            "local_noprefetch_rounds_per_s": serial,
            "prefetch_speedup": local / serial,
            "mesh_rounds_per_s": mesh,
            "mesh_vs_local": mesh / local,
        })
        print(f"mesh_backend[C={num_clients},cpr={cpr}]: local "
              f"{local:.2f} rounds/s (no-prefetch {serial:.2f}, "
              f"{local / serial:.2f}x)  mesh {mesh:.2f} rounds/s "
              f"({mesh / local:.2f}x of local)")

    rec = {
        "bench": "mesh_backend",
        "rounds": rounds,
        "devices": n_dev,
        "algorithm": "feddumap",
        "timing_note": "warm rounds/s; 8 virtual CPU devices share the "
                       "container's cores, so mesh/local < 1 here measures "
                       "GSPMD partitioning overhead — on real multi-device "
                       "hardware the client axis is genuinely parallel "
                       "(numerics locked by tests/test_mesh_backend.py)",
        "scenarios": scenarios,
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_mesh_backend.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"-> {path}")
    return rec


def bench_guarded_train(out_dir: str, *, rounds: int = 12) -> dict:
    """Rounds/sec of one FedDUMAP plan with the in-scan health guard off
    vs on (both modes), at two client counts on the local scan backend.

    Timings are WARM (second run of the same trainer).  The guard is pure
    device data-flow riding the existing chunk program, so the expected
    cost is a few elementwise isfinite reductions per round; on this CPU
    container they share two cores with the matmuls, making the measured
    ratio an upper bound on real-accelerator overhead.
    """
    import dataclasses as dc
    import time

    import jax

    from repro.core import FederatedTrainer, feddumap_config
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec
    from repro.models import SimpleCNN

    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(8, 8, 8), fc_width=16)

    def timed_run(trainer):
        trainer.run(rounds, eval_every=rounds)          # compile + data
        t0 = time.perf_counter()
        trainer.run(rounds, eval_every=rounds)
        return rounds / (time.perf_counter() - t0)

    scenarios = []
    for num_clients, cpr in [(16, 8), (32, 16)]:
        spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                             train_size=num_clients * 100 + 1100,
                             test_size=200, noise_scale=0.5)
        data = build_federated_data(num_clients=num_clients,
                                    server_fraction=0.1,
                                    device_pool=num_clients * 100, spec=spec)
        cfg = feddumap_config(num_clients=num_clients, clients_per_round=cpr,
                              local_epochs=1, batch_size=10, lr=0.05)
        off = timed_run(FederatedTrainer(model, data, cfg))
        reject = timed_run(FederatedTrainer(
            model, data, dc.replace(cfg, guard="reject_client")))
        skip = timed_run(FederatedTrainer(
            model, data, dc.replace(cfg, guard="skip_round")))
        scenarios.append({
            "num_clients": num_clients,
            "clients_per_round": cpr,
            "guard_off_rounds_per_s": off,
            "guard_reject_rounds_per_s": reject,
            "guard_skip_rounds_per_s": skip,
            "reject_vs_off": reject / off,
            "skip_vs_off": skip / off,
        })
        print(f"guarded_train[C={num_clients},cpr={cpr}]: off {off:.2f} "
              f"rounds/s  reject {reject:.2f} ({reject / off:.2f}x)  "
              f"skip {skip:.2f} ({skip / off:.2f}x)")

    rec = {
        "bench": "guarded_train",
        "rounds": rounds,
        "devices": len(jax.devices()),
        "algorithm": "feddumap",
        "timing_note": "warm rounds/s on the local scan backend; the guard "
                       "adds zero jitted programs (guard_* compile-budget "
                       "scenarios) — on this shared-core CPU container the "
                       "isfinite reductions contend with the matmuls, so "
                       "the on/off ratio is an upper bound on accelerator "
                       "overhead",
        "scenarios": scenarios,
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_guarded_train.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"-> {path}")
    return rec


def bench_mesh_server_eval(out_dir: str, *, rounds: int = 8) -> dict:
    """Replicated vs batch-sharded FedDU server scan + eval on the mesh.

    Three measurements, all on the 8-virtual-device host mesh:
      * warm rounds/s of a Scan-only plan with the server-update batches
        sharded over the data axis vs replicated (tau in {5, 20} — the
        server scan's share of the round grows with tau, which is where
        FedDUAP's server-side work dominates);
      * warm seconds per Eval event, sharded test batch vs replicated
        full-test pass;
      * seconds per Prune(mode="shrink") compaction of a masked round
        state (params + momentum): the jitted shard-local gather (new)
        vs the legacy host re-materialize + device_put re-place (old).
    """
    import time

    import jax

    from repro.core import (
        FederatedTrainer,
        Prune,
        Scan,
        TrainPlan,
        feddumap_config,
    )
    from repro.core.backend import _EngineBackend
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec
    from repro.models import SimpleCNN

    n_dev = len(jax.devices())
    model = SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                      channels=(8, 8, 8), fc_width=16)
    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=2800, test_size=200, noise_scale=0.5)
    # n0 = 0.1 * 1600 = 160 server rows, server_batch 32 -> 5 steps/epoch
    data = build_federated_data(num_clients=16, server_fraction=0.1,
                                device_pool=1600, spec=spec)

    def trainer(cfg, *, sharded):
        opts = {} if sharded else {"shard_server": False,
                                   "shard_eval": False}
        return FederatedTrainer(model, data, cfg, backend="mesh",
                                backend_opts=opts)

    def timed_rounds(tr):
        plan = TrainPlan(Scan(rounds))
        tr.run(plan)                                    # compile + data
        t0 = time.perf_counter()
        jax.block_until_ready(tr.run(plan).params)
        return (time.perf_counter() - t0) / rounds

    def timed_eval(tr, reps=20):
        be = tr.backend()
        state = be.init_state(model.init(jax.random.key(0)))
        jax.block_until_ready(be.evaluate(state))       # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = be.evaluate(state)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    scenarios = []
    for server_epochs in (1, 4):                        # tau = 5, 20
        cfg = feddumap_config(num_clients=16, clients_per_round=8,
                              local_epochs=1, batch_size=10, lr=0.05,
                              server_batch_size=32,
                              server_epochs=server_epochs)
        tau = server_epochs * (data.server_x.shape[0] // 32)
        tr_s, tr_r = trainer(cfg, sharded=True), trainer(cfg, sharded=False)
        round_s, round_r = timed_rounds(tr_s), timed_rounds(tr_r)
        eval_s, eval_r = timed_eval(tr_s), timed_eval(tr_r)
        scenarios.append({
            "server_tau": tau,
            "round_s_sharded": round_s,
            "round_s_replicated": round_r,
            "round_sharded_vs_replicated": round_r / round_s,
            "eval_s_sharded": eval_s,
            "eval_s_replicated": eval_r,
            "eval_sharded_vs_replicated": eval_r / eval_s,
        })
        print(f"mesh_server_eval[tau={tau}]: round sharded "
              f"{round_s * 1e3:.1f} ms vs replicated {round_r * 1e3:.1f} ms "
              f"({round_r / round_s:.2f}x); eval sharded "
              f"{eval_s * 1e3:.1f} ms vs replicated {eval_r * 1e3:.1f} ms "
              f"({eval_r / eval_s:.2f}x)")

    # --- shrink round-trip: jitted shard-local gather vs host path ---------
    apcfg = dataclasses.replace(
        feddumap_config().fedap, prune_round=2, probe_size=8,
        participants=2, min_rate=0.5)
    cfg = feddumap_config(num_clients=16, clients_per_round=8,
                          local_epochs=1, batch_size=10, lr=0.05,
                          server_batch_size=32, fedap=apcfg)
    tr = trainer(cfg, sharded=True)
    res = tr.run(TrainPlan(Scan(2), Prune(mode="mask")))
    be = tr.backend(use_masks=True)
    state, kept = res.state, res.artifacts["prune"]["kept"]

    def timed_shrink(apply_fn, reps=10):
        out, _ = apply_fn()                             # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out, _ = apply_fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    shrink_new = timed_shrink(
        lambda: be.apply_prune(state, "shrink", kept, compact_existing=True))
    shrink_old = timed_shrink(
        lambda: _EngineBackend.apply_prune(be, state, "shrink", kept,
                                           compact_existing=True))
    print(f"mesh_server_eval[shrink]: sharded compaction "
          f"{shrink_new * 1e3:.1f} ms vs host re-materialize "
          f"{shrink_old * 1e3:.1f} ms ({shrink_old / shrink_new:.2f}x)")

    rec = {
        "bench": "mesh_server_eval",
        "rounds": rounds,
        "devices": n_dev,
        "algorithm": "feddumap",
        "config": {"num_clients": 16, "clients_per_round": 8,
                   "server_batch_size": 32, "test_size": 200},
        "timing_note": "warm timings; 8 virtual CPU devices share the "
                       "container's cores, so sharded/replicated here "
                       "measures GSPMD partitioning overhead, not the "
                       "multi-device win — on real hardware the sharded "
                       "server scan and eval split work that was "
                       "redundantly replicated per device "
                       "(tests/test_mesh_backend.py locks the numerics)",
        "scenarios": scenarios,
        "shrink": {
            "sharded_compaction_s": shrink_new,
            "host_rematerialize_s": shrink_old,
            "speedup": shrink_old / shrink_new,
            "note": "Prune(mode='shrink') of params+momentum on a masked "
                    "mesh state: one jitted gather with NamedSharding "
                    "outputs vs eager per-tensor slicing + device_put "
                    "re-place",
        },
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_mesh_server_eval.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"-> {path}")
    return rec


def bench_masked_train(out_dir: str, *, steps: int = 5,
                       prune_rate: float = 0.5) -> dict:
    """One masked TRAINING step: Pallas masked-matmul (kernel path, with
    the custom VJP) vs dense-masked (full-density matmuls + elementwise
    mask — what masked_compute="params" computes).

    Model: 256 -> 512 -> 512 -> 10 MLP, batch 128; both 512-wide hidden
    layers carry an output-filter mask with ``prune_rate`` of their
    128-wide blocks pruned.  The kernel path routes through
    ``masked_dense`` (M-pad shim + block-skip fwd/bwd kernels); on CPU it
    executes in interpret mode, so the timing comparison shows overhead,
    not the MXU win — the analytic FLOP counts are the hardware claim.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.cnn import masked_dense, softmax_xent_acc

    m, d_in, d_h, classes, block = 128, 256, 512, 10, 128
    nblocks = d_h // block
    pruned_blocks = int(round(prune_rate * nblocks))
    kept_frac = (nblocks - pruned_blocks) / nblocks

    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.standard_normal((d_in, d_h)) * 0.05, jnp.float32),
        "b1": jnp.zeros((d_h,), jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((d_h, d_h)) * 0.05, jnp.float32),
        "b2": jnp.zeros((d_h,), jnp.float32),
        "w3": jnp.asarray(rng.standard_normal((d_h, classes)) * 0.05,
                          jnp.float32),
        "b3": jnp.zeros((classes,), jnp.float32),
    }
    mask = np.ones((d_h,), np.float32)
    mask[: pruned_blocks * block] = 0.0
    mask = jnp.asarray(mask)
    x = jnp.asarray(rng.standard_normal((m, d_in)), jnp.float32)
    y = jnp.asarray(rng.integers(0, classes, (m,)), jnp.int32)

    def loss_kernel(p):
        h = jax.nn.relu(masked_dense(x, p["w1"], mask, p["b1"]))
        h = jax.nn.relu(masked_dense(h, p["w2"], mask, p["b2"]))
        return softmax_xent_acc(h @ p["w3"] + p["b3"], y)[0]

    def loss_dense(p):
        h = jax.nn.relu(((x @ p["w1"]) + p["b1"]) * mask)
        h = jax.nn.relu(((h @ p["w2"]) + p["b2"]) * mask)
        return softmax_xent_acc(h @ p["w3"] + p["b3"], y)[0]

    def sgd(loss_fn):
        @jax.jit
        def step(p):
            g = jax.grad(loss_fn)(p)
            return jax.tree.map(lambda pi, gi: pi - 0.01 * gi, p, g)
        return step

    def timed(step):
        p = jax.tree.map(jnp.copy, params)
        p = step(p)                                   # compile
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for _ in range(steps):
            p = step(p)
        jax.block_until_ready(p)
        return (time.perf_counter() - t0) / steps

    kernel_s = timed(sgd(loss_kernel))
    dense_s = timed(sgd(loss_dense))

    # analytic training matmul FLOPs of the two masked layers: fwd + dx +
    # dw are each 2*M*K*N MACs; the kernel skips the pruned N blocks in
    # all three, the dense path runs all of them every step
    def layer_flops(k, n):
        return 3 * 2 * m * k * n

    masked_layers = layer_flops(d_in, d_h) + layer_flops(d_h, d_h)
    out_layer = layer_flops(d_h, classes)
    flops_dense = masked_layers + out_layer
    flops_masked = masked_layers * kept_frac + out_layer

    rec = {
        "bench": "masked_train",
        "model": {"dims": [d_in, d_h, d_h, classes], "batch": m,
                  "block": block},
        "prune_rate": prune_rate,
        "kept_block_fraction": kept_frac,
        "steps": steps,
        "kernel_step_s": kernel_s,
        "dense_masked_step_s": dense_s,
        "timing_note": "kernel path runs in Pallas INTERPRET mode on this "
                       "CPU container; wall times measure dispatch/python "
                       "overhead, not MXU block-skipping",
        "train_matmul_flops_dense": flops_dense,
        "train_matmul_flops_masked_kernel": flops_masked,
        "flop_reduction": 1.0 - flops_masked / flops_dense,
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_masked_train.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"masked_train: kernel(step, interpret) {kernel_s * 1e3:.1f} ms  "
          f"dense-masked(step) {dense_s * 1e3:.1f} ms")
    print(f"masked_train: analytic train-matmul FLOPs "
          f"{flops_dense / 1e6:.1f}M -> {flops_masked / 1e6:.1f}M "
          f"({rec['flop_reduction'] * 100:.1f}% reduction at prune rate "
          f"{prune_rate})")
    print(f"-> {path}")
    return rec


def bench_masked_lm_train(out_dir: str, *, steps: int = 3,
                          prune_rate: float = 0.5) -> dict:
    """One masked LM TRAINING step on the 128-aligned tiny transformer:
    the Pallas masked-FFN path (``masked_compute="kernel"``: wi/wg routed
    through ``masked_dense`` with the FedAP keep-masks riding the layer
    scan) vs the dense-masked path (``masked_compute="params"``:
    full-density matmuls on elementwise-masked params).

    Same claim split as BENCH_masked_train.json: on this CPU container
    the kernel executes in Pallas INTERPRET mode, so wall times measure
    dispatch overhead — the hardware claim is the analytic FFN-matmul
    FLOP reduction the block-skip kernel realizes on the MXU.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.models.lm import LM

    layers, d_model, d_ff, vocab = 2, 128, 512, 2048
    batch, seq = 4, 16
    model = LM(ModelConfig(name="dense-tiny", family="dense", rope="1d",
                           norm="rmsnorm", act="silu",
                           param_dtype="float32", remat="none",
                           num_layers=layers, d_model=d_model, num_heads=4,
                           num_kv_heads=2, d_ff=d_ff, vocab_size=vocab))
    params = model.init(jax.random.key(0))
    kept = model.decide_kept(params, prune_rate)     # 128-lane-aligned
    fmasks = model.filter_masks(params, kept)
    pmasks = model.param_masks(params, kept)
    kept_frac = int(np.asarray(kept["mlp"]).shape[-1]) / d_ff

    rng = np.random.default_rng(1)
    toks = rng.integers(0, vocab, (batch, seq + 1))
    bdict = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    def loss_kernel(p):
        return model.loss(p, bdict, masks=fmasks)

    def loss_dense(p):
        return model.loss(jax.tree.map(jnp.multiply, p, pmasks), bdict)

    def sgd(loss_fn):
        @jax.jit
        def step(p):
            g = jax.grad(loss_fn)(p)
            return jax.tree.map(lambda pi, gi: pi - 0.01 * gi, p, g)
        return step

    def timed(step):
        p = jax.tree.map(jnp.copy, params)
        p = step(p)                                   # compile
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for _ in range(steps):
            p = step(p)
        jax.block_until_ready(p)
        return (time.perf_counter() - t0) / steps

    kernel_s = timed(sgd(loss_kernel))
    dense_s = timed(sgd(loss_dense))

    # analytic FFN training-matmul FLOPs per step (fwd + dx + dw are each
    # 2*T*K*N MACs): the kernel skips the pruned 128-column blocks of
    # wi/wg; wo stays dense in mask mode (its K-dim rows are zero, not
    # absent).  Attention/embedding matmuls are identical on both paths
    # and excluded from the comparison.
    tokens = batch * seq
    per_matmul = 3 * 2 * tokens * d_model * d_ff
    flops_dense = layers * 3 * per_matmul                # wi + wg + wo
    flops_masked = layers * (2 * kept_frac + 1) * per_matmul

    rec = {
        "bench": "masked_lm_train",
        "model": {"num_layers": layers, "d_model": d_model, "d_ff": d_ff,
                  "vocab_size": vocab, "batch": batch, "seq": seq,
                  "align": 128},
        "prune_rate": prune_rate,
        "kept_unit_fraction": kept_frac,
        "steps": steps,
        "kernel_step_s": kernel_s,
        "dense_masked_step_s": dense_s,
        "timing_note": "kernel path runs in Pallas INTERPRET mode on this "
                       "CPU container; wall times measure dispatch/python "
                       "overhead, not MXU block-skipping",
        "ffn_train_matmul_flops_dense": flops_dense,
        "ffn_train_matmul_flops_masked_kernel": flops_masked,
        "flop_reduction": 1.0 - flops_masked / flops_dense,
        "flop_note": "FFN matmuls only (wi/wg block-skipped, wo dense); "
                     "attention and embedding matmuls are identical on "
                     "both paths",
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_masked_lm_train.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"masked_lm_train: kernel(step, interpret) {kernel_s * 1e3:.1f} ms"
          f"  dense-masked(step) {dense_s * 1e3:.1f} ms")
    print(f"masked_lm_train: analytic FFN train-matmul FLOPs "
          f"{flops_dense / 1e6:.1f}M -> {flops_masked / 1e6:.1f}M "
          f"({rec['flop_reduction'] * 100:.1f}% reduction at prune rate "
          f"{prune_rate})")
    print(f"-> {path}")
    return rec


def bench_serve_decode(out_dir: str, *, requests: int = 12, slots: int = 4,
                       prompt: int = 8, tokens: int = 32,
                       reps: int = 3) -> dict:
    """Continuous-batching decode tokens/s on the 128-aligned tiny LM:
    prune rate {0.0, 0.25, 0.5} x serve mode {dense, masked, shrunk}
    through ``repro.serving.DecodeEngine`` (fixed slot pool, chunked
    prefill, on-device done-mask, one jitted wave program).

    Same claim split as the training benches: on this CPU container the
    flash-decode attention kernel runs in Pallas INTERPRET mode and its
    python dispatch dominates wall time, so tokens/s deltas between modes
    are muted — the hardware claim is the analytic per-token decode
    FFN-matmul FLOP reduction (masked skips pruned wi/wg blocks on the
    MXU; shrunk does compacted-shape matmuls outright).
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.core import pruning_lm
    from repro.models.lm import LM
    from repro.serving import DecodeEngine, ServeConfig

    layers, d_model, d_ff, vocab = 2, 128, 512, 2048
    cfg = ModelConfig(name="dense-tiny", family="dense", rope="1d",
                      norm="rmsnorm", act="silu", param_dtype="float32",
                      remat="none", num_layers=layers, d_model=d_model,
                      num_heads=4, num_kv_heads=2, d_ff=d_ff,
                      vocab_size=vocab)
    model = LM(cfg)
    params0 = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, vocab, size=rng.integers(1, prompt + 1))
               .astype(np.int32) for _ in range(requests)]
    scfg = ServeConfig(slots=slots, cache_len=prompt + tokens,
                       max_prompt=prompt, max_new_tokens=tokens,
                       steps_per_wave=8)

    # per-token decode FFN-matmul FLOPs (2 MACs per weight): wi + wg + wo
    per_matmul = 2 * d_model * d_ff

    def servable(rate, mode):
        """(model, params, masks, kept_frac) for one grid cell — every
        mode serves the SAME pruned checkpoint (zeros at the pruned
        coordinates), differing only in how the zeros are exploited."""
        if rate == 0.0:
            return model, params0, None, 1.0
        kept = model.decide_kept(params0, rate)         # 128-lane-aligned
        kept_frac = int(np.asarray(kept["mlp"]).shape[-1]) / d_ff
        zeroed = jax.tree.map(jnp.multiply, params0,
                              model.param_masks(params0, kept))
        if mode == "dense":
            return model, zeroed, None, kept_frac
        if mode == "masked":
            return model, zeroed, model.filter_masks(params0, kept), kept_frac
        shrunk = pruning_lm.shrink_ffn_at(params0, kept["mlp"])
        d_kept = int(np.asarray(kept["mlp"]).shape[-1])
        return (LM(dataclasses.replace(cfg, d_ff=d_kept)), shrunk, None,
                kept_frac)

    cells = []
    for rate in (0.0, 0.25, 0.5):
        for mode in ("dense", "masked", "shrunk"):
            m, p, masks, kept_frac = servable(rate, mode)
            eng = DecodeEngine(m, p, scfg, masks=masks)
            eng.run(prompts[:1])                        # compile both programs
            elapsed, generated = float("inf"), 0
            for _ in range(reps):                       # best-of-reps: the
                t0 = time.perf_counter()                # timed region is ms-
                completions = eng.run(prompts)          # scale on this box
                # engine.run host-syncs every wave, so the clock reads
                # after the final wave's device work completed
                elapsed = min(elapsed, time.perf_counter() - t0)
                generated = sum(len(c.tokens) for c in completions)
            if mode == "shrunk":
                flops = layers * 3 * int(kept_frac * per_matmul)
            elif mode == "masked":
                flops = layers * int((2 * kept_frac + 1) * per_matmul)
            else:
                flops = layers * 3 * per_matmul
            cells.append({
                "prune_rate": rate,
                "mode": mode,
                "d_ff_served": int(m.cfg.d_ff),
                "kept_unit_fraction": kept_frac,
                "generated_tokens": generated,
                "elapsed_s": elapsed,
                "tok_per_s": generated / elapsed,
                "programs": eng.program_counts(),
                "ffn_decode_matmul_flops_per_token": flops,
                "flop_reduction": 1.0 - flops / (layers * 3 * per_matmul),
            })
            print(f"serve_decode: rate={rate:<4} mode={mode:<6} "
                  f"{generated} tok in {elapsed:.2f}s "
                  f"({cells[-1]['tok_per_s']:.1f} tok/s)  "
                  f"ffn-flop-cut {cells[-1]['flop_reduction'] * 100:.0f}%")

    by = {(c["prune_rate"], c["mode"]): c for c in cells}
    rec = {
        "bench": "serve_decode",
        "model": {"num_layers": layers, "d_model": d_model, "d_ff": d_ff,
                  "vocab_size": vocab, "align": 128},
        "serving": {"requests": requests, "slots": slots,
                    "max_prompt": prompt, "max_new_tokens": tokens,
                    "steps_per_wave": scfg.steps_per_wave},
        "cells": cells,
        "shrunk_speedup_at_0.5":
            by[(0.5, "shrunk")]["tok_per_s"] / by[(0.5, "dense")]["tok_per_s"],
        "timing_note": "flash-decode attention runs in Pallas INTERPRET "
                       "mode on this CPU container; its python dispatch "
                       "dominates wall time, muting tokens/s deltas "
                       "between serve modes — the hardware claim is the "
                       "analytic FFN-matmul FLOP column",
        "flop_note": "per-token decode FFN matmuls only (masked: wi/wg "
                     "block-skipped, wo dense; shrunk: all three at the "
                     "compacted d_ff); attention and embedding matmuls "
                     "are identical across modes",
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / "BENCH_serve_decode.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"serve_decode: shrunk/dense tokens/s at rate 0.5 = "
          f"{rec['shrunk_speedup_at_0.5']:.2f}x")
    print(f"-> {path}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", choices=list(VARIANTS))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--fl-engine", action="store_true",
                    help="rounds/sec: python-loop driver vs. scan engine")
    ap.add_argument("--fedap-plan", action="store_true",
                    help="rounds/sec: masked-FedAP plan vs. legacy hook path "
                         "vs. masked-then-shrink")
    ap.add_argument("--mesh-backend", action="store_true",
                    help="rounds/sec: LocalScanBackend vs. client-sharded "
                         "MeshBackend (forces 8 virtual devices)")
    ap.add_argument("--mesh-server-eval", action="store_true",
                    help="per-round server-update/eval time: batch-sharded "
                         "vs replicated on the mesh, + the shrink "
                         "compaction round-trip (forces 8 virtual devices)")
    ap.add_argument("--masked-train", action="store_true",
                    help="training step: Pallas masked-matmul kernel vs. "
                         "dense-masked, + analytic FLOP reduction")
    ap.add_argument("--masked-lm-train", action="store_true",
                    help="LM training step on the 128-aligned tiny "
                         "transformer: masked-FFN kernel path vs. "
                         "dense-masked params, + analytic FLOP reduction")
    ap.add_argument("--guarded-train", action="store_true",
                    help="rounds/sec: in-scan health guard off vs "
                         "reject_client vs skip_round on the local backend")
    ap.add_argument("--serve-decode", action="store_true",
                    help="continuous-batching decode tokens/s: prune rate "
                         "{0, 0.25, 0.5} x serve mode {dense, masked, "
                         "shrunk} through the DecodeEngine")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the per-benchmark default round count")
    ap.add_argument("--out", default="benchmarks/results/perf")
    args = ap.parse_args()

    if args.mesh_backend or args.mesh_server_eval:
        # must precede the first jax import — same rule as the dry-run;
        # APPEND so a user's pre-existing XLA_FLAGS can't silently turn
        # this into a 1-device "mesh"
        flag = "--xla_force_host_platform_device_count=8"
        if flag not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = \
                (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
        if args.mesh_backend:
            bench_mesh_backend(args.out, rounds=args.rounds or 12)
        else:
            bench_mesh_server_eval(args.out, rounds=args.rounds or 8)
        return
    if args.fl_engine:
        bench_fl_engine(args.out, num_rounds=args.rounds or 30)
        return
    if args.fedap_plan:
        bench_fedap_plan(args.out)
        return
    if args.masked_train:
        bench_masked_train(args.out)
        return
    if args.masked_lm_train:
        bench_masked_lm_train(args.out)
        return
    if args.guarded_train:
        bench_guarded_train(args.out, rounds=args.rounds or 12)
        return
    if args.serve_decode:
        bench_serve_decode(args.out)
        return
    if not (args.arch and args.shape and args.variant):
        ap.error("--arch/--shape/--variant are required unless one of "
                 "--fl-engine/--fedap-plan/--mesh-backend/"
                 "--mesh-server-eval/--masked-train/--masked-lm-train/"
                 "--guarded-train/--serve-decode is given")

    spec = VARIANTS[args.variant]
    for k, v in spec.get("env", {}).items():
        os.environ[k] = v

    # XLA device count must be set before jax import — same as dryrun
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.configs import _ARCHS  # noqa: F401  (triggers config import)
    import repro.configs as C
    from repro.launch import dryrun as D

    cfg_overrides = dict(spec.get("cfg", {}))
    moe_overrides = dict(spec.get("moe", {}))
    if cfg_overrides or moe_overrides:
        # monkey-patch get_config so dryrun_pair sees the variant config
        base_get = C.get_config

        def patched(name):
            cfg = base_get(name)
            if moe_overrides and cfg.moe:
                cfg = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
            if cfg_overrides:
                cfg = dataclasses.replace(cfg, **cfg_overrides)
            return cfg

        C.get_config = patched
        D.get_config = patched

    rec = D.dryrun_pair(args.arch, args.shape, multi_pod=args.multipod)
    rec["variant"] = args.variant
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    mesh = "2x16x16" if args.multipod else "16x16"
    path = out / f"{args.arch}__{args.shape}__{mesh}__{args.variant}.json"
    path.write_text(json.dumps(rec, indent=2))
    t = rec["roofline"]
    print(f"{args.variant}: compute {t['compute_s']:.2f}s  memory "
          f"{t['memory_s']:.2f}s  collective {t['collective_s']:.2f}s  "
          f"bottleneck={t['bottleneck']}  flops/dev={rec['hlo_flops_per_device']:.3e}")


if __name__ == "__main__":
    main()
