"""Paper-faithful FL experiments (EXPERIMENTS.md §Paper-validation).

Reproduces the paper's evaluation protocol on the synthetic CIFAR-10
substitute (DESIGN.md §2): 100 devices, 10 sampled/round, E=5, B=10,
lr=0.1 decayed 0.99/round, server data p * 40000 drawn from a held-out
pool, pruning at round 30.

  PYTHONPATH=src python -m benchmarks.paper_experiments --suite main
  PYTHONPATH=src python -m benchmarks.paper_experiments --suite ablations

The heterogeneity scenario matrix (client algorithm x Dirichlet skew x
participation/stragglers, both backends) is a separate grid runner:

  PYTHONPATH=src python -m benchmarks.paper_experiments --grid smoke --backend mesh
  PYTHONPATH=src python -m benchmarks.paper_experiments --grid full --backend both

Writes one JSON per run into benchmarks/results/paper/ (the grid writes
one combined BENCH_scenario_matrix.json).  Every cell trains on its OWN
key derived from (base_seed, cell_index) via ``jax.random.fold_in`` —
rerunning a grid reproduces it array-exactly.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
import zlib
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FedAPConfig,
    FedDUConfig,
    FedDynConfig,
    FedProxConfig,
    FederatedTrainer,
    TrainPlan,
    baselines,
    fedap_plan,
    feddumap_config,
    niid,
)
from repro.core.rounds import FLConfig
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import LeNet5, SimpleCNN

OUT = Path("benchmarks/results/paper")


def _cell_seed(base_seed: int, cell_index: int) -> int:
    """The per-cell seed: fold the cell index into the base key.  Every
    grid cell gets its own deterministic key chain instead of all cells
    silently reusing the raw base seed."""
    key = jax.random.fold_in(jax.random.key(base_seed), cell_index)
    return int(jax.random.bits(key, dtype=jnp.uint32))

# Scaled-down paper protocol (1-core CPU): 100 clients, 10/round, E=5, B=10.
NUM_CLIENTS = 100
ROUNDS = 60
SPEC = SyntheticSpec(num_classes=10, image_shape=(10, 10, 3),
                     train_size=13000, test_size=2000, noise_scale=0.45)
DEVICE_POOL = 10000
COMMON = dict(num_clients=NUM_CLIENTS, clients_per_round=10, local_epochs=5,
              batch_size=10, lr=0.1, lr_decay=0.99)


def make_model(name: str):
    if name == "cnn":
        return SimpleCNN(num_classes=10, image_shape=SPEC.image_shape)
    if name == "lenet":
        return LeNet5(num_classes=10, image_shape=SPEC.image_shape)
    raise ValueError(name)


def run_one(tag: str, *, model_name="cnn", algo="fedavg", p=0.05,
            server_niid="iid", rounds=ROUNDS, seed=0, cell_index=None,
            feddu_overrides=None, prune_round=30, static_tau=None,
            backend="local", out_dir: Path = OUT):
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{tag}.json"
    if path.exists():
        print(f"[skip] {tag}")
        return json.loads(path.read_text())
    t0 = time.time()
    # per-cell key threading: a suite cell trains on fold_in(base, cell),
    # never the raw base seed shared across every run (tag-hash fallback
    # keeps ad-hoc single runs distinct too)
    base_seed = seed
    if cell_index is None:
        cell_index = zlib.crc32(tag.encode())
    seed = _cell_seed(base_seed, cell_index)
    data = build_federated_data(num_clients=NUM_CLIENTS, server_fraction=p,
                                server_niid=server_niid, device_pool=DEVICE_POOL,
                                spec=SPEC, seed=seed)
    model = make_model(model_name)
    feddu = FedDUConfig(**(feddu_overrides or {}),
                        **({"static_tau_eff": static_tau} if static_tau else {}))
    # Paper-faithful FedAP re-materializes the model (the device-FLOP shrink
    # of Tables 6-9) -> Prune(mode="shrink"); the in-scan masked variant is
    # benchmarked separately (perf_iter --fedap-plan).
    apcfg = FedAPConfig(prune_round=prune_round, probe_size=32, participants=6)
    plan = TrainPlan.standard(rounds, eval_every=2)

    if algo == "fedavg":
        cfg = baselines.fedavg_config(**COMMON, seed=seed)
    elif algo == "feddu":
        cfg = baselines.feddu_config(**COMMON, seed=seed, feddu=feddu)
    elif algo == "feddum":
        cfg = feddumap_config(**COMMON, seed=seed, feddu=feddu)
    elif algo == "serverm":
        cfg = baselines.server_momentum_config(**COMMON, seed=seed, feddu=feddu)
    elif algo == "devicem":
        cfg = baselines.device_momentum_config(**COMMON, seed=seed, feddu=feddu)
    elif algo == "fedda":
        cfg = baselines.fedda_config(**COMMON, seed=seed, feddu=feddu)
    elif algo == "datasharing":
        data = baselines.apply_data_sharing(data, np.random.default_rng(seed))
        cfg = baselines.fedavg_config(**COMMON, seed=seed)
    elif algo == "hybridfl":
        data = baselines.apply_hybrid_fl(data)
        cfg = baselines.fedavg_config(
            **{**COMMON, "num_clients": NUM_CLIENTS + 1}, seed=seed)
    elif algo in ("feddf", "fedkt"):
        cfg = baselines.fedavg_config(**COMMON, seed=seed)
        hook = baselines.make_distillation_round_end(
            model, data, mode=algo, steps=10, batch=32, seed=seed)
        plan = TrainPlan.with_callback(rounds, hook, eval_every=2)
    elif algo in ("imc", "prunefl"):
        cfg = baselines.fedavg_config(**COMMON, seed=seed)
        hook = baselines.make_unstructured_pruning_hook(
            rate=0.5, prune_round=prune_round,
            refresh_every=10 if algo == "prunefl" else None)
        plan = TrainPlan.with_callback(rounds, hook, eval_every=2)
    elif algo == "hrank":
        cfg = baselines.fedavg_config(**COMMON, seed=seed)
        hook = baselines.make_hrank_pruning_hook(
            model, data, rate=0.4, prune_round=prune_round, probe=32)
        plan = TrainPlan.with_callback(rounds, hook, eval_every=2)
    elif algo == "fedap":
        cfg = baselines.fedavg_config(**COMMON, seed=seed, fedap=apcfg)
        plan = fedap_plan(rounds, prune_round=prune_round, mode="shrink",
                          eval_every=2)
    elif algo == "fedduap":   # FedDU + FedAP, no momentum
        cfg = baselines.feddu_config(**COMMON, seed=seed, feddu=feddu,
                                     fedap=apcfg)
        plan = fedap_plan(rounds, prune_round=prune_round, mode="shrink",
                          eval_every=2)
    elif algo == "feddumap":  # the full method
        cfg = feddumap_config(**COMMON, seed=seed, feddu=feddu, fedap=apcfg)
        plan = fedap_plan(rounds, prune_round=prune_round, mode="shrink",
                          eval_every=2)
    else:
        raise ValueError(algo)

    trainer = FederatedTrainer(model, data, cfg, backend=backend)
    init_params = model.init(jax.random.key(seed))
    flops_before = model.flops_per_example(init_params, SPEC.image_shape)
    res = trainer.run(plan)
    params, hist = res.params, res.history
    flops_after = model.flops_per_example(params, SPEC.image_shape) \
        if algo in ("fedap", "fedduap", "feddumap", "hrank") else flops_before

    rec = {
        "tag": tag, "algo": algo, "model": model_name, "p": p,
        "server_niid": server_niid, "rounds": rounds, "seed": seed,
        "base_seed": base_seed, "cell_index": cell_index,
        "final_acc": hist["acc"][-1],
        "best_acc": max(hist["acc"]),
        "history": hist,
        "mflops_before": flops_before / 1e6,
        "mflops_after": flops_after / 1e6,
        "wall_s": time.time() - t0,
    }
    prune_art = res.artifacts.get("prune")
    if prune_art is not None:
        rec["fedap"] = {"p_star": prune_art["p_star"],
                        "layer_rates": prune_art["layer_rates"],
                        "kept_counts": prune_art["kept_counts"]}
    path.write_text(json.dumps(rec))
    print(f"[done] {tag}: acc={rec['final_acc']:.3f} best={rec['best_acc']:.3f} "
          f"({rec['wall_s']:.0f}s)", flush=True)
    return rec


def suite_main():
    """The paper's Table 10/12 comparison on the CNN model."""
    for algo in ["fedavg", "feddu", "feddum", "fedap", "fedduap", "feddumap",
                 "datasharing", "hybridfl", "serverm", "devicem", "fedda",
                 "feddf", "fedkt", "imc", "prunefl", "hrank"]:
        run_one(f"main_cnn_{algo}", algo=algo, p=0.05)


def suite_p_sweep():
    """Figure 2: FedDU with p in {1%, 5%, 10%}."""
    for p in [0.01, 0.05, 0.10]:
        run_one(f"psweep_feddu_p{int(p * 100)}", algo="feddu", p=p)


def suite_ablations():
    """Tables 2-5: tau_eff static vs dynamic, f'(acc), C, server non-IID."""
    for tau in [5, 10, 20]:
        run_one(f"abl_static_tau{tau}", algo="feddu", static_tau=float(tau))
    run_one("abl_fprime_inv", algo="feddu",
            feddu_overrides={"f_prime_kind": "inv"})
    for c in [0.5, 1.5]:
        run_one(f"abl_C{c}", algo="feddu", feddu_overrides={"C": c})
    for kind in ["iid", "mild", "severe"]:
        run_one(f"abl_server_{kind}", algo="feddu", server_niid=kind)


def suite_lenet():
    for algo in ["fedavg", "feddu", "feddumap"]:
        run_one(f"lenet_{algo}", model_name="lenet", algo=algo, p=0.05)


# ---------------------------------------------------------------------------
# Heterogeneity scenario matrix: client algorithm x Dirichlet skew x
# participation/stragglers, on both execution backends
# ---------------------------------------------------------------------------

SCEN_CLIENTS = 16
SCEN_SPEC = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                          train_size=2600, test_size=400, noise_scale=0.45)
SCEN_POOL = 2000
SCEN_COMMON = dict(num_clients=SCEN_CLIENTS, local_epochs=1, batch_size=10,
                   lr=0.08, lr_decay=0.98, server_batch_size=16)
SCEN_MU, SCEN_FEDDYN_ALPHA = 0.01, 0.01


def scenario_cells(grid: str):
    """The grid: 3 algorithms x Dirichlet alpha x (clients_per_round,
    dropout_rate).  ``smoke`` is the CI gate (one scenario per algorithm,
    2 rounds); ``full`` is the recorded BENCH matrix."""
    algos = ("fedavg", "fedprox", "feddyn")
    if grid == "smoke":
        alphas, participation, rounds = (0.5,), ((4, 0.25),), 2
    elif grid == "full":
        alphas = (0.1, 0.5, 100.0)
        participation = ((8, 0.0), (4, 0.0), (8, 0.25))
        rounds = 8
    else:
        raise ValueError(grid)
    cells = [dict(algo=a, dirichlet_alpha=al, clients_per_round=c,
                  dropout_rate=d)
             for a in algos for al in alphas for c, d in participation]
    return cells, rounds


def _scenario_config(cell: dict, seed: int) -> FLConfig:
    common = dict(SCEN_COMMON, clients_per_round=cell["clients_per_round"],
                  dropout_rate=cell["dropout_rate"], seed=seed)
    if cell["algo"] == "fedavg":
        return baselines.fedavg_config(**common)
    if cell["algo"] == "fedprox":
        return baselines.fedprox_config(
            **common, fedprox=FedProxConfig(mu=SCEN_MU))
    if cell["algo"] == "feddyn":
        return baselines.feddyn_config(
            **common, feddyn=FedDynConfig(alpha=SCEN_FEDDYN_ALPHA))
    raise ValueError(cell["algo"])


def run_scenario_cell(cell: dict, *, rounds: int, backend: str = "local",
                      base_seed: int = 0, cell_index: int = 0) -> dict:
    seed = _cell_seed(base_seed, cell_index)
    data = build_federated_data(
        num_clients=SCEN_CLIENTS, server_fraction=0.1, device_pool=SCEN_POOL,
        spec=SCEN_SPEC, partition="dirichlet",
        dirichlet_alpha=cell["dirichlet_alpha"], seed=seed)
    p_bar = niid.global_distribution(data.client_dists, data.sizes)
    degree = float(np.mean(np.asarray(
        niid.non_iid_degree(data.client_dists, p_bar))))
    model = SimpleCNN(num_classes=10, image_shape=SCEN_SPEC.image_shape,
                      channels=(4, 8, 8), fc_width=16)
    cfg = _scenario_config(cell, seed)
    t0 = time.time()
    res = FederatedTrainer(model, data, cfg, backend=backend).run(
        TrainPlan.standard(rounds, eval_every=1))
    return {**cell, "backend": backend, "rounds": rounds,
            "base_seed": base_seed, "cell_index": cell_index, "seed": seed,
            "mean_niid_degree": degree,
            "final_acc": float(res.history["acc"][-1]),
            "final_loss": float(res.history["loss"][-1]),
            "history": {k: [float(v) for v in vs]
                        for k, vs in res.history.items()},
            "wall_s": time.time() - t0}


def suite_scenario_matrix(grid: str = "smoke", backends=("local",),
                          base_seed: int = 0, out_dir: Path = OUT):
    cells, rounds = scenario_cells(grid)
    recs = []
    for backend in backends:
        for i, cell in enumerate(cells):
            rec = run_scenario_cell(cell, rounds=rounds, backend=backend,
                                    base_seed=base_seed, cell_index=i)
            print(f"[grid] {backend} {cell['algo']} "
                  f"alpha={cell['dirichlet_alpha']} "
                  f"C={cell['clients_per_round']} "
                  f"drop={cell['dropout_rate']} "
                  f"d={rec['mean_niid_degree']:.3f} "
                  f"acc={rec['final_acc']:.3f} ({rec['wall_s']:.0f}s)",
                  flush=True)
            recs.append(rec)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "BENCH_scenario_matrix.json"
    path.write_text(json.dumps({"grid": grid, "rounds": rounds,
                                "base_seed": base_seed, "cells": recs},
                               indent=1))
    print(f"[done] scenario matrix -> {path} ({len(recs)} cells)")
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["main", "psweep", "ablations", "lenet", "all"])
    ap.add_argument("--grid", default=None, choices=["smoke", "full"],
                    help="run the heterogeneity scenario matrix instead of "
                         "the paper suites")
    ap.add_argument("--backend", default="local",
                    choices=["local", "mesh", "both"])
    ap.add_argument("--base-seed", type=int, default=0)
    args = ap.parse_args()
    t0 = time.time()
    if args.grid:
        backends = ("local", "mesh") if args.backend == "both" \
            else (args.backend,)
        suite_scenario_matrix(args.grid, backends, args.base_seed)
        print(f"total {time.time() - t0:.0f}s")
        return
    if args.suite in ("main", "all"):
        suite_main()
    if args.suite in ("psweep", "all"):
        suite_p_sweep()
    if args.suite in ("ablations", "all"):
        suite_ablations()
    if args.suite in ("lenet", "all"):
        suite_lenet()
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
