"""Quickstart: FedDUMAP in ~40 lines.

Builds a small federated world (20 non-IID clients + shared server data),
trains the paper's CNN with the full method (FedDU dynamic server update +
FedDUM two-sided momentum + FedAP adaptive pruning at round 6) under a
declarative TrainPlan, and prints the accuracy trajectory and the dynamic
tau_eff schedule.

Pruning uses the static-shape MASK mode: the FedAP keep-masks enter the
scan carry at the Prune event, so all 10 rounds run inside compiled scan
chunks — no re-jit.  Swap mode="shrink" to re-materialize a genuinely
smaller model instead.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import FedAPConfig, FederatedTrainer, fedap_plan, feddumap_config
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import SimpleCNN
from repro.utils import tree_size


def main():
    spec = SyntheticSpec(num_classes=10, image_shape=(10, 10, 3),
                         train_size=5200, test_size=800, noise_scale=0.5)
    data = build_federated_data(num_clients=20, server_fraction=0.08,
                                device_pool=4000, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(10, 10, 3))

    # min_rate: a compression-budget floor — the pure eigen-gap rule can
    # decide "prune nothing" on this easy synthetic task
    fedap = FedAPConfig(prune_round=6, probe_size=16, participants=4,
                        min_rate=0.3)
    cfg = feddumap_config(num_clients=20, clients_per_round=5, local_epochs=2,
                          batch_size=10, lr=0.08, fedap=fedap)
    trainer = FederatedTrainer(model, data, cfg)

    plan = fedap_plan(10, prune_round=fedap.prune_round, mode="mask")
    res = trainer.run(plan)

    print("\nround  acc     tau_eff")
    for r, a, t in zip(res.history["round"], res.history["acc"],
                       res.history["tau_eff"]):
        print(f"{r:>5}  {a:.3f}  {t:8.3f}")

    prune = res.artifacts["prune"]
    live = sum(int(jnp.sum(m)) for m in jax.tree.leaves(res.state["masks"]))
    print(f"\nFedAP: global rate p*={prune['p_star']:.3f}, kept filters "
          f"{prune['kept_counts']}")
    print(f"masked params {live:,} live of {tree_size(res.params):,} "
          f"(static shapes — every round ran inside the compiled scan)")


if __name__ == "__main__":
    main()
