"""Quickstart: FedDUMAP in ~40 lines.

Builds a small federated world (20 non-IID clients + shared server data),
trains the paper's CNN with the full method (FedDU dynamic server update +
FedDUM two-sided momentum + FedAP adaptive pruning at round 6), and prints
the accuracy trajectory and the dynamic tau_eff schedule.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import FedAPConfig, FederatedTrainer, feddumap_config
from repro.core.fedap import make_fedap_hook
from repro.data import build_federated_data
from repro.data.synthetic import SyntheticSpec
from repro.models import SimpleCNN
from repro.utils import tree_size


def main():
    spec = SyntheticSpec(num_classes=10, image_shape=(10, 10, 3),
                         train_size=5200, test_size=800, noise_scale=0.5)
    data = build_federated_data(num_clients=20, server_fraction=0.08,
                                device_pool=4000, spec=spec)
    model = SimpleCNN(num_classes=10, image_shape=(10, 10, 3))

    fedap = FedAPConfig(prune_round=6, probe_size=16)
    cfg = feddumap_config(num_clients=20, clients_per_round=5, local_epochs=2,
                          batch_size=10, lr=0.08, fedap=fedap)
    trainer = FederatedTrainer(model, data, cfg)

    init_params = model.init(jax.random.key(0))
    hook = make_fedap_hook(model, data, fedap, init_params=init_params,
                           participants=4)
    params, hist = trainer.run(10, on_round_end=hook)

    print("\nround  acc     tau_eff")
    for r, a, t in zip(hist["round"], hist["acc"], hist["tau_eff"]):
        print(f"{r:>5}  {a:.3f}  {t:8.3f}")
    print(f"\nFedAP: global rate p*={hook.result['p_star']:.3f}, "
          f"params {tree_size(init_params):,} -> {tree_size(params):,}")


if __name__ == "__main__":
    main()
