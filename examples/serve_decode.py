"""Serving demo: continuous-batching decode of (pruned) checkpoints.

For the scanned-KV families (dense / moe) this drives
``repro.serving.DecodeEngine``: a fixed pool of decode slots, requests
admitted as slots free up, prompts chunk-prefilled through the same
lockstep step, finished sequences retired via the on-device done-mask.
``--prune-rate`` serves a FedAP-style pruned model either ``masked``
(block-skipping masked_matmul at dense shapes) or ``shrunk`` (compacted
d_ff) — the FLOP cut the paper claims, measured at the tokens/s level.

  PYTHONPATH=src python examples/serve_decode.py --arch llama3-405b \\
      --requests 8 --slots 4 --tokens 16 --prune-rate 0.5 --serve-mode shrunk

Other families (encdec / ssm / hybrid / vlm) fall back to the plain
lockstep batch-decode loop through ``decode_step``:

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b --tokens 32
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models.api import build_model


def serve_continuous(cfg, args):
    """Engine path: continuous batching, optional pruned serving."""
    from repro.core import pruning_lm
    from repro.models.lm import LM
    from repro.serving import DecodeEngine, ServeConfig

    rng = np.random.default_rng(args.seed)
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    masks = None
    tag = "dense"
    if args.prune_rate > 0 and cfg.family != "dense":
        raise SystemExit("--prune-rate prunes the scanned FFN stack; use a "
                         "dense-family --arch")
    if args.prune_rate > 0:
        kept = pruning_lm.ffn_kept_indices(params, cfg, args.prune_rate,
                                           align=128)
        if args.serve_mode == "masked":
            masks = model.filter_masks(params, {"mlp": kept})
            # zero the pruned coordinates as mask-mode training would have
            params = jax.tree.map(
                lambda p, m: p * m, params,
                model.param_masks(params, {"mlp": kept}))
            tag = f"masked@{args.prune_rate}"
        else:
            params = pruning_lm.shrink_ffn_at(params, kept)
            cfg = dataclasses.replace(cfg, d_ff=int(np.asarray(kept).shape[-1]))
            model = LM(cfg)
            tag = f"shrunk@{args.prune_rate} (d_ff={cfg.d_ff})"

    scfg = ServeConfig(slots=args.slots,
                       cache_len=args.prompt + args.tokens,
                       max_prompt=args.prompt, max_new_tokens=args.tokens,
                       steps_per_wave=args.steps_per_wave)
    engine = DecodeEngine(model, params, scfg, masks=masks)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=rng.integers(1, args.prompt + 1))
               .astype(np.int32) for _ in range(args.requests)]

    # warm-up wave compiles the two programs outside the timed region
    engine.submit(prompts[0])
    while engine.pending:
        engine.step_wave()

    t0 = time.perf_counter()
    completions = engine.run(prompts)
    # engine.run host-syncs every wave (np.asarray on the done-mask), so
    # the clock reads AFTER the final wave's device work completed
    elapsed = time.perf_counter() - t0

    generated = sum(len(c.tokens) for c in completions)
    print(f"arch={cfg.name} (reduced, {tag}) slots={args.slots} "
          f"requests={args.requests} programs={engine.program_counts()}")
    print(f"{generated} tokens in {elapsed:.2f}s "
          f"({generated / elapsed:.1f} tok/s continuous batching)")
    print("sample:", completions[0].tokens[:16].tolist())


def serve_lockstep(cfg, args):
    """Legacy path for families without per-slot cache indices: every
    sequence at the same depth, one jitted decode_step in a host loop."""
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    cache_len = args.prompt + args.tokens
    cache = model.init_cache(args.slots, cache_len)
    batch_extra = {}
    if cfg.family == "encdec":
        batch_extra["enc_embeds"] = jnp.asarray(
            rng.standard_normal((args.slots, cfg.encoder.frames, cfg.d_model)),
            jnp.float32)
        cache = model.prefill_cross(params, cache, batch_extra)

    decode = jax.jit(model.decode_step)
    prompt = rng.integers(0, cfg.vocab_size, (args.slots, args.prompt))

    def step_input(tok):
        if cfg.family == "vlm":
            return {"embeds": jax.nn.one_hot(tok[:, 0], cfg.d_model,
                                             dtype=jnp.float32)[:, None]}
        return {"tokens": tok.astype(jnp.int32), **batch_extra}

    # prefill by stepping the prompt through the cache
    t0 = time.perf_counter()
    for t in range(args.prompt):
        logits, cache = decode(params, cache,
                               step_input(jnp.asarray(prompt[:, t:t + 1])))
    jax.block_until_ready(logits)       # time execution, not dispatch
    prefill_s = time.perf_counter() - t0

    # greedy decode
    out = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(args.tokens):
        logits, cache = decode(params, cache, step_input(tok))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(tok[:, 0])
    gen = np.stack(jax.block_until_ready(out), 1)
    decode_s = time.perf_counter() - t0

    print(f"arch={cfg.name} (reduced) batch={args.slots}")
    print(f"prefill {args.prompt} tok: {prefill_s:.2f}s; "
          f"decode {args.tokens} tok: {decode_s:.2f}s "
          f"({args.slots * args.tokens / decode_s:.1f} tok/s)")
    print("sample:", gen[0][:16].tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-405b", choices=list(ARCH_NAMES))
    ap.add_argument("--requests", type=int, default=8,
                    help="queued requests (engine path)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-slot pool (engine) / batch (lockstep)")
    ap.add_argument("--prompt", type=int, default=16,
                    help="max prompt length")
    ap.add_argument("--tokens", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--steps-per-wave", type=int, default=8)
    ap.add_argument("--prune-rate", type=float, default=0.0,
                    help="FedAP-style FFN prune rate (engine path)")
    ap.add_argument("--serve-mode", default="shrunk",
                    choices=("masked", "shrunk"),
                    help="how to serve the pruned model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family in ("dense", "moe"):
        serve_continuous(cfg, args)
    else:
        serve_lockstep(cfg, args)


if __name__ == "__main__":
    main()
