"""Serving demo: batched autoregressive decode with a KV cache.

Instantiates a reduced variant of any assigned architecture (--arch), runs
a short prefill, then decodes tokens for a batch of requests through the
same ``decode_step`` the decode_32k / long_500k dry-runs lower.

  PYTHONPATH=src python examples/serve_decode.py --arch chatglm3-6b --tokens 32
  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=list(ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    cache_len = args.prefill + args.tokens
    cache = model.init_cache(args.batch, cache_len)
    batch_extra = {}
    if cfg.family == "encdec":
        batch_extra["enc_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder.frames, cfg.d_model)),
            jnp.float32)
        cache = model.prefill_cross(params, cache, batch_extra)

    decode = jax.jit(model.decode_step)
    prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prefill))

    # prefill by stepping the prompt through the cache (simple serving path)
    tok = None
    t0 = time.time()
    for t in range(args.prefill):
        step = {"tokens": jnp.asarray(prompt[:, t:t + 1], jnp.int32), **(
            batch_extra if cfg.family == "encdec" else {})}
        if cfg.family == "vlm":
            step = {"embeds": jnp.asarray(
                rng.standard_normal((args.batch, 1, cfg.d_model)) * 0.1,
                jnp.float32)}
        logits, cache = decode(params, cache, step)
    prefill_s = time.time() - t0

    # greedy decode
    out = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(args.tokens):
        step = {"tokens": tok.astype(jnp.int32), **(
            batch_extra if cfg.family == "encdec" else {})}
        if cfg.family == "vlm":
            step = {"embeds": jax.nn.one_hot(tok, cfg.d_model, dtype=jnp.float32)}
        logits, cache = decode(params, cache, step)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(np.asarray(tok[:, 0]))
    decode_s = time.time() - t0
    gen = np.stack(out, 1)

    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"prefill {args.prefill} tok: {prefill_s:.2f}s; "
          f"decode {args.tokens} tok: {decode_s:.2f}s "
          f"({args.batch * args.tokens / decode_s:.1f} tok/s)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
