"""Scenario: the paper's CIFAR-10 protocol end-to-end, one algorithm.

  PYTHONPATH=src python examples/fl_paper_repro.py --algo feddumap --rounds 30

This is a thin CLI over benchmarks/paper_experiments.run_one; it reproduces
one cell of the paper's Tables 10/12 on the synthetic CIFAR substitute
(100 clients, 10/round, E=5, B=10, p=5% server data, prune at round 30).
"""
import argparse
from pathlib import Path

import benchmarks.paper_experiments as PE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="feddumap",
                    choices=["fedavg", "feddu", "feddum", "fedap", "fedduap",
                             "feddumap", "datasharing", "hybridfl", "serverm",
                             "devicem", "fedda", "feddf", "fedkt", "imc",
                             "prunefl", "hrank"])
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--p", type=float, default=0.05)
    ap.add_argument("--backend", default="local", choices=["local", "mesh"],
                    help="execution backend: single-host scan, or the "
                         "client-sharded device mesh (same numerics; run "
                         "with XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 to simulate a mesh on CPU)")
    ap.add_argument("--out", default="/tmp/fl_paper_repro")
    args = ap.parse_args()
    tag = (f"example_{args.algo}" if args.backend == "local"
           else f"example_{args.algo}_{args.backend}")
    rec = PE.run_one(tag, algo=args.algo, p=args.p,
                     rounds=args.rounds, prune_round=min(args.rounds // 2, 30),
                     backend=args.backend, out_dir=Path(args.out))
    accs = rec["history"]["acc"]
    print(f"\n{args.algo}: final acc {rec['final_acc']:.3f}; trajectory "
          f"{[round(a, 3) for a in accs[:: max(1, len(accs) // 8)]]}")
    print(f"device MFLOPs {rec['mflops_before']:.2f} -> {rec['mflops_after']:.2f}")


if __name__ == "__main__":
    main()
