"""End-to-end driver: federated training of a transformer LM with FedDUMAP.

This runs the SAME pod-scale FL train step that the multi-pod dry-run
lowers (repro.launch.steps.make_fl_train_step) on this host's devices, with
a small dense LM over synthetic topic-skewed token streams: 4 clients with
non-IID topic mixtures + IID server data, restart-SGDM locally, FedDU
dynamic server update + FedDUM server momentum every round.

  PYTHONPATH=src python examples/fl_llm_train.py --rounds 50 --scale 25m

--scale 100m trains a ~100M-parameter model (slow on CPU; the default 25m
finishes in minutes).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import niid
from repro.data.synthetic import TokenSpec, synthetic_tokens
from repro.launch.steps import FLRunConfig, make_fl_train_step

SCALES = {
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 d_ff=512, vocab_size=2048),
    "25m": dict(num_layers=6, d_model=512, num_heads=8, num_kv_heads=4,
                d_ff=2048, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--scale", default="25m", choices=list(SCALES))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"dense-{args.scale}", family="dense",
                      rope="1d", norm="rmsnorm", act="silu",
                      param_dtype="float32", remat="none",
                      **SCALES[args.scale])
    run = FLRunConfig(lr=3e-3, local_steps=args.local_steps, server_tau=1,
                      server_batch=args.batch)
    init_state, train_step = make_fl_train_step(cfg, run, args.clients)
    train_step = jax.jit(train_step)

    # topic-skewed client corpora: client k sees mostly topics {k, k+1}
    tokens, topics = synthetic_tokens(TokenSpec(
        vocab_size=cfg.vocab_size, num_topics=args.clients * 2,
        seq_len=args.seq + 1, num_sequences=4096))
    per_client = []
    dists = []
    for k in range(args.clients):
        mask = np.isin(topics, [2 * k, 2 * k + 1])
        per_client.append(tokens[mask])
        dists.append(np.bincount(topics[mask], minlength=args.clients * 2))
    dists = np.stack(dists).astype(np.float32)
    dists /= dists.sum(1, keepdims=True)
    sizes = np.asarray([len(c) for c in per_client], np.float32)
    p_bar = niid.global_distribution(jnp.asarray(dists), jnp.asarray(sizes))
    d_server = float(niid.non_iid_degree(
        jnp.asarray(np.bincount(topics, minlength=args.clients * 2)
                    / len(topics), jnp.float32), p_bar))
    d_round = float(jnp.mean(jnp.stack(
        [niid.non_iid_degree(jnp.asarray(d), p_bar) for d in dists])))

    rng = np.random.default_rng(0)
    state = init_state(jax.random.key(0))

    def sample_round():
        def batch_from(pool, lead):
            idx = rng.integers(0, len(pool), lead + (args.batch,))
            seqs = pool[idx]
            return {"tokens": jnp.asarray(seqs[..., :-1]),
                    "labels": jnp.asarray(seqs[..., 1:])}

        client = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[batch_from(per_client[k], (args.local_steps,))
              for k in range(args.clients)])
        server = batch_from(tokens, (run.server_tau,))
        return {"client": client, "server": server,
                "sizes": jnp.asarray(sizes),
                "d_round": jnp.float32(d_round),
                "d_server": jnp.float32(d_server),
                "n0": jnp.float32(len(tokens))}

    t0 = time.time()
    for r in range(args.rounds):
        state, t_eff = train_step(state, sample_round())
        if r % 5 == 0 or r == args.rounds - 1:
            # eval loss on held-out server batch
            from repro.models.api import build_model
            model = build_model(cfg)
            b = sample_round()["server"]
            loss = model.loss(state["params"],
                              jax.tree.map(lambda x: x[0], b))
            print(f"round {r:>3}  loss {float(loss):.4f}  "
                  f"tau_eff {float(t_eff):.3f}  ({time.time() - t0:.0f}s)",
                  flush=True)


if __name__ == "__main__":
    main()
