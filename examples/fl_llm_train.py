"""Federated LM fine-tuning on the unified executor: TrainPlan in, RunResult out.

The transformer LM runs the SAME TrainPlan/PlanExecutor stack as the CNN
repro — one driver for both model families:

  * :func:`repro.data.pipeline.build_lm_federated_data` transplants the
    paper's Section-4.1 protocol to a next-token corpus (sequences
    label-shard partitioned by TOPIC over the clients, IID-controllable
    server pool, held-out test split);
  * :class:`repro.models.lm.LM` plugs into the executor through the
    simulation-model contract (``loss_and_acc(params, x, y, masks=)``),
    so ``FederatedTrainer`` drives it over the local scan backend or —
    ``--backend mesh`` — client-sharded over a device mesh, unchanged;
  * ``--prune-round K`` schedules FedAP as a first-class ``Prune`` event
    (:func:`repro.core.plan.fedap_plan`): the layer-adaptive decision
    (Fisher eigen-gap rates -> Formula 15 -> uniform 128-lane-aligned
    FFN-unit selection, ``core.pruning_lm``) is injected as keep-masks
    carried in the scan — structure fixed from round 0, zero re-jit —
    or re-materializes the smaller stack with ``--prune-mode shrink``;
  * ``--masked-compute kernel`` additionally routes the masked FFN
    matmuls through the differentiable Pallas ``masked_matmul`` kernel
    (pruned 128-column blocks skipped on the MXU; set
    ``REPRO_PALLAS_INTERPRET=1`` on CPU).

Examples::

  PYTHONPATH=src python examples/fl_llm_train.py --rounds 20 --scale tiny
  PYTHONPATH=src python examples/fl_llm_train.py --rounds 10 \
      --prune-round 5 --prune-mode mask
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python examples/fl_llm_train.py --rounds 4 --backend mesh

--scale 25m/100m train larger models (slow on CPU; tiny finishes in
seconds per round).
"""
import argparse

from repro.configs.base import ModelConfig
from repro.core.plan import TrainPlan, fedap_plan
from repro.core.pruning import FedAPConfig
from repro.core.rounds import FederatedTrainer, feddumap_config
from repro.data.pipeline import build_lm_federated_data
from repro.data.synthetic import TokenSpec
from repro.models.lm import LM

SCALES = {
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 d_ff=512, vocab_size=2048),
    "25m": dict(num_layers=6, d_model=512, num_heads=8, num_kv_heads=4,
                d_ff=2048, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--backend", default="local", choices=("local", "mesh"))
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sequences", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--prune-round", type=int, default=0,
                    help="0 = no FedAP event")
    ap.add_argument("--prune-mode", default="mask",
                    choices=("mask", "shrink"))
    ap.add_argument("--masked-compute", default="params",
                    choices=("params", "kernel"))
    ap.add_argument("--prune-floor", type=float, default=0.5,
                    help="FedAPConfig.min_rate compression-budget floor")
    args = ap.parse_args()

    mcfg = ModelConfig(name=f"dense-{args.scale}", family="dense",
                       rope="1d", norm="rmsnorm", act="silu",
                       param_dtype="float32", remat="none",
                       **SCALES[args.scale])
    model = LM(mcfg)
    data = build_lm_federated_data(
        num_clients=args.clients,
        spec=TokenSpec(vocab_size=mcfg.vocab_size,
                       num_topics=2 * args.clients,
                       seq_len=args.seq + 1,
                       num_sequences=args.sequences))

    cfg = feddumap_config(
        num_clients=args.clients,
        clients_per_round=args.clients_per_round,
        local_epochs=args.local_epochs,
        batch_size=args.batch,
        server_batch_size=2 * args.batch,
        lr=3e-3, lr_decay=1.0,
        masked_compute=args.masked_compute,
        # the FFN stack prunes at the 128-lane boundary (core.pruning_lm's
        # uniform kept count); the floor guarantees a visible compression
        fedap=FedAPConfig(align=128, min_rate=args.prune_floor,
                          probe_size=8,
                          participants=min(4, args.clients)))
    trainer = FederatedTrainer(model, data, cfg, backend=args.backend)

    if args.prune_round:
        plan = fedap_plan(args.rounds, prune_round=args.prune_round,
                          mode=args.prune_mode, eval_every=args.eval_every)
    else:
        plan = TrainPlan.standard(args.rounds, eval_every=args.eval_every)

    res = trainer.run(plan)
    for r, loss, acc, tau, dt in zip(res.history["round"],
                                     res.history["loss"],
                                     res.history["acc"],
                                     res.history["tau_eff"],
                                     res.history["time"]):
        print(f"round {r:>3}  loss {loss:.4f}  token-acc {acc:.4f}  "
              f"tau_eff {tau:.3f}  ({dt:.0f}s)", flush=True)
    if args.prune_round:
        art = res.artifacts["prune"]
        print(f"FedAP: p*={art['p_star']:.3f}  "
              f"kept={art['kept_counts']}  mode={art['mode']}")


if __name__ == "__main__":
    main()
