"""Static trace-safety analysis for the repro engine.

Three coordinated checkers guard the invariants the paper's efficiency
claims hang on (one compiled program per shape, no host syncs in the scan,
disciplined PRNG key chains):

  * :mod:`repro.analysis.lint` — stdlib-``ast`` lint (rules R1-R5) over
    ``src/``, ``examples/`` and ``benchmarks/``;
  * :mod:`repro.analysis.compile_budget` — runs the canonical TrainPlans
    under a jit-cache counter and diffs lowered-program counts against the
    checked-in ``compile_budget.json`` baseline;
  * :mod:`repro.analysis.hlo_lint` — lowers the engine chunk and asserts
    HLO-level invariants (no f64 leaks, no collectives in the local
    program, no host callbacks in scan bodies, mesh all-reduce budget).

Run all three with ``python -m repro.analysis`` (exit 0 == clean).
"""

from repro.analysis.lint import Violation, lint_paths, lint_source  # noqa: F401
