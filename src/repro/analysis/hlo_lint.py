"""HLO invariant checker — lowers the engine chunk and inspects the
optimized program text (via :class:`repro.launch.hlo_cost.HloCostModel`).

Checked invariants:

* **No f64 ops** anywhere in the f32 training graph (an accidental
  float64 promotion silently doubles bandwidth and falls off the fast
  unit paths);
* **No collectives** in the :class:`LocalScanBackend` program — the
  single-device scan must be communication-free (checked for BOTH
  canonical worlds: the CNN chunk and the transformer-LM chunk, whose
  layer scan carries the FFN keep-masks as zipped xs);
* **Guarded chunk stays clean** — with ``EngineConfig.guard`` on, the
  in-scan health guard (finiteness checks, rejected-client scrubbing,
  round discard) must be pure device data-flow: no host callbacks, no
  f64 promotion, no collectives in the local program;
* **No host callbacks / infeed / outfeed** inside any lowered program —
  a `io_callback`/`debug.print` smuggled into the scan body would stall
  every round on the host; checked for the serving wave program too,
  where it IS the continuous-batching "no per-token host sync" claim
  (the done-mask is read once per wave, after the launch);
* **Mesh all-reduce budget** (needs >= 2 devices): the per-round
  all-reduce count matches the PR 5 design — one *logical* all-reduce
  per tau server step (physically one per parameter leaf, inside the
  trip-``tau`` while loop) plus one *logical* FedAvg aggregation
  (physically per-leaf, direct in the round body) plus the fixed metric
  reductions.  The measured physical counts are recorded in
  ``compile_budget.json`` under ``"hlo"`` (the same single source of
  truth the compile-budget sentinel uses); any NEW collective in the
  round body fails the diff naming the loop it appeared in.

Regenerate the recorded counts after an intentional engine change with::

    PYTHONPATH=src python -m repro.analysis.hlo_lint --update
"""
from __future__ import annotations

import re
from typing import Any

from repro.analysis.compile_budget import (
    BUDGET_PATH,
    load_budget,
    make_world,
    _fresh_model,
)

_F64 = re.compile(r"\bf64\[")
_HOST_OPS = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\],{}()\s/]*"
                       r"(infeed|outfeed|send|recv)\(", re.M)
_HOST_CUSTOM = re.compile(r'custom_call_target="([^"]*'
                          r'(?:callback|host|outside_compilation)[^"]*)"',
                          re.I)
_TRIP = re.compile(r'known_trip_count[":{\\]+n[":\\]+(\d+)')

# Round length used when lowering: distinct from the canonical world's
# server_tau (2) and local step count (8) so the round loop is the unique
# entry-level while with this trip count.
CHUNK_LEN = 3


def f64_ops(hlo_text: str) -> int:
    """Number of f64-typed tensor references in the program text."""
    return len(_F64.findall(hlo_text))


def host_callbacks(hlo_text: str) -> list[str]:
    """Infeed/outfeed/send/recv ops and host-callback custom-calls."""
    out = [m.group(1) for m in _HOST_OPS.finditer(hlo_text)]
    out += [f"custom-call:{t}" for t in _HOST_CUSTOM.findall(hlo_text)]
    return out


# ---------------------------------------------------------------------------
# Structural all-reduce accounting over HloCostModel's computation table


def _direct_counts(cm, comp: str, opcode: str) -> int:
    return sum(1 for i in cm.comps.get(comp, [])
               if i.opcode.startswith(opcode))


def _whiles(cm, comp: str) -> list[tuple[str, int]]:
    out = []
    for i in cm.comps.get(comp, []):
        if i.opcode != "while":
            continue
        body = re.search(r"body=%?([\w.\-]+)", i.line)
        tm = _TRIP.search(i.line)
        if body:
            out.append((body.group(1), int(tm.group(1)) if tm else 1))
    return out


def _weighted_count(cm, comp: str, opcode: str) -> int:
    """Trip-count-weighted op count over the computation subtree."""
    total = _direct_counts(cm, comp, opcode)
    for body, trip in _whiles(cm, comp):
        total += trip * _weighted_count(cm, body, opcode)
    return total


def mesh_all_reduce_profile(cm, *, length: int, server_tau: int) -> dict:
    """Locate the round loop (the unique entry-level while with trip ==
    ``length``) and the tau server loop inside it; return the physical
    all-reduce counts at each level."""
    entry = cm.entry
    round_bodies = [(b, t) for b, t in _whiles(cm, entry) if t == length]
    if len(round_bodies) != 1:
        raise AssertionError(
            f"expected exactly one entry-level while with trip={length} "
            f"(the round scan); found {round_bodies}")
    round_body = round_bodies[0][0]
    tau_loops = [(b, t) for b, t in _whiles(cm, round_body)
                 if t == server_tau and _weighted_count(cm, b, "all-reduce")]
    return {
        "entry_all_reduce": _direct_counts(cm, entry, "all-reduce"),
        "round_body_all_reduce": _direct_counts(cm, round_body,
                                                "all-reduce"),
        "tau_body_all_reduce": (
            _weighted_count(cm, tau_loops[0][0], "all-reduce")
            if tau_loops else 0),
        "tau_loops_with_all_reduce": len(tau_loops),
        "per_round_all_reduce": _weighted_count(cm, round_body,
                                                "all-reduce"),
    }


# ---------------------------------------------------------------------------
# Lowering the canonical chunks


def _lower_chunk(backend_name: str, world=None, *, kind: str = "cnn",
                 use_masks: bool = False,
                 guard: str = "off") -> tuple[str, dict]:
    """Optimized HLO text of the canonical chunk + the world's sample_kw."""
    import dataclasses as _dc

    import jax

    from repro.core import FederatedTrainer

    data, cfg = world if world is not None else make_world(kind)
    if guard != "off":
        cfg = _dc.replace(cfg, guard=guard)
    model = _fresh_model(kind)
    tr = FederatedTrainer(model, data, cfg, backend=backend_name)
    be = tr.backend(use_masks=use_masks)
    state = be.init_state(model.init(jax.random.key(cfg.seed)))
    d = be.device_data()
    key = jax.random.key(cfg.seed + 1)
    if backend_name == "mesh":
        from jax.sharding import NamedSharding, PartitionSpec as P

        key = jax.device_put(key, NamedSharding(be.mesh, P()))
    txt = be.chunk.lower(state, key, d, length=CHUNK_LEN).compile().as_text()
    return txt, dict(be.sample_kw)


def _lower_serving(*, masked: bool = False) -> str:
    """Optimized HLO text of the serving wave program (the lax.scan of
    ``steps_per_wave`` continuous-batching decode steps over the
    flash-decode kernel)."""
    import jax
    import jax.numpy as jnp

    from repro.serving import DecodeEngine, ServeConfig

    model = _fresh_model("lm")
    params = model.init(jax.random.key(0))
    masks = None
    if masked:
        kept = model.decide_kept(params, 0.5)
        masks = model.filter_masks(params, kept)
        params = jax.tree.map(jnp.multiply, params,
                              model.param_masks(params, kept))
    eng = DecodeEngine(model, params,
                       ServeConfig(slots=2, cache_len=12, max_prompt=4,
                                   max_new_tokens=4, steps_per_wave=2),
                       masks=masks)
    return eng.lower_wave().compile().as_text()


def check(budget: dict | None = None, world=None) -> list[str]:
    """Run every HLO invariant; returns failure messages (empty == ok)."""
    import jax

    from repro.launch import hlo_cost

    budget = budget if budget is not None else load_budget()
    recorded = budget.get("hlo", {})
    errors: list[str] = []
    if world is None:
        world = make_world()

    # ---- local program: f64 / collectives / host callbacks ----------------
    txt, _ = _lower_chunk("local", world)
    if f64_ops(txt):
        errors.append(f"local chunk: {f64_ops(txt)} f64 tensor reference(s) "
                      f"leaked into the f32 training graph")
    cbs = host_callbacks(txt)
    if cbs:
        errors.append(f"local chunk: host callback ops in lowered program: "
                      f"{cbs}")
    cm = hlo_cost.HloCostModel(txt)
    coll = dict(cm.entry_cost().collective_counts)
    if coll:
        errors.append(f"local chunk: collectives in the single-device scan "
                      f"program: {coll}")

    # ---- guarded local program: the in-scan health guard (finiteness
    # checks + scrubbing + round discard) must be pure device data-flow —
    # no host callbacks (a host-side NaN check would stall every round),
    # no f64 (the guard compares in the training dtype), no collectives --
    txt_g, _ = _lower_chunk("local", world, guard="reject_client")
    if f64_ops(txt_g):
        errors.append(f"guarded local chunk: {f64_ops(txt_g)} f64 tensor "
                      f"reference(s) leaked in by the health guard")
    cbs = host_callbacks(txt_g)
    if cbs:
        errors.append(f"guarded local chunk: host callback ops in lowered "
                      f"program (the guard must not sync to host): {cbs}")
    coll_g = dict(
        hlo_cost.HloCostModel(txt_g).entry_cost().collective_counts)
    if coll_g:
        errors.append(f"guarded local chunk: collectives in the "
                      f"single-device scan program: {coll_g}")

    # ---- LM local program: the transformer chunk (layer scan carrying
    # the FFN keep-masks) must stay collective-free and clean too --------
    txt_lm, _ = _lower_chunk("local", kind="lm", use_masks=True)
    if f64_ops(txt_lm):
        errors.append(f"LM local chunk: {f64_ops(txt_lm)} f64 tensor "
                      f"reference(s) leaked into the f32 training graph")
    cbs = host_callbacks(txt_lm)
    if cbs:
        errors.append(f"LM local chunk: host callback ops in lowered "
                      f"program: {cbs}")
    coll_lm = dict(
        hlo_cost.HloCostModel(txt_lm).entry_cost().collective_counts)
    if coll_lm:
        errors.append(f"LM local chunk: collectives in the single-device "
                      f"scan program: {coll_lm}")

    # ---- serving wave program: the continuous-batching decode scan is
    # the "no per-token host sync" claim at the HLO level — no host
    # callbacks (the done-mask is read AFTER the wave, not inside it),
    # no f64, no collectives on a mesh-less engine -----------------------
    for label, masked in (("serving wave", False),
                          ("serving wave (masked)", True)):
        txt_sv = _lower_serving(masked=masked)
        if f64_ops(txt_sv):
            errors.append(f"{label}: {f64_ops(txt_sv)} f64 tensor "
                          f"reference(s) leaked into the f32 decode graph")
        cbs = host_callbacks(txt_sv)
        if cbs:
            errors.append(f"{label}: host callback ops inside the wave "
                          f"program (per-token host syncs): {cbs}")
        coll_sv = dict(
            hlo_cost.HloCostModel(txt_sv).entry_cost().collective_counts)
        if coll_sv:
            errors.append(f"{label}: collectives in the mesh-less decode "
                          f"program: {coll_sv}")

    # ---- mesh program: all-reduce budget (needs a real mesh) --------------
    if len(jax.devices()) < 2:
        # On one device GSPMD elides every collective; the CI job supplies
        # 8 virtual devices.  Not a failure — the local checks above ran.
        print("repro.analysis.hlo_lint: mesh all-reduce budget skipped "
              "(single device; set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return errors

    txt, sample_kw = _lower_chunk("mesh", world)
    if f64_ops(txt):
        errors.append(f"mesh chunk: {f64_ops(txt)} f64 tensor reference(s)")
    cbs = host_callbacks(txt)
    if cbs:
        errors.append(f"mesh chunk: host callback ops: {cbs}")

    cm = hlo_cost.HloCostModel(txt)
    try:
        prof = mesh_all_reduce_profile(cm, length=CHUNK_LEN,
                                       server_tau=sample_kw["server_tau"])
    except AssertionError as e:
        return errors + [f"mesh chunk: {e}"]

    # PR 5 design: >= one all-reduce per tau step (the sharded server
    # scan's partial-grad reduction) and >= one aggregation all-reduce
    # direct in the round body (FedAvg), regardless of recorded numbers.
    if prof["tau_loops_with_all_reduce"] != 1:
        errors.append(
            f"mesh chunk: expected exactly one trip-{sample_kw['server_tau']}"
            f" server loop carrying all-reduces inside the round body, "
            f"found {prof['tau_loops_with_all_reduce']} "
            f"(the sharded FedDU server scan lost its per-step reduction?)")
    if prof["round_body_all_reduce"] < 1:
        errors.append("mesh chunk: no FedAvg aggregation all-reduce in the "
                      "round body")

    want = recorded.get("mesh")
    if want is None:
        errors.append("mesh all-reduce counts missing from "
                      "compile_budget.json ['hlo']['mesh'] — run "
                      "python -m repro.analysis.hlo_lint --update")
        return errors
    for field in ("entry_all_reduce", "round_body_all_reduce",
                  "tau_body_all_reduce", "per_round_all_reduce"):
        if prof[field] != want[field]:
            where = {"entry_all_reduce": "outside the round loop",
                     "round_body_all_reduce":
                         "direct in the round body (FedAvg aggregation + "
                         "metric reductions)",
                     "tau_body_all_reduce":
                         "inside the tau server loop (per-step partial-grad "
                         "reduction)",
                     "per_round_all_reduce": "per round (total)"}[field]
            errors.append(
                f"mesh chunk: {prof[field]} all-reduce(s) {where}, "
                f"recorded budget says {want[field]} — an unbudgeted "
                f"collective changes every round's critical path "
                f"(profile={prof})")
    return errors


def update(world=None) -> dict:
    """Measure the mesh all-reduce profile and record it in
    compile_budget.json under ['hlo']."""
    import json

    import jax

    from repro.launch import hlo_cost

    if len(jax.devices()) < 2:
        raise SystemExit("--update needs >= 2 devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    txt, sample_kw = _lower_chunk("mesh", world)
    cm = hlo_cost.HloCostModel(txt)
    prof = mesh_all_reduce_profile(cm, length=CHUNK_LEN,
                                   server_tau=sample_kw["server_tau"])
    txt_lm, _ = _lower_chunk("local", kind="lm", use_masks=True)
    lm_coll = dict(
        hlo_cost.HloCostModel(txt_lm).entry_cost().collective_counts)
    txt_g, _ = _lower_chunk("local", world, guard="reject_client")
    g_coll = dict(
        hlo_cost.HloCostModel(txt_g).entry_cost().collective_counts)
    sv_coll = dict(hlo_cost.HloCostModel(
        _lower_serving()).entry_cost().collective_counts)
    svm_coll = dict(hlo_cost.HloCostModel(
        _lower_serving(masked=True)).entry_cost().collective_counts)
    budget = load_budget()
    budget["hlo"] = {
        "_comment": [
            "Physical all-reduce counts in the mesh chunk, lowered at",
            f"length={CHUNK_LEN} on {len(jax.devices())} devices.",
            "Design (PR 5): one LOGICAL all-reduce per tau server step",
            "(tau_body, physically one per param leaf + the loss/acc",
            "reduction) + one LOGICAL FedAvg aggregation (round_body,",
            "per leaf + metric reductions).",
        ],
        "mesh": {k: v for k, v in prof.items()},
        "local": {"collectives": 0},
        "guarded_local": {"collectives": sum(g_coll.values())},
        "lm_local": {"collectives": sum(lm_coll.values())},
        "serving": {"collectives": sum(sv_coll.values())},
        "serving_masked": {"collectives": sum(svm_coll.values())},
    }
    with open(BUDGET_PATH, "w") as f:
        json.dump(budget, f, indent=2)
        f.write("\n")
    return budget


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="repro.analysis.hlo_lint",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="re-measure the mesh all-reduce profile into "
                         "compile_budget.json")
    args = ap.parse_args(argv)

    if args.update:
        budget = update()
        print(f"recorded: {budget['hlo']['mesh']}")
        return 0

    errors = check()
    for e in errors:
        print(f"FAIL {e}")
    print(f"repro.analysis.hlo_lint: {len(errors)} violation(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
