"""``python -m repro.analysis`` — run all three checkers; exit 0 == clean.

Order: AST lint (pure host, fast) -> compile-budget sentinel -> HLO
invariant checker.  The mesh budget needs multiple devices, so when no
device-count flag is configured we force 8 virtual CPU devices BEFORE jax
is imported (the same setting as the CI ``static-analysis`` job).
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

# Must precede any jax import (the checkers import jax lazily, so setting
# it here at module import time is early enough).
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]


def _lint_roots() -> list[str]:
    roots = []
    for name in ("src/repro", "examples", "benchmarks"):
        p = _REPO_ROOT / name
        if p.exists():
            roots.append(str(p))
    return roots or [str(_REPO_ROOT)]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="trace-safety lint + compile-budget + HLO invariants")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-budget", action="store_true")
    ap.add_argument("--skip-hlo", action="store_true")
    args = ap.parse_args(argv)

    failures = 0

    if not args.skip_lint:
        from repro.analysis import lint

        violations = lint.lint_paths(_lint_roots())
        for v in violations:
            print(v)
        print(f"[1/3] lint: {len(violations)} violation(s)")
        failures += len(violations)
    else:
        print("[1/3] lint: skipped")

    # One world shared by the two dynamic checkers (data build is the
    # expensive part; models stay per-scenario for fresh jit caches).
    world = None
    if not (args.skip_budget and args.skip_hlo):
        from repro.analysis.compile_budget import make_world

        world = make_world()

    if not args.skip_budget:
        from repro.analysis import compile_budget

        errors = compile_budget.check(world=world)
        for e in errors:
            print(f"FAIL {e}")
        print(f"[2/3] compile_budget: {len(errors)} violation(s)")
        failures += len(errors)
    else:
        print("[2/3] compile_budget: skipped")

    if not args.skip_hlo:
        from repro.analysis import hlo_lint

        errors = hlo_lint.check(world=world)
        for e in errors:
            print(f"FAIL {e}")
        print(f"[3/3] hlo_lint: {len(errors)} violation(s)")
        failures += len(errors)
    else:
        print("[3/3] hlo_lint: skipped")

    print(f"repro.analysis: {'CLEAN' if not failures else 'FAILED'} "
          f"({failures} total violation(s))")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
