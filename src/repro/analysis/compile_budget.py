"""Compile-budget sentinel: the zero-re-lowering contract, enforced.

The engine's efficiency story requires that a TrainPlan compiles exactly
one scan-chunk program per distinct (chunk length x parameter shape)
combination — prune-mask events, snapshots, callbacks and evals must add
ZERO chunk traces, and a shrink event exactly ONE (the post-shrink
shapes).  This module runs the canonical plans (Scan / Eval / Prune-mask /
Prune-shrink / Snapshot, on both the local scan backend and the
client-sharded mesh backend, for the CNN *and* the transformer-LM
worlds) under a jit-cache counter and diffs the
lowered-program counts against the checked-in ``compile_budget.json``
baseline.  Any unexpected re-trace fails naming the scenario and the plan
event after which the count jumped.

The ``serving/*`` scenarios extend the contract to inference: a
continuous-batching ``repro.serving.DecodeEngine`` session compiles
exactly TWO programs (admit + wave) and re-traces neither across
admissions, retirements and slot reuse — for dense, masked and shrunk
checkpoints alike.

``compile_budget.json`` is the single source of truth for expected program
counts: ``tests/test_plan.py`` and ``tests/test_mesh_backend.py`` assert
against :func:`expected_programs` instead of inline magic numbers.

Regenerate the baseline after an *intentional* budget change with::

    PYTHONPATH=src python -m repro.analysis.compile_budget --update
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Callable

BUDGET_PATH = pathlib.Path(__file__).with_name("compile_budget.json")


def load_budget(path: pathlib.Path | str | None = None) -> dict:
    with open(path or BUDGET_PATH) as f:
        return json.load(f)


def expected_programs(scenario: str,
                      path: pathlib.Path | str | None = None) -> int:
    """Expected chunk-program count for a named scenario (test entry
    point — replaces the former inline ``_cache_size() == N`` numbers)."""
    return int(load_budget(path)["scenarios"][scenario]["programs"])


# ---------------------------------------------------------------------------
# Canonical worlds and plans


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    backend: str                       # "local" | "mesh"
    plan_factory: Callable[[], Any]    # () -> TrainPlan (kind="plan" only)
    masked_compute: str = "params"
    world: str = "cnn"                 # "cnn" | "lm" (make_world kind)
    kind: str = "plan"                 # "plan" | "serving"
    serve_mode: str = "dense"          # serving: dense | masked | shrunk
    guard: str = "off"                 # EngineConfig.guard health-guard mode
    note: str = ""


def _plans():
    from repro.core import Eval, Prune, Scan, Snapshot, TrainPlan

    return {
        # one chunk length, no prune: exactly one program
        "scan_eval": lambda: TrainPlan(
            Eval(), Scan(2), Eval(), Scan(2), Eval()),
        # mask-mode prune swaps carry contents only: still one program
        "prune_mask": lambda: TrainPlan(
            Eval(), Scan(2), Eval(), Prune(mode="mask"), Snapshot(),
            Scan(2), Eval()),
        # shrink re-materializes shapes: exactly one extra program
        "prune_shrink": lambda: TrainPlan(
            Scan(2), Prune(mode="shrink"), Scan(2), Eval()),
        # mask now, compact later (momentum-preserving): pre- + post-shrink
        "mask_then_shrink": lambda: TrainPlan(
            Scan(2), Prune(mode="mask"), Scan(2),
            Prune(mode="shrink", reuse="prune", name="shrink"),
            Scan(2), Eval()),
        # snapshots/callback-free plan with a second distinct chunk length
        "two_chunk_lengths": lambda: TrainPlan(
            Scan(2), Snapshot(), Scan(1), Eval()),
    }


def scenarios() -> list[Scenario]:
    out = []
    for backend in ("local", "mesh"):
        for pname, factory in _plans().items():
            out.append(Scenario(f"{backend}/{pname}", backend, factory))
        out.append(Scenario(f"{backend}/prune_mask_kernel", backend,
                            _plans()["prune_mask"],
                            masked_compute="kernel",
                            note="masked_compute=kernel routes matmuls "
                                 "through the Pallas masked kernel"))
        # The LM leg of the zero-re-lowering contract: the FedAP FFN-unit
        # keep-masks ride the layer scan as zipped xs, so a mask-mode
        # Prune event on the transformer must add ZERO chunk programs —
        # same budget as the CNN, same plan, different model family.
        out.append(Scenario(f"{backend}/lm_prune_mask", backend,
                            _plans()["prune_mask"], world="lm",
                            note="transformer LM; FFN keep-masks carried "
                                 "in the layer scan"))
        out.append(Scenario(f"{backend}/lm_prune_mask_kernel", backend,
                            _plans()["prune_mask"],
                            masked_compute="kernel", world="lm",
                            note="transformer LM with the masked FFN "
                                 "matmuls routed through the Pallas "
                                 "masked kernel"))
    # The reliability leg of the contract: the in-scan health guards
    # (finiteness checks + rejected-client scrubbing + round discard) are
    # pure data-flow inside round_core — turning them on must add ZERO
    # chunk programs over the guard-off scan_eval budget, on both
    # backends.
    for backend in ("local", "mesh"):
        for guard in ("reject_client", "skip_round"):
            out.append(Scenario(
                f"{backend}/guard_{guard.split('_')[0]}", backend,
                _plans()["scan_eval"], guard=guard,
                note=f"guard={guard!r} health guard on: finiteness "
                     f"checks and round discard ride the one chunk "
                     f"program — zero extra traces"))
    # The serving leg of the contract: the continuous-batching
    # DecodeEngine compiles exactly TWO programs — _admit (one slot
    # write) and _wave (the step scan) — and re-traces NEITHER across
    # admissions, retirements and slot reuse, for dense, masked and
    # shrunk checkpoints alike.
    for mode in ("dense", "masked", "shrunk"):
        out.append(Scenario(
            f"serving/decode_{mode}", "local", None, world="lm",
            kind="serving", serve_mode=mode,
            note=f"DecodeEngine over a {mode} checkpoint: admit + wave "
                 f"programs, zero re-traces across admission waves"))
    return out


def make_world(kind: str = "cnn"):
    """The canonical tiny world for ``kind``:

    * ``"cnn"`` — mirrors the tier-1 fixtures: 8 clients, 8x8x3
      synthetic data (drives a (4,8,8)-channel SimpleCNN);
    * ``"lm"`` — the tiny next-token corpus: 8 clients, topic
      label-shard partitioned 16-token sequences (drives a 2-layer
      d_model=128 transformer with a 128-lane-aligned d_ff=512 FFN).
    """
    from repro.core import FedAPConfig, feddumap_config

    if kind == "lm":
        from repro.data.pipeline import build_lm_federated_data
        from repro.data.synthetic import TokenSpec

        data = build_lm_federated_data(
            num_clients=8,
            spec=TokenSpec(vocab_size=2048, num_topics=16, seq_len=17,
                           num_sequences=256))
        apcfg = FedAPConfig(prune_round=2, align=128, probe_size=4,
                            participants=2, min_rate=0.5)
        cfg = feddumap_config(num_clients=8, clients_per_round=4,
                              local_epochs=1, batch_size=4,
                              server_batch_size=8, lr=3e-3, lr_decay=1.0,
                              fedap=apcfg)
        return data, cfg
    if kind != "cnn":
        raise ValueError(f"unknown world kind {kind!r}")
    from repro.data import build_federated_data
    from repro.data.synthetic import SyntheticSpec

    spec = SyntheticSpec(num_classes=10, image_shape=(8, 8, 3),
                         train_size=1700, test_size=100, noise_scale=0.5)
    data = build_federated_data(num_clients=8, server_fraction=0.1,
                                device_pool=640, spec=spec)
    apcfg = FedAPConfig(prune_round=2, probe_size=8, participants=7,
                        min_rate=0.5)
    cfg = feddumap_config(num_clients=8, clients_per_round=8, local_epochs=1,
                          batch_size=10, lr=0.05, fedap=apcfg)
    return data, cfg


def _fresh_model(kind: str = "cnn"):
    """A NEW model instance per scenario: the session compile cache is
    keyed on the model object, so each scenario gets a zeroed jit-cache
    counter."""
    if kind == "lm":
        from repro.configs.base import ModelConfig
        from repro.models.lm import LM

        return LM(ModelConfig(name="dense-tiny", family="dense", rope="1d",
                              norm="rmsnorm", act="silu",
                              param_dtype="float32", remat="none",
                              num_layers=2, d_model=128, num_heads=4,
                              num_kv_heads=2, d_ff=512, vocab_size=2048))
    from repro.models import SimpleCNN

    return SimpleCNN(num_classes=10, image_shape=(8, 8, 3),
                     channels=(4, 8, 8), fc_width=16)


# ---------------------------------------------------------------------------
# Recording execution


class _RecordingBackend:
    """Delegating ExecutionBackend wrapper that samples the chunk
    jit-cache size after every plan event."""

    def __init__(self, inner):
        self._inner = inner
        self.timeline: list[tuple[str, int]] = []
        self._n = 0

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def _record(self, label: str):
        self._n += 1
        self.timeline.append(
            (f"event#{self._n}:{label}",
             int(self._inner.chunk._cache_size())))

    def run_chunk(self, state, key, length):
        out = self._inner.run_chunk(state, key, length)
        self._record(f"Scan(rounds={length})")
        return out

    def apply_prune(self, state, mode, kept, **kw):
        out = self._inner.apply_prune(state, mode, kept, **kw)
        self._record(f"Prune(mode={mode!r})")
        return out

    def evaluate(self, state):
        out = self._inner.evaluate(state)
        self._record("Eval")
        return out

    def snapshot(self, state):
        out = self._inner.snapshot(state)
        if self.timeline:    # snapshot also runs inside Callback plumbing
            self._record("Snapshot")
        return out

    def snapshot_artifact(self, state, t):
        # Snapshot plan events go through the donation-aware artifact
        # path, not snapshot(); record them under the same label.
        out = self._inner.snapshot_artifact(state, t)
        self._record("Snapshot")
        return out


@dataclasses.dataclass
class ScenarioResult:
    name: str
    programs: int
    timeline: list[tuple[str, int]]


def _run_serving_scenario(sc: Scenario) -> ScenarioResult:
    """More requests than slots driven through a DecodeEngine; the
    program count (admit + wave jit caches) is sampled after every wave —
    an admission or retirement that re-traced shows up as a count jump at
    the wave it happened in."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import DecodeEngine, ServeConfig

    model = _fresh_model("lm")
    params = model.init(jax.random.key(0))
    masks = None
    if sc.serve_mode != "dense":
        kept = model.decide_kept(params, 0.5)
        if sc.serve_mode == "masked":
            masks = model.filter_masks(params, kept)
            params = jax.tree.map(jnp.multiply, params,
                                  model.param_masks(params, kept))
        else:
            from repro.core import pruning_lm
            from repro.models.lm import LM

            params = pruning_lm.shrink_ffn_at(params, kept["mlp"])
            model = LM(_dc.replace(
                model.cfg, d_ff=int(np.asarray(kept["mlp"]).shape[-1])))
    eng = DecodeEngine(
        model, params,
        ServeConfig(slots=2, cache_len=12, max_prompt=4, max_new_tokens=4,
                    steps_per_wave=4),
        masks=masks)
    rng = np.random.default_rng(0)
    for _ in range(5):     # 5 ragged requests over 2 slots: reuse + ragged
        eng.submit(rng.integers(                       # admission waves
            0, model.cfg.vocab_size,
            size=int(rng.integers(1, 5))).astype(np.int32))
    timeline, wave = [], 0
    while eng.pending:
        eng.step_wave()
        wave += 1
        timeline.append((f"wave#{wave}",
                         sum(eng.program_counts().values())))
    return ScenarioResult(sc.name, sum(eng.program_counts().values()),
                          timeline)


def run_scenario(sc: Scenario, world=None) -> ScenarioResult:
    import dataclasses as _dc

    import jax

    from repro.core import FederatedTrainer
    from repro.core.backend import PlanExecutor

    if sc.kind == "serving":
        return _run_serving_scenario(sc)
    data, cfg = world if world is not None else make_world(sc.world)
    if sc.masked_compute != "params":
        cfg = _dc.replace(cfg, masked_compute=sc.masked_compute)
    if sc.guard != "off":
        cfg = _dc.replace(cfg, guard=sc.guard)
    model = _fresh_model(sc.world)
    plan = sc.plan_factory()
    tr = FederatedTrainer(model, data, cfg, backend=sc.backend)
    be = tr.backend(use_masks=plan.uses_masks)
    rec = _RecordingBackend(be)
    executor = PlanExecutor(rec, trainer=tr)
    params0 = model.init(jax.random.key(cfg.seed))
    executor.run(plan, params=params0, key=jax.random.key(cfg.seed + 1))
    return ScenarioResult(sc.name, int(be.chunk._cache_size()),
                          rec.timeline)


# ---------------------------------------------------------------------------
# Check / update


def check(budget: dict | None = None,
          scenario_list: list[Scenario] | None = None,
          world=None) -> list[str]:
    """Run every scenario and diff against the baseline.  Returns a list
    of failure messages (empty == within budget).  ``world``, when given,
    is the shared CNN world; other world kinds are built on first use."""
    budget = budget if budget is not None else load_budget()
    expected_map = budget["scenarios"]
    errors = []
    results = []
    worlds = {} if world is None else {"cnn": world}
    for sc in (scenario_list if scenario_list is not None else scenarios()):
        if sc.name not in expected_map:
            errors.append(
                f"{sc.name}: scenario missing from compile_budget.json — "
                f"regenerate with --update if this is intentional")
            continue
        if sc.world not in worlds:
            worlds[sc.world] = make_world(sc.world)
        res = run_scenario(sc, world=worlds[sc.world])
        results.append(res)
        want = int(expected_map[sc.name]["programs"])
        if res.programs != want:
            culprit = next(
                (ev for ev, count in res.timeline if count > want), None)
            detail = (f" first exceeded after {culprit}" if culprit
                      else " (fewer programs than budgeted — update the "
                           "baseline if the plan changed)")
            errors.append(
                f"{sc.name}: {res.programs} chunk program(s) lowered, "
                f"budget says {want};{detail}. timeline="
                f"{res.timeline}")
    return errors


def update(path: pathlib.Path | str | None = None) -> dict:
    worlds = {}
    budget = {
        "_comment": [
            "Expected lowered chunk-program counts per canonical plan",
            "scenario — the zero-re-lowering contract.  Checked by",
            "`python -m repro.analysis.compile_budget` and asserted by",
            "tests/test_plan.py + tests/test_mesh_backend.py via",
            "repro.analysis.compile_budget.expected_programs().",
            "Regenerate ONLY for intentional plan/engine changes:",
            "PYTHONPATH=src python -m repro.analysis.compile_budget --update",
        ],
        "scenarios": {},
    }
    old = load_budget(path) if pathlib.Path(path or BUDGET_PATH).exists() \
        else {}
    if "hlo" in old:
        budget["hlo"] = old["hlo"]
    for sc in scenarios():
        if sc.world not in worlds:
            worlds[sc.world] = make_world(sc.world)
        res = run_scenario(sc, world=worlds[sc.world])
        budget["scenarios"][res.name] = {
            "programs": res.programs,
            "timeline": [f"{ev}={count}" for ev, count in res.timeline],
        }
        if sc.note:
            budget["scenarios"][res.name]["note"] = sc.note
    with open(path or BUDGET_PATH, "w") as f:
        json.dump(budget, f, indent=2)
        f.write("\n")
    return budget


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="repro.analysis.compile_budget",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="re-measure and overwrite compile_budget.json")
    args = ap.parse_args(argv)

    if args.update:
        budget = update()
        for name, entry in budget["scenarios"].items():
            print(f"  {name}: {entry['programs']} program(s)")
        print(f"wrote {BUDGET_PATH}")
        return 0

    errors = check()
    for e in errors:
        print(f"FAIL {e}")
    print(f"repro.analysis.compile_budget: "
          f"{len(errors)} violation(s) across {len(scenarios())} scenarios")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
