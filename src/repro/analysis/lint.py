"""Repo-specific trace-safety lint (stdlib ``ast`` only, no new deps).

The rules encode the contracts the engine's efficiency story depends on
(see README "Invariants & static analysis"):

R1  **No PRNG key reuse.**  Every ``jax.random.*`` consumer must receive a
    key produced by ``split``/``fold_in`` in the same scope; a key name may
    be passed to a consumer at most once before being re-bound.  Two
    ``fold_in(key, <const>)`` calls with the *same* constant count as
    reuse; ``fold_in(key, i)`` with a varying operand is the blessed
    derivation pattern.  Additionally, constructing two literal root keys
    (``jax.random.key(0)`` + ``jax.random.key(1)``) in one function is a
    "seed ladder" — derive streams with ``fold_in`` from one base instead.
    Escape: ``# lint: key-reuse-ok``.

R2  **No host syncs in traced code.**  Functions reachable from
    ``engine.round_core``, ``backend.build_chunk`` or any
    ``@jax.jit``-decorated function must not call ``.item()``,
    ``jax.device_get``, ``np.asarray``/``np.array``, or ``float()`` /
    ``int()`` / ``bool()`` on a non-static expression — each forces a
    device->host transfer that stalls the scan.  Reachability is a
    conservative module-level call graph (bare names, ``from m import f``
    and ``module.attr`` calls; attribute/method dispatch is not followed).
    Escape: ``# lint: host-sync-ok``.

R3  **No Python branching on traced values** in the engine/kernels modules
    (``core/engine.py``, ``core/momentum.py``, ``core/server_update.py``,
    ``kernels/*.py``).  A condition is *static* when it is built from
    constants, attribute access (config fields / ``.shape`` / ``.ndim`` /
    ``.dtype``), ``is None`` / ``in`` tests, scalar-annotated or
    constant-defaulted parameters, and locals assigned from such
    expressions.  Anything touching a bare array name (``if x:``,
    ``if jnp.sum(x) > 0:``) re-traces or crashes under ``jit``.
    Escape: ``# lint: static-branch``.

R4  **No bare ``assert`` in ``kernels/``.**  Shape preconditions must raise
    ``ValueError`` naming the offending shapes/blocks (the PR 3
    ``masked_matmul`` precedent); asserts vanish under ``python -O`` and
    carry no shape context.  No escape pragma.

R5  **No mutable default arguments** anywhere, and **no ``jnp.`` calls at
    module import time** (module-level array constants force device
    placement and platform init at import).  Escape: ``# lint:
    import-time-ok`` (import-time half only).

Pragmas are same-line comments: ``... # lint: static-branch``.  Several
tags may share one comment (``# lint: static-branch host-sync-ok``).
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable

RULES = ("R1", "R2", "R3", "R4", "R5")

_PRAGMA_TAGS = {
    "key-reuse-ok": "R1",
    "host-sync-ok": "R2",
    "static-branch": "R3",
    "import-time-ok": "R5",
}

# jax.random constructors/derivers that *produce* keys.
_KEY_MAKERS = {"key", "PRNGKey", "split", "fold_in", "clone", "wrap_key_data"}
# jax.random calls that do NOT consume a key as arg 0.
_NON_CONSUMERS = {"key", "PRNGKey", "key_data", "wrap_key_data", "key_impl"}

# Builtins whose result is host-static regardless of arguments.
_STATIC_CALLS = {"len", "isinstance", "hasattr", "callable", "getattr"}
# Builtins that are static iff every argument is static.
_STATIC_IF_ARGS = {"min", "max", "abs", "bool", "int", "float", "str", "tuple",
                   "sorted", "any", "all", "sum", "range"}
# Dotted calls that read host state at trace time (static by construction).
_STATIC_DOTTED = {"os.environ.get", "os.getenv", "math.sqrt", "math.ceil",
                  "math.floor", "math.log", "math.prod"}

# R3 scope: modules whose bodies run under trace.
_R3_MODULE_RE = re.compile(
    r"(^|/)(kernels/[^/]+\.py|core/engine\.py|core/momentum\.py|"
    r"core/server_update\.py)$")
_R4_MODULE_RE = re.compile(r"(^|/)kernels/[^/]+\.py$")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> str | None:
    """'jax.random.split' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _pragmas(source: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), 1):
        if "lint:" not in line:
            continue
        _, _, tail = line.partition("lint:")
        tags = {t for t in re.findall(r"[a-z][a-z0-9-]*", tail)
                if t in _PRAGMA_TAGS}
        if tags:
            out[i] = tags
    return out


# ---------------------------------------------------------------------------
# Per-module model


@dataclasses.dataclass
class _Func:
    """One analysis unit: a def (top-level, method, or nested)."""
    qualname: str
    node: ast.FunctionDef
    children: list["_Func"] = dataclasses.field(default_factory=list)

    def own_body_nodes(self) -> Iterable[ast.AST]:
        """Walk the unit's body, stopping at nested defs (own units)."""
        stack: list[ast.AST] = list(self.node.body)
        while stack:
            n = stack.pop()
            yield n
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.extend(ast.iter_child_nodes(n))


@dataclasses.dataclass
class _Module:
    path: str                   # display path
    modname: str | None         # dotted module name (src/ files), else None
    tree: ast.Module
    source: str
    pragmas: dict[int, set[str]]
    funcs: list[_Func] = dataclasses.field(default_factory=list)
    # name -> dotted module for `import x as y` / `from pkg import mod`
    mod_aliases: dict[str, str] = dataclasses.field(default_factory=dict)
    # name -> (dotted module, func name) for `from m import f`
    func_imports: dict[str, tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    top_funcs: dict[str, _Func] = dataclasses.field(default_factory=dict)

    def allowed(self, line: int, rule: str) -> bool:
        return any(_PRAGMA_TAGS.get(t) == rule
                   for t in self.pragmas.get(line, ()))


def _collect_funcs(mod: _Module) -> None:
    def visit(node: ast.AST, prefix: str, into: list[_Func]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                f = _Func(qualname=prefix + child.name, node=child)
                into.append(f)
                visit(child, f.qualname + ".", f.children)
            elif isinstance(child, ast.ClassDef):
                visit(child, prefix + child.name + ".", into)
            elif not isinstance(child, (ast.Lambda,)):
                visit(child, prefix, into)

    visit(mod.tree, "", mod.funcs)
    for f in mod.funcs:
        mod.top_funcs.setdefault(f.node.name, f)


def _collect_imports(mod: _Module) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mod.mod_aliases[alias.asname or alias.name.split(".")[0]] = (
                    alias.name)
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for alias in node.names:
                bound = alias.asname or alias.name
                # `from pkg import mod` and `from mod import func` are
                # indistinguishable without the file set; record both and
                # let resolution pick whichever exists.
                mod.mod_aliases.setdefault(bound, f"{node.module}.{alias.name}")
                mod.func_imports[bound] = (node.module, alias.name)


def _parse_module(source: str, path: str, modname: str | None) -> _Module:
    mod = _Module(path=path, modname=modname, tree=ast.parse(source),
                  source=source, pragmas=_pragmas(source))
    _collect_funcs(mod)
    _collect_imports(mod)
    return mod


# ---------------------------------------------------------------------------
# Static-expression classifier (shared by R2 and R3)


def _is_static(node: ast.AST, static_names: set[str]) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id in static_names
    if isinstance(node, ast.Attribute):
        # Attribute access in a branch condition is config fields or array
        # metadata (.shape/.ndim/.dtype) — both trace-static in this repo.
        return True
    if isinstance(node, ast.Subscript):
        return _is_static(node.value, static_names)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_static(e, static_names) for e in node.elts)
    if isinstance(node, ast.UnaryOp):
        return _is_static(node.operand, static_names)
    if isinstance(node, ast.BinOp):
        return (_is_static(node.left, static_names)
                and _is_static(node.right, static_names))
    if isinstance(node, ast.BoolOp):
        return all(_is_static(v, static_names) for v in node.values)
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
               for op in node.ops):
            return True
        return (_is_static(node.left, static_names)
                and all(_is_static(c, static_names) for c in node.comparators))
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name):
            if fn.id in _STATIC_CALLS:
                return True
            if fn.id in _STATIC_IF_ARGS:
                return all(_is_static(a, static_names) for a in node.args)
            return False
        return _dotted(fn) in _STATIC_DOTTED
    return False


_SCALAR_ANNOTATIONS = ("int", "float", "bool", "str")


def _static_params(fn: ast.FunctionDef) -> set[str]:
    """Parameters known host-static: scalar-annotated or constant-defaulted."""
    a = fn.args
    params = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    defaults: dict[str, ast.AST] = {}
    pos = list(a.posonlyargs) + list(a.args)
    for arg, d in zip(reversed(pos), reversed(a.defaults)):
        defaults[arg.arg] = d
    for arg, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            defaults[arg.arg] = d
    out = set()
    for arg in params:
        if arg.annotation is not None:
            ann = ast.unparse(arg.annotation)
            if any(s in ann for s in _SCALAR_ANNOTATIONS):
                out.add(arg.arg)
                continue
        if isinstance(defaults.get(arg.arg), ast.Constant):
            out.add(arg.arg)
    return out


# ---------------------------------------------------------------------------
# R1 — PRNG key discipline


def _is_key_maker(call: ast.Call) -> bool:
    d = _dotted(call.func)
    if d is None:
        return False
    parts = d.split(".")
    return parts[-1] in _KEY_MAKERS and (
        "random" in parts[:-1] or parts[-1] == "PRNGKey")


def _is_key_consumer(call: ast.Call) -> bool:
    d = _dotted(call.func)
    if d is None:
        return False
    parts = d.split(".")
    return (len(parts) >= 2 and "random" in parts[:-1]
            and parts[-1] not in _NON_CONSUMERS)


_KEY_PARAM_RE = re.compile(r"(^(key|rng|prng)$)|(^(key|k|rng)_)|(_(key|rng)$)")


def _check_keys(mod: _Module, fn: _Func, out: list[Violation]) -> None:
    keys = {a.arg for a in (list(fn.node.args.posonlyargs)
                            + list(fn.node.args.args)
                            + list(fn.node.args.kwonlyargs))
            if _KEY_PARAM_RE.search(a.arg)}
    consumed: dict[str, int] = {}
    literal_roots: list[int] = []
    reported: set[tuple[str, int]] = set()

    def consume_token(tok: str, line: int) -> None:
        base = tok.split("@")[0]
        if base not in keys:
            return
        if tok in consumed and (tok, line) not in reported:
            reported.add((tok, line))
            if not mod.allowed(line, "R1"):
                out.append(Violation(
                    "R1", mod.path, line,
                    f"key `{base}` already consumed at line {consumed[tok]}; "
                    f"split/fold_in a fresh key instead of reusing it"))
        consumed.setdefault(tok, line)

    def bind(target: ast.AST, is_key: bool) -> None:
        if isinstance(target, ast.Name):
            for tok in [t for t in consumed if t.split("@")[0] == target.id]:
                del consumed[tok]
            if is_key:
                keys.add(target.id)
            else:
                keys.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                bind(e, is_key)

    def handle_call(call: ast.Call) -> None:
        if _is_key_maker(call):
            d = _dotted(call.func) or ""
            leaf = d.split(".")[-1]
            if leaf in ("key", "PRNGKey") and call.args and isinstance(
                    call.args[0], ast.Constant) \
                    and call.lineno not in literal_roots:
                literal_roots.append(call.lineno)
        if not _is_key_consumer(call) or not call.args:
            return
        arg0 = call.args[0]
        leaf = (_dotted(call.func) or "").split(".")[-1]
        if isinstance(arg0, ast.Name):
            if leaf == "fold_in":
                data = call.args[1] if len(call.args) > 1 else None
                if isinstance(data, ast.Constant):
                    consume_token(f"{arg0.id}@{data.value!r}", call.lineno)
                # fold_in(key, i) with a varying operand derives a fresh
                # stream per i — the blessed pattern, not a reuse.
                return
            consume_token(arg0.id, call.lineno)

    def calls_in(expr: ast.AST) -> None:
        for n in ast.walk(expr):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(n, ast.Call):
                handle_call(n)

    def run_branch(stmts: list[ast.stmt]) -> tuple[dict, set]:
        """Run an exclusive branch on a copy of the state; return it."""
        snap_c, snap_k = dict(consumed), set(keys)
        run_stmts(stmts)
        result = dict(consumed), set(keys)
        consumed.clear(); consumed.update(snap_c)
        keys.clear(); keys.update(snap_k)
        return result

    def run_stmts(stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.If):
                # exclusive branches must not see each other's consumes
                c_body, k_body = run_branch(st.body)
                c_else, k_else = run_branch(st.orelse)
                for branch_c in (c_body, c_else):
                    for tok, line in branch_c.items():
                        consumed.setdefault(tok, line)
                keys.update(k_body & k_else)
                calls_in(st.test)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
                calls_in(st.iter if isinstance(st, (ast.For, ast.AsyncFor))
                         else st.test)
                # Two passes: a consume not re-bound within the loop body is
                # a reuse on the second iteration.
                run_stmts(st.body)
                run_stmts(st.body)
                run_stmts(st.orelse)
                continue
            if isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    calls_in(item.context_expr)
                run_stmts(st.body)
                continue
            if isinstance(st, ast.Try):
                run_stmts(st.body)
                for h in st.handlers:
                    run_stmts(h.body)
                run_stmts(st.orelse)
                run_stmts(st.finalbody)
                continue
            # simple statement: calls in evaluation order, then bindings
            calls_in(st)
            if isinstance(st, ast.Assign):
                is_key = isinstance(st.value, ast.Call) and _is_key_maker(
                    st.value)
                for t in st.targets:
                    bind(t, is_key)
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                bind(st.target, isinstance(st.value, ast.Call)
                     and _is_key_maker(st.value))

    run_stmts(fn.node.body)

    if len(literal_roots) > 1:
        line = literal_roots[1]
        if not mod.allowed(line, "R1") and not mod.allowed(
                literal_roots[0], "R1"):
            out.append(Violation(
                "R1", mod.path, line,
                f"{len(literal_roots)} literal root keys in one scope "
                f"(first at line {literal_roots[0]}); derive streams with "
                f"jax.random.fold_in(base, index) from one base seed"))


# ---------------------------------------------------------------------------
# R2 — host syncs in traced code


def _jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        d = _dotted(dec)
        if d in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call):
            d = _dotted(dec.func)
            if d in ("jax.jit", "jit"):
                return True
            if d in ("functools.partial", "partial") and dec.args:
                if _dotted(dec.args[0]) in ("jax.jit", "jit"):
                    return True
    return False


def _reachable_units(mods: list[_Module]) -> set[tuple[str, str]]:
    """(path, qualname) of every unit reachable from the trace roots."""
    by_modname = {m.modname: m for m in mods if m.modname}
    units: dict[tuple[str, str], _Func] = {}
    for m in mods:
        def add(f: _Func) -> None:
            units[(m.path, f.qualname)] = f
            for c in f.children:
                add(c)
        for f in m.funcs:
            add(f)

    edges: dict[tuple[str, str], set[tuple[str, str]]] = {
        k: set() for k in units}
    roots: set[tuple[str, str]] = set()

    def resolve_call(m: _Module, owner: _Func, fnode: ast.AST
                     ) -> tuple[str, str] | None:
        if isinstance(fnode, ast.Name):
            name = fnode.id
            for c in owner.children:
                if c.node.name == name:
                    return (m.path, c.qualname)
            if name in m.top_funcs:
                return (m.path, m.top_funcs[name].qualname)
            if name in m.func_imports:
                src_mod, src_name = m.func_imports[name]
                target = by_modname.get(src_mod)
                if target and src_name in target.top_funcs:
                    return (target.path, target.top_funcs[src_name].qualname)
            return None
        if isinstance(fnode, ast.Attribute) and isinstance(
                fnode.value, ast.Name):
            alias = m.mod_aliases.get(fnode.value.id)
            target = by_modname.get(alias) if alias else None
            if target and fnode.attr in target.top_funcs:
                return (target.path, target.top_funcs[fnode.attr].qualname)
        return None

    for m in mods:
        for key, f in list(units.items()):
            if key[0] != m.path:
                continue
            if _jit_decorated(f.node):
                roots.add(key)
            if f.qualname in ("round_core", "build_chunk") and (
                    m.modname or "").endswith((".engine", ".backend")):
                roots.add(key)
            for c in f.children:
                edges[key].add((m.path, c.qualname))
            for n in f.own_body_nodes():
                if not isinstance(n, ast.Call):
                    continue
                if _dotted(n.func) in ("jax.jit", "jit") and n.args:
                    tgt = resolve_call(m, f, n.args[0])
                    if tgt:
                        roots.add(tgt)
                tgt = resolve_call(m, f, n.func)
                if tgt:
                    edges[key].add(tgt)

    seen = set(roots)
    stack = list(roots)
    while stack:
        cur = stack.pop()
        for nxt in edges.get(cur, ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return seen


def _collect_statics(fn: ast.FunctionDef, inherited: set[str]) -> set[str]:
    """Params + locals assigned from static expressions (single forward
    pass; nested defs excluded — they inherit the result)."""
    static = set(inherited) | _static_params(fn)

    def mark(target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            static.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                mark(e)

    def scan(stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.Assign) and _is_static(st.value, static):
                for t in st.targets:
                    mark(t)
            elif isinstance(st, ast.AnnAssign) and st.value is not None \
                    and _is_static(st.value, static):
                mark(st.target)
            for field in ("body", "orelse", "finalbody"):
                b = getattr(st, field, None)
                if b:
                    scan(b)
            for h in getattr(st, "handlers", []):
                scan(h.body)

    scan(fn.body)
    return static


def _check_host_sync(mod: _Module, fn: _Func, inherited: set[str],
                     out: list[Violation]) -> None:
    numpy_aliases = {a for a, target in mod.mod_aliases.items()
                     if target == "numpy"} | {"numpy"}
    static = _collect_statics(fn.node, inherited)
    for n in fn.own_body_nodes():
        if not isinstance(n, ast.Call):
            continue
        line = n.lineno
        if mod.allowed(line, "R2"):
            continue
        msg = None
        d = _dotted(n.func)
        if isinstance(n.func, ast.Attribute) and n.func.attr == "item" \
                and not n.args:
            msg = "`.item()` forces a device->host sync inside traced code"
        elif d in ("jax.device_get", "device_get"):
            msg = "`jax.device_get` blocks on device results in traced code"
        elif d is not None and "." in d and d.split(".")[0] in numpy_aliases \
                and d.split(".")[-1] in ("asarray", "array", "copy"):
            msg = (f"`{d}` materializes a device array on host; use jnp or "
                   f"move this out of the traced path")
        elif isinstance(n.func, ast.Name) and n.func.id in ("float", "int",
                                                            "bool") \
                and n.args and not _is_static(n.args[0], static):
            msg = (f"`{n.func.id}()` on a traced value concretizes it "
                   f"(host sync / ConcretizationError)")
        if msg:
            out.append(Violation(
                "R2", mod.path, line,
                f"{msg} [in `{fn.qualname}`, reachable from a jit root]"))


# ---------------------------------------------------------------------------
# R3 — traced-value branching


def _check_branches(mod: _Module, fn: _Func,
                    inherited: set[str], out: list[Violation]) -> None:
    static = set(inherited) | _static_params(fn.node)

    def scan_body(stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue   # nested defs checked as their own units
            if isinstance(st, ast.Assign):
                if _is_static(st.value, static):
                    for t in st.targets:
                        _mark(t)
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                if _is_static(st.value, static):
                    _mark(st.target)
            if isinstance(st, ast.If):
                check_test(st.test)
                scan_body(st.body)
                scan_body(st.orelse)
                continue
            for n in ast.iter_child_nodes(st):
                scan_expr(n)
            if isinstance(st, (ast.For, ast.AsyncFor, ast.While,
                               ast.With, ast.AsyncWith, ast.Try)):
                for body in _sub_bodies(st):
                    scan_body(body)

    def _sub_bodies(st: ast.stmt) -> list[list[ast.stmt]]:
        bodies = []
        for field in ("body", "orelse", "finalbody"):
            b = getattr(st, field, None)
            if b:
                bodies.append(b)
        for h in getattr(st, "handlers", []):
            bodies.append(h.body)
        return bodies

    def _mark(target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            static.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                _mark(e)

    def check_test(test: ast.expr) -> None:
        if not _is_static(test, static) and not mod.allowed(test.lineno, "R3"):
            out.append(Violation(
                "R3", mod.path, test.lineno,
                f"`if {ast.unparse(test)}` branches on a value not provably "
                f"static under trace; use lax.cond/jnp.where, or mark with "
                f"`# lint: static-branch` if it is config-static"))

    def scan_expr(node: ast.AST) -> None:
        for n in ast.walk(node):
            if isinstance(n, ast.IfExp) and not _is_static(n.test, static) \
                    and not mod.allowed(n.lineno, "R3"):
                out.append(Violation(
                    "R3", mod.path, n.lineno,
                    f"conditional expression on non-static "
                    f"`{ast.unparse(n.test)}`"))
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return

    scan_body(fn.node.body)
    for child in fn.children:
        _check_branches(mod, child, static, out)


# ---------------------------------------------------------------------------
# R4 / R5


def _check_asserts(mod: _Module, out: list[Violation]) -> None:
    for n in ast.walk(mod.tree):
        if isinstance(n, ast.Assert):
            out.append(Violation(
                "R4", mod.path, n.lineno,
                "bare `assert` in kernels/ — raise ValueError naming the "
                "offending shapes/blocks (vanishes under python -O)"))


def _check_defaults_and_import_time(mod: _Module,
                                    out: list[Violation]) -> None:
    for n in ast.walk(mod.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            defaults = list(n.args.defaults) + [
                d for d in n.args.kw_defaults if d is not None]
            for d in defaults:
                if isinstance(d, (ast.List, ast.Dict, ast.Set)):
                    out.append(Violation(
                        "R5", mod.path, d.lineno,
                        "mutable default argument (shared across calls); "
                        "default to None and construct inside"))

    def module_level(stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(st, ast.ClassDef):
                module_level(st.body)
                continue
            for n in ast.walk(st):
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                    break
                if isinstance(n, ast.Call):
                    d = _dotted(n.func) or ""
                    if (d.startswith(("jnp.", "jax.numpy."))
                            and not mod.allowed(n.lineno, "R5")):
                        out.append(Violation(
                            "R5", mod.path, n.lineno,
                            f"`{d}` at module import time places an array "
                            f"(and initializes the platform) on import; "
                            f"build it lazily"))

    module_level(mod.tree.body)


# ---------------------------------------------------------------------------
# Drivers


def _lint_modules(mods: list[_Module],
                  rules: Iterable[str] | None = None) -> list[Violation]:
    rules = set(rules or RULES)
    out: list[Violation] = []
    reachable = _reachable_units(mods) if "R2" in rules else set()

    for m in mods:
        all_units: list[_Func] = []

        def flatten(f: _Func) -> None:
            all_units.append(f)
            for c in f.children:
                flatten(c)
        for f in m.funcs:
            flatten(f)

        module_static = {n.id for st in m.tree.body
                         if isinstance(st, ast.Assign)
                         for n in st.targets if isinstance(n, ast.Name)}
        module_static |= set(m.mod_aliases) | set(m.func_imports)

        if "R1" in rules:
            for f in all_units:
                _check_keys(m, f, out)
        if "R2" in rules:
            def sync_walk(f: _Func, inherited: set[str]) -> None:
                if (m.path, f.qualname) in reachable:
                    _check_host_sync(m, f, inherited, out)
                statics = _collect_statics(f.node, inherited)
                for c in f.children:
                    sync_walk(c, statics)
            for f in m.funcs:
                sync_walk(f, module_static)
        if "R3" in rules and _R3_MODULE_RE.search(m.path.replace("\\", "/")):
            for f in m.funcs:
                _check_branches(m, f, module_static, out)
        if "R4" in rules and _R4_MODULE_RE.search(m.path.replace("\\", "/")):
            _check_asserts(m, out)
        if "R5" in rules:
            _check_defaults_and_import_time(m, out)

    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def _modname_for(path: pathlib.Path) -> str | None:
    parts = path.with_suffix("").parts
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    elif "repro" in parts:
        parts = parts[parts.index("repro"):]
    else:
        return None
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


def lint_paths(paths: Iterable[str | pathlib.Path],
               rules: Iterable[str] | None = None) -> list[Violation]:
    """Lint every .py file under the given paths with cross-file R2
    reachability. Returns violations sorted by (path, line)."""
    files: list[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    mods = []
    for f in files:
        src = f.read_text()
        mods.append(_parse_module(src, str(f), _modname_for(f)))
    return _lint_modules(mods, rules)


def lint_source(source: str, path: str = "<memory>",
                rules: Iterable[str] | None = None) -> list[Violation]:
    """Lint a single in-memory module (fixture/test entry point).

    R2 reachability is computed within the snippet alone; R3/R4 scoping by
    module path applies, so pass e.g. ``path="kernels/foo.py"`` to put the
    snippet in kernel scope.
    """
    return _lint_modules([_parse_module(source, path, None)], rules)


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="repro.analysis.lint",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    default=["src/repro", "examples", "benchmarks"])
    ap.add_argument("--rules", default=",".join(RULES),
                    help="comma-separated subset of R1..R5")
    args = ap.parse_args(argv)

    violations = lint_paths(args.paths, rules=args.rules.split(","))
    for v in violations:
        print(v)
    print(f"repro.analysis.lint: {len(violations)} violation(s) "
          f"in {len(args.paths)} root(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
