"""Pruned-checkpoint serving: continuous-batching decode over flash-decode.

``repro.serving`` closes the train->deploy loop: a federated run's
``RunResult`` (or its ``RunResult.save`` checkpoint directory) loads into
a fixed-slot continuous-batching decode engine that realizes FedAP's
FLOP cut at inference — masked (block-skipping kernel at dense shapes) or
shrunk (compacted shapes).

    from repro import serving

    servable = serving.load_servable("ckpt/", "shrunk")
    eng = serving.DecodeEngine(servable.model, servable.params,
                               serving.ServeConfig(slots=8, cache_len=64),
                               masks=servable.masks)
    for completion in eng.run(prompts):
        print(completion.uid, completion.tokens)
"""
from repro.serving.checkpoint import SERVE_MODES, Servable, load_servable
from repro.serving.engine import (Completion, DecodeEngine, QueueFull,
                                  ServeConfig)

__all__ = ["Completion", "DecodeEngine", "QueueFull", "ServeConfig",
           "SERVE_MODES", "Servable", "load_servable"]
