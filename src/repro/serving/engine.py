"""Continuous-batching decode engine over the flash-decode kernel.

The inference leg of FedDUMAP: a trained (optionally FedAP-pruned)
checkpoint is served from a FIXED pool of decode slots, so the pruned
model's FLOP cut is realized where the paper's efficiency claim matters —
tokens/s under load.

Design:

* **Slot pool.**  ``ServeConfig.slots`` decode slots form the batch axis
  of ONE model decode cache; each slot owns a KV-cache page (its row of
  ``cache["k"]/["v"]``) and a fill level (``cache["index"]`` is an int32
  ``[slots]`` vector — the continuous-batching extension of
  ``LM.decode_step``).  Attention over a slot's page is masked to its own
  valid prefix (``kernels.decode_attention`` ``lengths``), so slots at
  different depths — and stale rows from a page's previous occupant —
  never leak across requests.

* **Lockstep waves.**  The device runs ``steps_per_wave`` decode steps
  per launch as one ``lax.scan``.  Prompts prefill THROUGH the same step
  (one prompt token per step — chunked prefill), then generation
  continues seamlessly: the step input switches from the prompt buffer to
  the previous argmax on device.

* **On-device done-mask.**  A slot that reaches ``max_new_tokens`` (or
  ``eos_id``) flips its ``active`` bit in the carry and freezes — its
  cache index, output count and last token stop advancing.  There is NO
  per-token host sync: the host reads ``active`` once per wave to retire
  finished requests and admit queued ones into the freed slots.

* **Zero re-traces.**  All slot state lives in fixed-structure,
  fixed-shape device arrays, so the whole serving session compiles
  exactly TWO programs — ``_admit`` (one slot write) and ``_wave`` (the
  step scan) — no matter how many requests are admitted or retired
  (locked by the ``serving/*`` compile-budget scenarios).

* **Fault tolerance.**  An in-wave health guard retires any slot whose
  logits go non-finite (``error`` bit in the carry; the request completes
  with ``status="error"`` instead of poisoning the shared batch), and
  ``ServeConfig.max_queue``/``on_full`` bound the host admission queue
  (raise :class:`QueueFull` or count-and-drop).

* **Pruned checkpoints** serve either *masked* (dense shapes, FFN matmuls
  through the block-skipping ``masked_matmul`` kernel via
  ``decode_step(..., masks=)``) or *shrunk* (compacted shapes); see
  :mod:`repro.serving.checkpoint`.

* **Mesh throughput** (optional): pass ``mesh=`` to shard the slot axis
  over the mesh's data axis — slot state, KV pages and the decode batch
  all partition; the host protocol is unchanged.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine shape knobs (all static: they size the two compiled programs).

    slots           decode-slot pool == device batch of the step
    cache_len       per-slot KV page length (max prompt+generated context)
    max_prompt      admission pads prompts to this many tokens
    max_new_tokens  per-request generation budget
    eos_id          stop token (-1: never stop early)
    steps_per_wave  decode steps per device launch — the host-sync cadence
                    (admission latency vs. launch overhead trade-off)
    max_queue       backpressure bound on the host admission queue
                    (None: unbounded — the pre-backpressure behaviour)
    on_full         what ``submit`` does at the bound: "raise" a
                    :class:`QueueFull`, or "reject" (drop the request,
                    count it in ``DecodeEngine.rejected``, return None)
    """

    slots: int = 8
    cache_len: int = 64
    max_prompt: int = 16
    max_new_tokens: int = 16
    eos_id: int = -1
    steps_per_wave: int = 8
    max_queue: Optional[int] = None
    on_full: str = "raise"

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be None or >= 1, got {self.max_queue}")
        if self.on_full not in ("raise", "reject"):
            raise ValueError(
                f"on_full must be 'raise' or 'reject', got {self.on_full!r}")
        if not 1 <= self.max_prompt <= self.cache_len:
            raise ValueError(
                f"max_prompt must be in [1, cache_len={self.cache_len}], "
                f"got {self.max_prompt}")
        if self.max_prompt + self.max_new_tokens - 1 > self.cache_len:
            raise ValueError(
                f"cache_len={self.cache_len} cannot hold max_prompt="
                f"{self.max_prompt} + max_new_tokens={self.max_new_tokens} "
                f"- 1 context tokens")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if self.steps_per_wave < 1:
            raise ValueError(
                f"steps_per_wave must be >= 1, got {self.steps_per_wave}")


class QueueFull(RuntimeError):
    """``submit`` hit ``ServeConfig.max_queue`` with ``on_full="raise"``."""


@dataclasses.dataclass(frozen=True)
class Completion:
    """One finished request: ``tokens`` are the generated ids (prompt
    excluded), in generation order.  ``status`` is ``"ok"`` for a normal
    finish, ``"error"`` when the slot was retired by the in-wave health
    guard (non-finite logits); an error completion carries the tokens
    generated before the fault."""

    uid: int
    prompt: np.ndarray
    tokens: np.ndarray
    status: str = "ok"


# Families whose decode cache is the scanned [L, B, S, KV, hd] KV stack —
# the per-slot index/validity semantics the engine relies on.
_SERVABLE_FAMILIES = ("dense", "moe", "vlm")


class DecodeEngine:
    """Continuous-batching argmax decoding over ``model.decode_step``.

    ``masks`` (optional) is the FedAP filter keep-mask tree
    (``{"mlp": [L, d_ff]}``) — when given, every step routes the FFN
    matmuls through the block-skipping masked kernel (masked serving of a
    mask-mode pruned checkpoint).  ``mesh`` (optional) shards the slot
    axis over ``mesh_axis``.
    """

    def __init__(self, model, params, cfg: ServeConfig | None = None, *,
                 masks=None, mesh=None, mesh_axis: str = "data",
                 faults: tuple = ()):
        if model.cfg.family not in _SERVABLE_FAMILIES:
            raise ValueError(
                f"DecodeEngine serves the scanned-KV families "
                f"{_SERVABLE_FAMILIES}, not {model.cfg.family!r} (ssm/"
                f"hybrid/encdec decode state has no per-slot cache index)")
        self.model = model
        self.cfg = cfg or ServeConfig()
        self._faults = tuple(f for f in faults if hasattr(f, "apply_logits"))
        self._masks = masks
        self._mesh = mesh
        self._mesh_axis = mesh_axis
        if mesh is not None:
            n = mesh.shape[mesh_axis]
            if self.cfg.slots % n:
                raise ValueError(
                    f"slots={self.cfg.slots} must divide over the "
                    f"{n}-way mesh axis {mesh_axis!r}")
        self._params = self._place(params, batched=False)
        if masks is not None:
            self._masks = self._place(masks, batched=False)
        self._admit = jax.jit(self._admit_fn, donate_argnums=(0,))
        self._wave = jax.jit(self._wave_fn, donate_argnums=(1,))
        self._state = self._place_state(self._init_state())
        self._occupants: list[Optional[tuple[int, np.ndarray]]] = \
            [None] * self.cfg.slots
        self._queue: collections.deque = collections.deque()
        self._next_uid = 0
        self.rejected = 0  # requests dropped by on_full="reject" backpressure

    # -- state ------------------------------------------------------------
    def _init_state(self) -> dict:
        c = self.cfg
        cache = self.model.init_cache(c.slots, c.cache_len)
        cache["index"] = jnp.zeros((c.slots,), jnp.int32)
        return {
            "cache": cache,
            "active": jnp.zeros((c.slots,), bool),
            "last_tok": jnp.zeros((c.slots,), jnp.int32),
            "prompt": jnp.zeros((c.slots, c.max_prompt), jnp.int32),
            "prompt_len": jnp.ones((c.slots,), jnp.int32),
            "n_out": jnp.zeros((c.slots,), jnp.int32),
            "out": jnp.zeros((c.slots, c.max_new_tokens), jnp.int32),
            "error": jnp.zeros((c.slots,), bool),
        }

    def _place(self, tree, *, batched: bool, cache: bool = False):
        """device_put with the mesh sharding (replicated when
        ``batched=False``); identity on a mesh-less engine."""
        if self._mesh is None:
            return tree
        from jax.sharding import NamedSharding, PartitionSpec as P

        ax = self._mesh_axis

        def put(leaf):
            nd = jnp.ndim(leaf)
            if not batched:
                spec = P()
            elif cache and nd > 1:
                # scanned KV stacks [L, slots, S, KV, hd]: batch is axis 1
                spec = P(None, ax)
            else:
                spec = P(ax)
            return jax.device_put(leaf, NamedSharding(self._mesh, spec))

        return jax.tree.map(put, tree)

    def _place_state(self, state: dict) -> dict:
        if self._mesh is None:
            return state
        placed = {k: self._place(v, batched=True)
                  for k, v in state.items() if k != "cache"}
        placed["cache"] = self._place(state["cache"], batched=True,
                                      cache=True)
        return placed

    # -- the two compiled programs ---------------------------------------
    def _admit_fn(self, state, slot, prompt, plen):
        """Write one queued request into a freed slot.  Fixed shapes (the
        prompt arrives padded to max_prompt) and a traced slot index: ONE
        program for every admission.  The slot's cache page is NOT
        cleared — index=0 re-grows the valid prefix, so the previous
        occupant's rows are only ever attended after being overwritten."""
        st = dict(state)
        cache = dict(st["cache"])
        cache["index"] = cache["index"].at[slot].set(0)
        st["cache"] = cache
        st["active"] = st["active"].at[slot].set(True)
        st["prompt"] = st["prompt"].at[slot].set(prompt)
        st["prompt_len"] = st["prompt_len"].at[slot].set(plen)
        st["last_tok"] = st["last_tok"].at[slot].set(prompt[0])
        st["n_out"] = st["n_out"].at[slot].set(0)
        st["error"] = st["error"].at[slot].set(False)
        return st

    def _step(self, params, state):
        """One lockstep decode step for every slot (done slots frozen)."""
        c = self.cfg
        cache = state["cache"]
        idx = cache["index"]                         # [B] pre-step fill
        active = state["active"]
        logits, cache = self.model.decode_step(
            params, cache, {"tokens": state["last_tok"][:, None]},
            masks=self._masks)
        for f in self._faults:  # lint: static-branch (test-only injection)
            logits = f.apply_logits(logits, state)
        # in-wave health guard: a slot whose logits go non-finite is
        # retired on device (error bit set, slot frozen) instead of
        # emitting garbage tokens.  Same fixed state structure and no
        # host sync — the session still compiles exactly two programs.
        ok = jnp.all(jnp.isfinite(logits[:, 0]), axis=-1)
        bad = active & ~ok
        live = active & ok
        cache = dict(cache)
        # done-mask: frozen (and newly-errored) slots keep their fill
        # level (their page write lands on a slot that stays invalid —
        # never attended)
        cache["index"] = jnp.where(live, cache["index"], idx)
        sampled = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)

        consumed = idx + 1                           # tokens seen after step
        in_prefill = consumed < state["prompt_len"]  # next input from prompt
        nxt_prompt = jnp.take_along_axis(
            state["prompt"],
            jnp.minimum(consumed, c.max_prompt - 1)[:, None], axis=1)[:, 0]
        # a step that consumed the prompt's last token (or any later one)
        # emits a generated token
        emitted = live & (consumed >= state["prompt_len"])
        row = jnp.arange(c.slots)
        pos = jnp.clip(state["n_out"], 0, c.max_new_tokens - 1)
        out = state["out"].at[row, pos].set(
            jnp.where(emitted, sampled, state["out"][row, pos]))
        n_out = state["n_out"] + emitted.astype(jnp.int32)
        finished = emitted & ((n_out >= c.max_new_tokens) |
                              (sampled == c.eos_id))
        last_tok = jnp.where(
            live, jnp.where(in_prefill, nxt_prompt, sampled),
            state["last_tok"])
        return {
            "cache": cache,
            "active": active & ~finished & ~bad,
            "last_tok": last_tok,
            "prompt": state["prompt"],
            "prompt_len": state["prompt_len"],
            "n_out": n_out,
            "out": out,
            "error": state["error"] | bad,
        }

    def _wave_fn(self, params, state):
        def body(st, _):
            return self._step(params, st), None

        st, _ = jax.lax.scan(body, state, None,
                             length=self.cfg.steps_per_wave)
        return st

    # -- host protocol ----------------------------------------------------
    def submit(self, prompt) -> Optional[int]:
        """Queue a request; returns its uid (completion order may differ
        from submission order — slots free up raggedly).  With a
        ``max_queue`` bound and the host queue full, either raises
        :class:`QueueFull` (``on_full="raise"``) or drops the request and
        returns None (``on_full="reject"``, counted in ``rejected``)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 1 <= prompt.shape[0] <= self.cfg.max_prompt:
            raise ValueError(
                f"prompt length {prompt.shape[0]} outside [1, "
                f"max_prompt={self.cfg.max_prompt}]")
        if (self.cfg.max_queue is not None
                and len(self._queue) >= self.cfg.max_queue):
            if self.cfg.on_full == "raise":
                raise QueueFull(
                    f"admission queue at max_queue={self.cfg.max_queue} "
                    f"(drain with step_wave/run, or use on_full='reject')")
            self.rejected += 1
            return None
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append((uid, prompt))
        return uid

    @property
    def pending(self) -> int:
        """Queued + in-flight request count."""
        return len(self._queue) + sum(o is not None for o in self._occupants)

    def step_wave(self) -> list[Completion]:
        """Admit into free slots, run one wave, retire finished requests.
        The building block of :meth:`run` — exposed for callers that
        interleave submission with decoding."""
        for slot in range(self.cfg.slots):
            if self._occupants[slot] is None and self._queue:
                uid, prompt = self._queue.popleft()
                padded = np.zeros((self.cfg.max_prompt,), np.int32)
                padded[:prompt.shape[0]] = prompt
                self._state = self._admit(
                    self._state, slot, self._place(jnp.asarray(padded),
                                                   batched=False),
                    prompt.shape[0])
                self._occupants[slot] = (uid, prompt)
        self._state = self._wave(self._params, self._state)
        # the wave's ONLY host sync: the done-mask (and, for slots that
        # finished, their token counts and output rows)
        active = np.asarray(self._state["active"])
        done = [slot for slot, occ in enumerate(self._occupants)
                if occ is not None and not active[slot]]
        if not done:
            return []
        n_out = np.asarray(self._state["n_out"])
        out = np.asarray(self._state["out"])
        error = np.asarray(self._state["error"])
        completions = []
        for slot in done:
            uid, prompt = self._occupants[slot]
            completions.append(
                Completion(uid, prompt, out[slot, :n_out[slot]].copy(),
                           status="error" if error[slot] else "ok"))
            self._occupants[slot] = None
        return completions

    def run(self, prompts=None) -> list[Completion]:
        """Serve until the queue and every slot drain; returns completions
        sorted by uid.  ``prompts`` (optional) are submitted first."""
        for p in (prompts or []):
            self.submit(p)
        done: list[Completion] = []
        while self.pending:
            done.extend(self.step_wave())
        return sorted(done, key=lambda comp: comp.uid)

    # -- introspection -----------------------------------------------------
    def lower_wave(self):
        """AOT-lower the wave program against the current state — the
        analysis hook :mod:`repro.analysis.hlo_lint` uses to inspect the
        optimized HLO (f64 leaks, host callbacks, collectives)."""
        return self._wave.lower(self._params, self._state)

    def program_counts(self) -> dict:
        """Lowered-program counts of the session's two jitted entry points
        (the compile-budget serving scenarios lock admit=1, wave=1 across
        arbitrarily many admissions)."""
        return {"admit": int(self._admit._cache_size()),
                "wave": int(self._wave._cache_size())}
