"""Checkpoint -> servable (model, params, masks) for the decode engine.

Bridges the training side (``RunResult.save`` / ``core.plan.load_artifact``
checkpoint directories, or an in-memory ``RunResult``) to the three serving
modes of a FedAP-pruned LM:

* ``dense``   — decode the params as saved (a mask-trained checkpoint's
                pruned coordinates are exact zeros, so this is correct but
                does dense-shape FLOPs);
* ``masked``  — dense shapes, FFN matmuls through the block-skipping
                ``masked_matmul`` kernel (``decode_step(..., masks=)``):
                pruned 128-lane blocks are skipped on the MXU;
* ``shrunk``  — structurally compacted params (``shrink_ffn_at``) decode
                at the smaller d_ff: the full FLOP and memory cut.

``masked`` and ``shrunk`` produce logits equal to within float
reassociation (locked <= 1e-5 by tests/test_serving.py); ``masked`` keeps
the dense parameter layout (cheap to flip back, e.g. for continued
training), ``shrunk`` is the deployment end-state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

SERVE_MODES = ("auto", "dense", "masked", "shrunk")


@dataclasses.dataclass(frozen=True)
class Servable:
    """What :func:`load_servable` hands to ``DecodeEngine``: build the
    engine as ``DecodeEngine(s.model, s.params, cfg, masks=s.masks)``."""

    model: Any
    params: Any
    masks: Optional[dict]
    mode: str


def _infer_d_ff(params) -> int | None:
    layers = params.get("layers") if isinstance(params, dict) else None
    if isinstance(layers, dict) and "mlp" in layers:
        return int(np.asarray(layers["mlp"]["wi"]).shape[-1])
    return None


def load_servable(source, serve_mode: str = "auto", *, model_config=None,
                  attn_impl: str = "pallas") -> Servable:
    """Build a servable from ``source`` — a checkpoint directory path, a
    ``core.plan.load_artifact`` dict, or a ``RunResult``-shaped object
    (``.params`` + ``.artifacts``).

    ``serve_mode="auto"`` picks ``masked`` when the checkpoint carries a
    mask-mode prune decision, ``shrunk`` for a shrink-mode one, ``dense``
    otherwise.  ``model_config`` overrides (or supplies, for in-memory
    sources) the checkpoint's recorded config; its ``d_ff`` is re-derived
    from the actual param shapes, so a config recorded before a shrink
    still loads.
    """
    from repro.models.lm import LM

    if serve_mode not in SERVE_MODES:
        raise ValueError(
            f"serve_mode must be one of {SERVE_MODES}, got {serve_mode!r}")

    if hasattr(source, "artifacts") and hasattr(source, "params"):
        art: dict = {"params": source.params, "kept": None,
                     "filter_masks": None, "mode": None, "model_config": None}
        for entry in source.artifacts.values():
            if isinstance(entry, dict) and "kept" in entry:
                art["kept"] = dict(entry["kept"] or {})
                art["filter_masks"] = (dict(entry["filter_masks"])
                                       if entry.get("filter_masks") else None)
                art["mode"] = entry.get("mode")
    elif isinstance(source, dict):
        art = source
    else:
        from repro.core.plan import load_artifact

        art = load_artifact(source)

    cfg = model_config or art.get("model_config")
    if cfg is None:
        raise ValueError(
            "no model config: the checkpoint was saved without one — pass "
            "model_config= (RunResult.save(..., model_config=cfg) records "
            "it)")
    params = art["params"]
    kept = art.get("kept")
    mode = serve_mode
    if mode == "auto":
        mode = ("dense" if kept is None
                else "shrunk" if art.get("mode") == "shrink" else "masked")

    # trust the param shapes over the recorded d_ff (a shrink-mode run's
    # params are already compacted relative to its training-time config)
    d_ff = _infer_d_ff(params)
    if d_ff is not None and d_ff != cfg.d_ff:
        cfg = dataclasses.replace(cfg, d_ff=d_ff)

    if mode == "dense":
        return Servable(LM(cfg, attn_impl=attn_impl), params, None, mode)

    if kept is None:
        raise ValueError(
            f"serve_mode={mode!r} needs a pruned checkpoint, but this one "
            f"carries no kept-filter decision (train with a Prune event, "
            f"or serve dense)")

    if mode == "masked":
        masks = art.get("filter_masks")
        if masks is None:
            model = LM(cfg, attn_impl=attn_impl)
            masks = model.filter_masks(
                params, {k: jnp.asarray(v) for k, v in kept.items()})
        else:
            masks = {k: jnp.asarray(v) for k, v in masks.items()}
        return Servable(LM(cfg, attn_impl=attn_impl), params, masks, mode)

    # shrunk: compact (a no-op if the checkpoint is already shrink-mode —
    # its kept width equals the param width)
    from repro.core import pruning_lm

    idx = np.asarray(kept["mlp"])
    if idx.shape[-1] != d_ff:
        params = pruning_lm.shrink_ffn_at(params, jnp.asarray(idx))
        cfg = dataclasses.replace(cfg, d_ff=int(idx.shape[-1]))
    return Servable(LM(cfg, attn_impl=attn_impl), params, None, mode)
