"""Minimal functional optimizers (no optax dependency).

API mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``, and updates are
*subtracted* via :func:`apply_updates`.

SGDM follows the paper's Formula 8 convention:

    m^t = beta * m^{t-1} + (1 - beta) * g
    w^t = w^{t-1} - eta * m^t

i.e. the (1 - beta) damping variant, NOT the torch ``momentum`` variant.
FedDUM relies on this exact form on both the server and the devices.

Momentum/second-moment state is kept in float32 regardless of the param
dtype (bf16-safe), matching production mixed-precision practice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p - u).astype(p.dtype), params, updates)


def _f32_zeros(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: lr * g, grads), state

    return Optimizer(init, update)


def sgdm(lr: float, beta: float = 0.9) -> Optimizer:
    """SGD with momentum, paper Formula 8 (damped)."""

    def init(params):
        return _f32_zeros(params)

    def update(grads, m, params=None):
        m = jax.tree.map(
            lambda mi, g: beta * mi + (1.0 - beta) * g.astype(jnp.float32), m, grads
        )
        return jax.tree.map(lambda mi, g: (lr * mi).astype(g.dtype), m, grads), m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return AdamState(_f32_zeros(params), _f32_zeros(params), jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        count = state.count + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(
            lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads
        )
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree.map(
            lambda mi, vi, g: (lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)).astype(g.dtype),
            m,
            v,
            grads,
        )
        return updates, AdamState(m, v, count)

    return Optimizer(init, update)


def adagrad(lr: float, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return _f32_zeros(params)

    def update(grads, acc, params=None):
        acc = jax.tree.map(lambda a, g: a + jnp.square(g.astype(jnp.float32)), acc, grads)
        updates = jax.tree.map(
            lambda a, g: (lr * g.astype(jnp.float32) / (jnp.sqrt(a) + eps)).astype(g.dtype),
            acc,
            grads,
        )
        return updates, acc

    return Optimizer(init, update)


class YogiState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def yogi(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-3) -> Optimizer:
    """Yogi (Reddi et al., 2018) — the paper's 'server-side momentum' baseline
    family (adaptive methods for nonconvex optimization)."""

    def init(params):
        return YogiState(_f32_zeros(params), _f32_zeros(params), jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        count = state.count + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32), state.m, grads)

        def _v(vi, g):
            g2 = jnp.square(g.astype(jnp.float32))
            return vi - (1 - b2) * jnp.sign(vi - g2) * g2

        v = jax.tree.map(_v, state.v, grads)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        updates = jax.tree.map(
            lambda mi, vi, g: (lr * (mi / bc1) / (jnp.sqrt(jnp.maximum(vi, 0.0)) + eps)).astype(
                g.dtype
            ),
            m,
            v,
            grads,
        )
        return updates, YogiState(m, v, count)

    return Optimizer(init, update)
