from repro.optim.optimizers import (
    Optimizer,
    sgd,
    sgdm,
    adam,
    adagrad,
    yogi,
    apply_updates,
)

__all__ = ["Optimizer", "sgd", "sgdm", "adam", "adagrad", "yogi", "apply_updates"]
