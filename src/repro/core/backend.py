"""Pluggable execution backends behind ONE backend-agnostic plan executor.

A :class:`~repro.core.plan.TrainPlan` describes WHAT happens (Scan / Eval /
Prune / Snapshot / Callback); this module decides WHERE it happens.  The
:class:`PlanExecutor` owns the schedule loop — history and artifact
bookkeeping, the Prune decision/apply split, the legacy Callback contract —
and drives a narrow :class:`ExecutionBackend` protocol:

    init_state(params)                 build the engine round state
    run_chunk(state, key, length)      one compiled scan chunk of rounds
    evaluate(state)                    (loss, acc) on the held-out split
    prune_decision(state, init_params) FedAP Algorithm 3 (the DECISION)
    apply_prune(state, mode, kept)     inject/apply it (mask or shrink)
    snapshot(state)                    a safe copy of the global params
    replace_params(state, params)      the legacy Callback restart contract

Two implementations ship:

  :class:`LocalScanBackend` — the single-host simulation path: session-
      cached jitted scan chunks (`compiled_engine`) with device-side
      `engine.sample_round_batches`; exactly the execution the differential
      tests lock against the f64 oracle.

  :class:`MeshBackend` — the same numerics, client-sharded over a device
      mesh: the federated dataset is placed with the client dimension
      sharded over the mesh's client axes
      (`FederatedData.device_arrays(mesh=...)`), the in-scan sampled round
      batch is sharding-constrained so the per-client local-epoch vmap,
      the FedAvg reduction AND the per-step server batches of the FedDU
      dynamic update partition over the mesh
      (`sharding.fl_specs.fl_sim_batch_specs` — the tau server-SGD steps
      become per-shard partial grads + one all-reduce instead of being
      replicated on every device), evaluation shards the test batch the
      same way (padded rows corrected out exactly), and Prune events run
      POD-SIDE: `fedap.fedap_decision_sharded` gathers the probe/Fisher
      statistics from mesh-sharded participants (ragged probe sets padded
      and masked), `launch.steps.with_masks` injects a mask decision into
      the live state without re-lowering the mesh program, and a shrink
      compacts the state SHARD-LOCALLY (one jitted gather of the kept
      filters — params and momentum never round-trip through the host).

Both backends share the scan-chunk builder below, including the
double-buffered sampling mode (``prefetch=True``): the scan carry holds the
NEXT round's already-gathered batch, so round t+1's client/server gathers
are issued while round t computes — on accelerators the gather latency
hides behind the round's compute.  The key chain and every drawn batch are
IDENTICAL to the non-prefetching chunk (locked bit-exact by
tests/test_plan.py), so prefetching is purely a scheduling change.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.engine import EngineConfig
from repro.core.plan import (
    Callback,
    Eval,
    Prune,
    RunResult,
    Scan,
    Snapshot,
    TrainPlan,
)


# ---------------------------------------------------------------------------
# Shared engine wiring: model fns, sampling kwargs, the scan-chunk builder
# ---------------------------------------------------------------------------

def model_fns(model, eng: EngineConfig):
    """(grad_fn, loss_and_acc_fn) for `engine.round_core` from a simulation
    model (``loss_and_acc(params, x, y[, masks=])``).  The kernel/non-kernel
    arity split lives in ``engine.build_model_fns``, shared with the pod
    path (`launch.steps.make_fl_train_step`) — only the batch adaptation
    ((x, y) tuples here, token dicts there) differs per caller.

    Models without the ``masks=`` keyword (e.g. ad-hoc test models) are
    still valid outside kernel mode — the filter masks are only threaded
    through when the model declares the seam."""
    accepts_masks = "masks" in inspect.signature(model.loss_and_acc).parameters
    if eng.use_masks and eng.masked_compute == "kernel" and not accepts_masks:
        raise TypeError(
            f"masked_compute='kernel' needs the model's loss_and_acc to "
            f"accept masks=, but {type(model).__name__}.loss_and_acc does not")

    if accepts_masks:
        def loss_fn(p, b, fm):
            return model.loss_and_acc(p, b[0], b[1], masks=fm)[0]

        def la_fn(p, b, fm):
            return model.loss_and_acc(p, b[0], b[1], masks=fm)
    else:
        def loss_fn(p, b, fm):
            return model.loss_and_acc(p, b[0], b[1])[0]

        def la_fn(p, b, fm):
            return model.loss_and_acc(p, b[0], b[1])

    return engine.build_model_fns(eng, loss_fn, la_fn)


def sim_sample_kw(cfg, data) -> dict:
    """The device-side sampling shape of one simulated round (shared by
    every backend; part of the compiled-program cache key)."""
    n_k = int(data.client_x.shape[1])
    n0 = int(data.server_x.shape[0])
    return dict(
        clients_per_round=cfg.clients_per_round,
        batch_size=cfg.batch_size,
        local_steps=max(1, n_k // cfg.batch_size) * cfg.local_epochs,
        server_batch=cfg.server_batch_size,
        server_tau=max(1, n0 // cfg.server_batch_size) * cfg.server_epochs,
        dropout_rate=float(getattr(cfg, "dropout_rate", 0.0)),
    )


def init_filter_masks(model, params):
    """All-ones per-layer filter masks (``masked_compute="kernel"``): the
    carry structure must be final from round 0 so a prune event only swaps
    contents, never re-traces."""
    return filter_masks_for(model, params, {})


# The Prune apply goes through a small model seam: models that publish
# their own mask/shrink builders (the scanned-stack LM, whose layer params
# are stacked [L, ...] and pruned with per-layer index rows) dispatch
# there; PruneSpec models (the CNN) fall back to the generic spec-driven
# builders in `repro.core.pruning`.  ``kept`` is the decision's host-side
# kept-index map in either case ([d] per layer for spec models, [L, keep]
# rows for scanned stacks).

def param_masks_for(model, params, kept):
    """Param-structured 0/1 masks for the carry (``state["masks"]``)."""
    if hasattr(model, "param_masks"):
        return model.param_masks(params, kept)
    from repro.core import pruning

    return pruning.param_masks(params, model.prune_spec(params), kept)


def filter_masks_for(model, params, kept):
    """Filter-level keep-masks for kernel-mode masked compute."""
    if hasattr(model, "filter_masks"):
        return model.filter_masks(params, kept)
    from repro.core import pruning

    return pruning.filter_masks(params, model.prune_spec(params), kept)


def shrink_params_for(model, params, kept):
    """Re-materialize a params-structured tree at the kept indices (also
    applied to momentum buffers, which share the params structure)."""
    if hasattr(model, "shrink_params"):
        return model.shrink_params(params, kept)
    from repro.core import pruning

    return pruning.shrink_params(params, model.prune_spec(params), kept)


def build_chunk(eng: EngineConfig, grad_fn, la_fn, sample_kw: dict, *,
                prefetch: bool = True, constrain=None):
    """``chunk(state, key, data_dev, length) -> (state, key, mets)`` — one
    scan over `round_core` with device-side sampling.  ``mets`` is a dict
    of per-round stacked metrics: ``{"tau_eff": [length], "health":
    [length]}`` (``health`` = guard rejection counts; identically zero
    with the guard off — the metric structure never depends on the guard
    mode, so guard configs compile zero extra programs).

    ``constrain`` (MeshBackend) maps the sampled batch through sharding
    constraints so the client axis partitions over the mesh.

    ``prefetch=True`` double-buffers the sampling: the prologue draws round
    0's batch, and every scan iteration gathers round t+1's batch BEFORE
    running round t on the batch riding in the carry, so the gather can
    overlap the round's compute.  Key accounting: the non-prefetch chunk
    consumes splits sub_0..sub_{L-1} of the key chain and returns k_L; here
    the prologue consumes sub_0 and iteration t consumes sub_{t+1}, while
    the carry keeps the PREVIOUS chain key so the returned key is the same
    k_L — draws and key chain are bit-identical, only the schedule moves.
    (The final iteration's prefetched batch is discarded: it is the next
    chunk's first draw, recomputed there.)
    """

    def sample(sub, data_dev):
        batch = engine.sample_round_batches(sub, data_dev, **sample_kw)
        return constrain(batch) if constrain is not None else batch

    def _mets(metrics):
        return {"tau_eff": metrics["tau_eff"], "health": metrics["health"]}

    def serial_chunk(state, key, data_dev, length):
        def body(carry, _):
            st, k = carry
            k, sub = jax.random.split(k)
            batch = sample(sub, data_dev)
            st, metrics = engine.round_core(eng, grad_fn, la_fn, st, batch)
            return (st, k), _mets(metrics)

        (state, key), mets = jax.lax.scan(body, (state, key), None,
                                          length=length)
        return state, key, mets

    if not prefetch:
        return serial_chunk

    def chunk(state, key, data_dev, length):
        if length == 1:
            # nothing to overlap with — the prefetch body would pay a
            # second, discarded gather (length is trace-time static, and
            # the draws/key chain are identical either way)
            return serial_chunk(state, key, data_dev, 1)
        k1, sub0 = jax.random.split(key)
        batch0 = sample(sub0, data_dev)

        def body(carry, _):
            st, _, k, batch = carry
            k_next, sub = jax.random.split(k)
            nb = sample(sub, data_dev)          # round t+1, drawn during t
            st, metrics = engine.round_core(eng, grad_fn, la_fn, st, batch)
            return (st, k, k_next, nb), _mets(metrics)

        (state, key, _, _), mets = jax.lax.scan(
            body, (state, key, k1, batch0), None, length=length)
        return state, key, mets

    return chunk


def _match_placement(new: Any, ref: Any) -> Any:
    """Place every leaf of ``new`` on its counterpart's NamedSharding in
    ``ref`` — injected host arrays must not silently decay a sharded (or
    mesh-replicated) SPMD state slot to single-device.  Plain single-device
    leaves are left alone: committing them would change the local jit
    cache key and force a needless re-trace."""
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda n, r: (jax.device_put(n, r.sharding)
                      if isinstance(getattr(r, "sharding", None),
                                    NamedSharding) else n), new, ref)


def masked_round_state(state: dict, masks: Any, filter_masks: Any = None
                       ) -> dict:
    """Inject FedAP keep-masks into a live masked round state: momentum
    (and the FedProx/FedDyn client_state corrections) restarts, params are
    masked, shapes and shardings — and therefore the compiled (or lowered
    SPMD) program — are untouched.  The canonical
    implementation behind both the executor's ``Prune(mode="mask")`` apply
    and the pod path's :func:`repro.launch.steps.with_masks`."""
    new = {k: (jax.tree.map(jnp.zeros_like, v)
               if k in ("server_m", "global_m", "client_state") else v)
           for k, v in state.items()}
    new["params"] = _match_placement(
        engine.apply_masks(state["params"], masks), state["params"])
    new["masks"] = _match_placement(
        jax.tree.map(lambda m: jnp.asarray(m, jnp.float32), masks),
        state["masks"])
    if filter_masks is not None:
        # copy, not asarray: the next scan chunk donates the state, and the
        # caller retains the same mask arrays as prune artifacts
        new["filter_masks"] = _match_placement(
            jax.tree.map(lambda m: jnp.array(m, jnp.float32), filter_masks),
            state["filter_masks"])
    return new


# ---------------------------------------------------------------------------
# Session-scoped compiled-engine cache (the LocalScanBackend's programs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledEngine:
    """The jitted programs for one (model, engine config, sampling shape,
    prefetch mode).  ``model`` is held as a strong reference so the
    ``id(model)`` cache key stays valid for the lifetime of the entry."""

    model: Any
    eng: EngineConfig
    chunk: Any        # (state, key, data_dev, *, length) -> (state, key, mets)
    round_core: Any   # (state, batch) -> (state, metrics)
    evaluate: Any     # (params, x, y) -> (loss, acc)


_COMPILED_CACHE: dict[tuple, CompiledEngine] = {}
_EVAL_CACHE: dict[int, tuple] = {}


def clear_compiled_cache() -> None:
    _COMPILED_CACHE.clear()
    _EVAL_CACHE.clear()


def compiled_engine(model, eng: EngineConfig, sample_kw: dict, *,
                    prefetch: bool = True) -> CompiledEngine:
    """Session-scoped cache of the jitted scan-chunk / round / eval
    programs.  Trainers over the same model object and equal (engine
    config, sampling shape, prefetch mode) share ONE compiled program set —
    e.g. the integration-test matrix re-running baselines over a
    module-scoped model fixture compiles each distinct configuration once
    per session instead of once per trainer."""
    key = (id(model), eng, tuple(sorted(sample_kw.items())), prefetch)
    ce = _COMPILED_CACHE.get(key)
    if ce is not None:
        return ce

    grad_fn, la_fn = model_fns(model, eng)
    chunk = build_chunk(eng, grad_fn, la_fn, sample_kw, prefetch=prefetch)

    ce = CompiledEngine(
        model=model, eng=eng,
        chunk=jax.jit(chunk, static_argnames=("length",), donate_argnums=(0,)),
        round_core=jax.jit(
            lambda state, batch: engine.round_core(eng, grad_fn, la_fn,
                                                   state, batch)),
        evaluate=eval_program(model))
    _COMPILED_CACHE[key] = ce
    return ce


def eval_program(model):
    """The one jitted ``loss_and_acc`` per model per session (shared by
    every backend instance over that model)."""
    ev = _EVAL_CACHE.get(id(model))
    if ev is None:
        ev = (model, jax.jit(model.loss_and_acc))
        _EVAL_CACHE[id(model)] = ev
    return ev[1]


# ---------------------------------------------------------------------------
# The backend protocol + the shared engine-state plumbing
# ---------------------------------------------------------------------------

@runtime_checkable
class ExecutionBackend(Protocol):
    """What the :class:`PlanExecutor` needs from an execution substrate."""

    eng: EngineConfig

    def init_state(self, params) -> dict: ...
    def restore_state(self, state: dict) -> dict: ...
    def run_chunk(self, state: dict, key, length: int): ...
    def evaluate(self, state: dict): ...
    def prune_decision(self, state: dict, init_params): ...
    def apply_prune(self, state: dict, mode: str, kept, *,
                    compact_existing: bool = False): ...
    def snapshot(self, state: dict): ...
    def snapshot_artifact(self, state: dict, t: int) -> dict: ...
    def replace_params(self, state: dict, params) -> dict: ...


class _EngineBackend:
    """Backend plumbing shared by local and mesh execution: round-state
    construction, the Prune apply (mask inject / shrink re-materialize /
    momentum-preserving compaction), and the legacy Callback restart."""

    model: Any
    eng: EngineConfig

    @property
    def _kernel_masks(self) -> bool:
        return self.eng.use_masks and self.eng.masked_compute == "kernel"

    @property
    def _num_clients(self) -> int:
        """Total client count — sizes the FedDyn per-client state slot."""
        return int(self.data.client_x.shape[0])

    def _place_state(self, state: dict) -> dict:
        """Hook for backends that pin state to explicit shardings."""
        return state

    def init_state(self, params) -> dict:
        fmasks = (init_filter_masks(self.model, params)
                  if self._kernel_masks else None)
        # the scan chunk donates its input state — never the caller's arrays
        state = engine.init_round_state(jax.tree.map(jnp.copy, params),
                                        self.eng, filter_masks=fmasks,
                                        num_clients=self._num_clients)
        return self._place_state(state)

    def restore_state(self, state: dict) -> dict:
        """Re-admit a checkpointed (host NumPy) round state: leaves go back
        on device with dtypes preserved, and the mesh backend re-pins them
        to their ``fl_state_specs`` shardings — f32 arrays round-trip
        through npz bit-exactly, which the resume-bit-identity tests
        lock."""
        return self._place_state(jax.tree.map(jnp.asarray, state))

    def snapshot(self, state: dict):
        # a copy: the next scan chunk donates the round state, which would
        # invalidate retained params
        return jax.tree.map(jnp.copy, state["params"])

    def snapshot_artifact(self, state: dict, t: int) -> dict:
        """A `Snapshot` artifact whose params copy is DEFERRED: the live
        param tree is loaned out and only copied right before the next
        donating chunk launch (``_secure_loans``).  A plan's trailing
        snapshot therefore costs zero copies, and mid-plan snapshots copy
        exactly once, off the per-event path — without ever aliasing a
        donated buffer."""
        art = {"round": t, "params": state["params"]}
        self._loans().append(art)
        return art

    def _loans(self) -> list:
        loans = getattr(self, "_loaned_artifacts", None)
        if loans is None:
            loans = self._loaned_artifacts = []
        return loans

    def _secure_loans(self) -> None:
        """Copy every pending loaned artifact in place.  Called before any
        donating call: the loaned trees may alias the state about to be
        donated (and we deliberately do not track which prune/replace
        events rebuilt the state in between — copying a still-valid loan
        is merely the eager behavior this buffer avoids on the fast
        path)."""
        loans = self._loans()
        for art in loans:
            art["params"] = jax.tree.map(jnp.copy, art["params"])
        loans.clear()

    def replace_params(self, state: dict, params) -> dict:
        """The legacy hook contract: replacement params re-initialize the
        round state (momentum restart) with the round counter preserved; an
        earlier mask-mode prune decision stays in force."""
        round_ = state["round"]
        masks = state.get("masks")
        fmasks = state.get("filter_masks")
        new_state = engine.init_round_state(
            jax.tree.map(jnp.copy, params), self.eng, filter_masks=fmasks,
            num_clients=self._num_clients)
        new_state["round"] = round_
        if masks is not None:
            new_state["masks"] = masks
            new_state["params"] = engine.apply_masks(new_state["params"],
                                                     masks)
        return self._place_state(new_state)

    def apply_prune(self, state: dict, mode: str, kept, *,
                    compact_existing: bool = False):
        """Apply a FedAP decision.  mask: inject keep-masks into the carry
        (same compiled program keeps running, momentum restarts); shrink:
        re-materialize the smaller model (next chunk re-traces).
        ``compact_existing`` (the mask-now-shrink-later follow-up) compacts
        the CURRENT masked state — params AND momentum buffers — at the
        already-decided kept indices instead of restarting momentum, so
        masked-then-shrunk training continues exactly like
        shrink-from-the-start on normalization-free models."""
        params = jax.tree.map(jnp.copy, state["params"])
        round_ = state["round"]

        if mode == "mask":
            masks = param_masks_for(self.model, params, kept)
            fmasks = filter_masks_for(self.model, params, kept)
            new_state = masked_round_state(
                state, masks,
                filter_masks=fmasks if self._kernel_masks else None)
            return self._place_state(new_state), {"filter_masks": fmasks}

        new_params = shrink_params_for(self.model, params, kept)
        # kernel mode: all-ones filter masks at the SHRUNK shapes — the
        # compacted model has nothing left to skip
        fm = (init_filter_masks(self.model, new_params)
              if self._kernel_masks else None)
        # FedDyn corrections restart as zeros at the SHRUNK shapes: the old
        # h lives in the pre-prune coordinate system and cannot be compacted
        # meaningfully (the correction re-accumulates within a few rounds)
        new_state = engine.init_round_state(new_params, self.eng,
                                            filter_masks=fm,
                                            num_clients=self._num_clients)
        if compact_existing:
            new_state["server_m"] = shrink_params_for(
                self.model, jax.tree.map(jnp.copy, state["server_m"]), kept)
            if "global_m" in state:
                new_state["global_m"] = shrink_params_for(
                    self.model, jax.tree.map(jnp.copy, state["global_m"]),
                    kept)
        new_state["round"] = round_
        # the shrink discards the pre-prune params — record them
        return self._place_state(new_state), {"params_before": params}


# ---------------------------------------------------------------------------
# LocalScanBackend — the single-host scan path
# ---------------------------------------------------------------------------

class LocalScanBackend(_EngineBackend):
    """Session-cached jitted scan chunks over the whole federated dataset
    resident on ONE device — the paper's 100-device simulation setting."""

    name = "local"

    def __init__(self, model, data, cfg, *, use_masks: bool = False,
                 data_cache: dict | None = None):
        from repro.core.rounds import engine_config

        self.model, self.data, self.cfg = model, data, cfg
        self.eng = dataclasses.replace(engine_config(cfg),
                                       use_masks=use_masks)
        self.sample_kw = sim_sample_kw(cfg, data)
        # shared per-trainer: both mask-mode backend instances read the
        # SAME device-resident dataset (one transfer, one HBM copy)
        self._data_cache = {} if data_cache is None else data_cache

    def _compiled(self) -> CompiledEngine:
        return compiled_engine(self.model, self.eng, self.sample_kw,
                               prefetch=self.cfg.prefetch_sampling)

    @property
    def chunk(self):
        return self._compiled().chunk

    def device_data(self) -> dict:
        d = self._data_cache.get("local")
        if d is None:
            d = self.data.device_arrays()
            self._data_cache["local"] = d
        return d

    def run_chunk(self, state, key, length):
        self._secure_loans()   # the jitted chunk donates `state`
        return self._compiled().chunk(state, key, self.device_data(),
                                      length=length)

    def evaluate(self, state):
        d = self.device_data()
        return self._compiled().evaluate(state["params"], d["test_x"],
                                         d["test_y"])

    def prune_decision(self, state, init_params):
        from repro.core import fedap

        params = jax.tree.map(jnp.copy, state["params"])
        return fedap.fedap_decision(
            self.model, self.data, self.cfg.fedap, params,
            init_params=init_params,
            rng=np.random.default_rng(self.cfg.seed))


# ---------------------------------------------------------------------------
# MeshBackend — the client-sharded SPMD path
# ---------------------------------------------------------------------------

class MeshBackend(_EngineBackend):
    """The same scan-compiled rounds, client-sharded over a device mesh.

    * the federated dataset is placed with the client dimension sharded
      over the mesh client axes (``FederatedData.device_arrays(mesh=)``);
    * the in-scan sampled round batch is sharding-constrained
      (``fl_specs.fl_sim_batch_specs``), so the local-epoch vmap runs
      client-parallel across devices, the FedAvg einsum partitions into
      per-shard partial sums + one all-reduce, and — ``shard_server``
      (default on) — the PER-STEP batch dim of ``batch["server"]`` shards
      over the same axes, so each of the tau FedDU server-update steps
      (the Formula 4-7 scan) is data-parallel instead of redundantly
      replicated on every device; GSPMD inserts the collectives, so
      `round_core` itself is untouched and the numerics stay within float
      tolerance of the local path (locked per round against
      LocalScanBackend AND the f64 oracle by tests/test_mesh_backend.py,
      first-step ``server_acc``/tau_eff gate included);
    * evaluation (``shard_eval``, default on) shards the test split's
      batch dim over the mesh instead of running a replicated full-test
      pass; non-divisible test sizes are padded at placement time with
      copies of row 0 and the eval program subtracts the padded rows'
      contribution exactly (`_eval_program`);
    * engine state follows ``fl_specs.fl_state_specs`` (replicated for the
      simulation models, which publish no model-sharding axes);
    * Prune events run pod-side: ``fedap.fedap_decision_sharded`` computes
      the probe/Fisher statistics on mesh-sharded participants, a mask
      decision is injected through ``launch.steps.with_masks`` — the
      chunk program is NOT re-lowered (mask mode keeps every shape, and
      the carry structure was final from round 0) — and a SHRINK runs as
      one jitted shard-local compaction (``NamedSharding`` outputs, no
      host round-trip of params or momentum; see ``apply_prune``).
    """

    name = "mesh"

    def __init__(self, model, data, cfg, *, use_masks: bool = False,
                 mesh=None, data_cache: dict | None = None,
                 shard_server: bool = True, shard_eval: bool = True):
        from repro.core.rounds import engine_config
        from repro.launch.mesh import make_host_mesh
        from repro.sharding.specs import MeshPlan

        self.model, self.data, self.cfg = model, data, cfg
        self.eng = dataclasses.replace(engine_config(cfg),
                                       use_masks=use_masks)
        self.sample_kw = sim_sample_kw(cfg, data)
        self._data_cache = {} if data_cache is None else data_cache
        self.shard_server = shard_server
        self.shard_eval = shard_eval
        self.mesh = mesh if mesh is not None else make_host_mesh(model=1)
        axes = dict(self.mesh.shape)
        if "data" not in axes:
            raise ValueError(
                f"MeshBackend needs a 'data' mesh axis to host FL clients; "
                f"got axes {tuple(axes)}")
        self.plan = MeshPlan(
            mesh=self.mesh, multi_pod="pod" in axes,
            client_axes=(("pod", "data") if "pod" in axes else ("data",)),
            fsdp_axes=(), tp_axes=(("model",) if "model" in axes else ()),
            batch_axes=(), num_clients=axes["data"] * axes.get("pod", 1))
        self._chunk = None
        self._eval = None
        self._shrink_cache: dict = {}

    # -- shardings -----------------------------------------------------------
    def _named(self, spec_tree):
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def _place_state(self, state: dict) -> dict:
        from repro.sharding.fl_specs import fl_state_specs

        return jax.device_put(state, self._named(
            fl_state_specs(state, None, self.plan,
                           client_axes=self.plan.client_axes)))

    def device_data(self) -> dict:
        # Mesh hashes by devices + axis names, so equal meshes built
        # independently still share one device-resident dataset copy
        key = ("mesh", self.mesh, self.shard_eval)
        d = self._data_cache.get(key)
        if d is None:
            d = self.data.device_arrays(mesh=self.mesh,
                                        client_axes=self.plan.client_axes,
                                        shard_test=self.shard_eval)
            self._data_cache[key] = d
        return d

    # -- programs ------------------------------------------------------------
    def _programs(self):
        if self._chunk is None:
            from repro.sharding.fl_specs import fl_sim_batch_specs

            grad_fn, la_fn = model_fns(self.model, self.eng)
            shardings = self._named(fl_sim_batch_specs(
                self.cfg.clients_per_round, self.plan,
                server_batch=(self.cfg.server_batch_size
                              if self.shard_server else None),
                with_active=bool(self.sample_kw.get("dropout_rate"))))

            def constrain(batch):
                return jax.lax.with_sharding_constraint(batch, shardings)

            chunk = build_chunk(self.eng, grad_fn, la_fn, self.sample_kw,
                                prefetch=self.cfg.prefetch_sampling,
                                constrain=constrain)
            self._chunk = jax.jit(chunk, static_argnames=("length",),
                                  donate_argnums=(0,))
        return self._chunk

    def _eval_program(self):
        """The batch-sharded eval program — built WITHOUT lowering the
        chunk program, so ``evaluate`` on a fresh backend stays cheap.

        The placed test split (``device_data``) is padded with copies of
        row 0 up to a multiple of the mesh client axes and sharded on its
        batch dim; padding keeps the shard genuinely data-parallel for ANY
        test size, and because every padded row IS row 0, its contribution
        is subtracted back out exactly:

            mean_true = (mean_pad * n_pad - k * f(row 0)) / n_true

        one extra single-row forward per Eval, instead of every device
        redundantly re-running the whole test set."""
        if self._eval is None:
            if not self.shard_eval:
                self._eval = eval_program(self.model)
                return self._eval
            la = self.model.loss_and_acc
            n_true = int(self.data.test_x.shape[0])

            def eval_fn(params, x, y):
                loss, acc = la(params, x, y)
                n_pad = x.shape[0]
                if n_pad == n_true:          # static: no padding was needed
                    return loss, acc
                k = float(n_pad - n_true)
                l0, a0 = la(params, x[:1], y[:1])
                return ((loss * n_pad - k * l0) / n_true,
                        (acc * n_pad - k * a0) / n_true)

            self._eval = jax.jit(eval_fn)
        return self._eval

    @property
    def chunk(self):
        return self._programs()

    def run_chunk(self, state, key, length):
        from jax.sharding import NamedSharding, PartitionSpec as P

        self._secure_loans()   # the jitted chunk donates `state`
        # pin the key to the mesh (replicated): a fresh host key is
        # uncommitted while the chunk's output key is mesh-committed, and
        # that sharding difference alone would re-trace the chunk program
        key = jax.device_put(key, NamedSharding(self.mesh, P()))
        return self._programs()(state, key, self.device_data(),
                                length=length)

    def evaluate(self, state):
        d = self.device_data()
        return self._eval_program()(state["params"], d["test_x"],
                                    d["test_y"])

    # -- pod-side FedAP ------------------------------------------------------
    def prune_decision(self, state, init_params):
        from repro.core import fedap

        params = jax.tree.map(jnp.copy, state["params"])
        return fedap.fedap_decision_sharded(
            self.model, self.data, self.cfg.fedap, params,
            init_params=init_params,
            rng=np.random.default_rng(self.cfg.seed),
            mesh=self.mesh, client_axes=self.plan.client_axes)

    def apply_prune(self, state, mode, kept, *, compact_existing=False):
        if mode != "mask":
            return self._sharded_shrink(state, kept,
                                        compact_existing=compact_existing)
        # mask mode: the pod-path injection helper — shapes, shardings and
        # the lowered chunk program are untouched
        from repro.launch.steps import with_masks

        params = state["params"]
        masks = param_masks_for(self.model, params, kept)
        fmasks = filter_masks_for(self.model, params, kept)
        new_state = with_masks(
            state, masks,
            filter_masks=fmasks if self._kernel_masks else None)
        return self._place_state(new_state), {"filter_masks": fmasks}

    def _sharded_shrink(self, state, kept, *, compact_existing):
        """``Prune(mode="shrink")`` without the host round-trip.

        The base-class shrink re-materializes eagerly (one dispatch per
        sliced tensor) and re-places the result via ``device_put`` — fine
        on one device, but at pod scale it serializes the prune round
        through the host.  Here the WHOLE compaction — gather of the kept
        filters from params (and, with ``compact_existing``, the momentum
        buffers — the ``reuse="prune"`` mask-now-shrink-later path), fresh
        zeros/ones for the restarted slots, the preserved round counter —
        is ONE jitted program whose ``out_shardings`` pin every leaf of
        the new state to its ``fl_state_specs`` NamedSharding: the
        compacted state is born mesh-committed, shard-locally, and the
        next chunk re-traces only because the shapes genuinely changed.
        """
        from repro.sharding.fl_specs import fl_state_specs

        # the shrink discards the pre-prune params — record a device copy
        # (never materialized on the host)
        params_before = jax.tree.map(jnp.copy, state["params"])

        # the jitted compaction is cached per (decision, momentum mode,
        # state structure), so re-applying the same decision — the
        # benchmark's warm timing, or repeated reuse-shrinks — runs the
        # already-compiled program.  Kept-index arrays may be [d] (spec
        # models) or [L, keep] (scanned stacks) — key on shape + raveled
        # values.
        cache_key = (tuple((k, np.asarray(v).shape,
                            tuple(int(i) for i in np.asarray(v).ravel()))
                           for k, v in sorted(kept.items())),
                     bool(compact_existing), tuple(sorted(state)))
        compacted = self._shrink_cache.get(cache_key)
        if compacted is None:
            def compact(st):
                params = shrink_params_for(self.model, st["params"], kept)
                # kernel mode: all-ones filter masks at the SHRUNK shapes —
                # the compacted model has nothing left to skip
                fm = (init_filter_masks(self.model, params)
                      if self._kernel_masks else None)
                new = engine.init_round_state(params, self.eng,
                                              filter_masks=fm,
                                              num_clients=self._num_clients)
                if compact_existing:
                    new["server_m"] = shrink_params_for(
                        self.model, st["server_m"], kept)
                    if "global_m" in st:
                        new["global_m"] = shrink_params_for(
                            self.model, st["global_m"], kept)
                new["round"] = st["round"]
                return new

            out_shardings = self._named(fl_state_specs(
                jax.eval_shape(compact, state), None, self.plan,
                client_axes=self.plan.client_axes))
            compacted = jax.jit(compact, out_shardings=out_shardings)
            self._shrink_cache[cache_key] = compacted
        return compacted(state), {"params_before": params_before}


# ---------------------------------------------------------------------------
# The executor — ONE schedule loop over any backend
# ---------------------------------------------------------------------------

class PlanExecutor:
    """Executes a :class:`TrainPlan` against an :class:`ExecutionBackend`.

    All schedule semantics live HERE, once: history rows record the true
    completed-round count ``t`` (Eval AND Callback), artifact keys
    deduplicate with ``#k`` suffixes, ``Prune(reuse=...)`` re-applies an
    earlier event's kept-filter decision instead of re-running Algorithm 3,
    and a Callback returning params restarts the round state through the
    backend (the legacy hook contract).

    Fault tolerance also lives here: a plan with ``checkpoint_dir`` set is
    durably snapshotted at chunk boundaries (round state + key chain +
    plan cursor + history/artifacts, atomic write — see
    :mod:`repro.reliability.checkpoint`), ``run(resume=payload)``
    continues a killed run bit-identically, and host faults
    (``reliability.KillAfterChunk``, threaded via ``faults=``) raise
    :class:`~repro.reliability.faults.SimulatedCrash` at the boundary a
    real preemption would hit — AFTER the checkpoint write.
    """

    def __init__(self, backend: ExecutionBackend, *, trainer=None,
                 faults=()):
        self.backend = backend
        self.trainer = trainer
        self._host_faults = tuple(f for f in faults
                                  if hasattr(f, "chunks"))

    def run(self, plan: TrainPlan, *, params=None, key=None, resume=None):
        """Returns (RunResult, advanced key).  Exactly one of ``params``/
        ``key`` or ``resume`` (a ``reliability.load_checkpoint`` payload)
        selects a fresh or a continued run."""
        backend = self.backend
        ckpt_dir = plan.checkpoint_dir
        if resume is not None:
            if params is not None or key is not None:
                raise ValueError("run(resume=...) restores params and key "
                                 "from the checkpoint — pass neither")
            # Everything the loop below mutates comes back from the
            # snapshot; the scan key chain continues from the EXACT key the
            # interrupted run held at the boundary.
            init_params = jax.tree.map(jnp.asarray, resume["init_params"])
            state = backend.restore_state(resume["state"])
            key = jax.random.wrap_key_data(jnp.asarray(resume["key_data"]))
            history = {k: list(v) for k, v in resume["history"].items()}
            artifacts: dict[str, Any] = dict(resume["artifacts"])
            t = int(resume["t"])
            last_tau = float(resume["last_tau"])
            chunks_done = int(resume["chunks_done"])
            start = int(resume["cursor"])
            t0 = time.time() - float(resume.get("elapsed", 0.0))
        else:
            if params is None or key is None:
                raise ValueError("run() needs params= and key= "
                                 "(or resume=)")
            # Prune events estimate the Lipschitz constant against the
            # params the run started from (the legacy hooks took them
            # explicitly).
            init_params = jax.tree.map(jnp.copy, params)
            state = backend.init_state(params)
            history = {"round": [], "acc": [], "loss": [], "tau_eff": [],
                       "time": [], "health": []}
            artifacts = {}
            t0 = time.time()
            t = 0
            last_tau = 0.0
            chunks_done = 0
            start = 0

        def record(name, value):
            k, i = name, 1
            while k in artifacts:
                k = f"{name}#{i}"
                i += 1
            artifacts[k] = value

        def write_checkpoint(cursor):
            from repro.reliability.checkpoint import (
                plan_spec,
                save_checkpoint,
            )

            backend._secure_loans()   # loaned artifacts may alias state
            save_checkpoint(ckpt_dir, {
                "state": state, "key_data": jax.random.key_data(key),
                "cursor": cursor, "t": t, "chunks_done": chunks_done,
                "last_tau": last_tau, "history": history,
                "artifacts": artifacts, "init_params": init_params,
                "plan": plan_spec(plan),
                "checkpoint_every": plan.checkpoint_every,
                "checkpoint_dir": str(ckpt_dir),
                "backend": backend.name,
                "elapsed": time.time() - t0,
            })

        events = plan.compiled()
        for idx, ev in enumerate(events):
            if idx < start:     # resumed: this event already completed
                continue
            if isinstance(ev, Scan):
                state, key, mets = backend.run_chunk(state, key, ev.rounds)
                t += ev.rounds
                last_tau = float(mets["tau_eff"][-1])
                history["health"].extend(
                    float(h) for h in np.asarray(mets["health"]))
                chunks_done += 1
                if (ckpt_dir is not None
                        and chunks_done % plan.checkpoint_every == 0):
                    write_checkpoint(idx + 1)
                # Host faults fire AFTER the checkpoint write — exactly
                # where a real between-chunks preemption lands.  Counted
                # over the WHOLE run, so a resumed run that restored
                # chunks_done past the fault does not re-die.
                for f in self._host_faults:
                    if f.chunks == chunks_done:
                        from repro.reliability.faults import SimulatedCrash

                        raise SimulatedCrash(
                            f"injected kill after chunk {chunks_done} "
                            f"(round {t})")
            elif isinstance(ev, Eval):
                loss, acc = backend.evaluate(state)
                # the TRUE round count: t rounds have completed when this
                # Eval runs, so a leading Eval() (evaluate-before-training)
                # records round 0, not a fabricated round -1
                history["round"].append(t)
                history["acc"].append(float(acc))
                history["loss"].append(float(loss))
                history["tau_eff"].append(last_tau)
                history["time"].append(time.time() - t0)
            elif isinstance(ev, Snapshot):
                # donation-aware: the copy is deferred until the next
                # donating chunk launch (see _EngineBackend.snapshot_artifact)
                record(ev.name, backend.snapshot_artifact(state, t))
            elif isinstance(ev, Prune):
                state, art = self._prune(ev, state, init_params, artifacts)
                record(ev.name, art)
            elif isinstance(ev, Callback):
                # the true completed-round count (NOT t-1 — mirrors the
                # Eval fix); params are a copy because the next scan chunk
                # donates the round state
                maybe = ev.fn(self.trainer, t, backend.snapshot(state))
                if maybe is not None:   # legacy contract: replace + restart
                    state = backend.replace_params(state, maybe)
            else:  # pragma: no cover — TrainPlan validates event types
                raise TypeError(f"unknown plan event: {ev!r}")

        return (RunResult(params=state["params"], history=history,
                          artifacts=artifacts, state=state), key)

    def _prune(self, ev: Prune, state: dict, init_params,
               artifacts: dict):
        """Decision + apply of one Prune event -> (new state, artifact)."""
        backend = self.backend
        if ev.reuse is not None:
            # the MOST RECENT artifact under that name: record() renames
            # repeated events to "name#k", and a reuse-shrink must compact
            # to the decision currently in force, not the first one
            src = None
            for k, v in artifacts.items():
                if (k.split("#", 1)[0] == ev.reuse
                        and isinstance(v, dict) and "kept" in v):
                    src = v
            if src is None:
                raise ValueError(
                    f"Prune(reuse={ev.reuse!r}) found no earlier prune "
                    f"artifact named {ev.reuse!r} (have: "
                    f"{sorted(artifacts)})")
            kept = src["kept"]
            new_state, extra = backend.apply_prune(state, ev.mode, kept,
                                                   compact_existing=True)
            art = {"mode": ev.mode, "reused": ev.reuse, "kept": kept,
                   # last axis: [d] kept vectors (spec models) and
                   # [L, keep] rows (scanned stacks) both count per layer
                   "kept_counts": {k: int(np.asarray(v).shape[-1])
                                   for k, v in kept.items()},
                   "p_star": src.get("p_star"),
                   "layer_rates": src.get("layer_rates")}
        else:
            decision = backend.prune_decision(state, init_params)
            art = decision.summary()
            art["kept"] = decision.kept
            art["mode"] = ev.mode
            new_state, extra = backend.apply_prune(state, ev.mode,
                                                   decision.kept)
        art.update(extra)
        return new_state, art
