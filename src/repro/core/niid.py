"""Non-IID degree quantification (paper Formulas 2-3).

The non-IID degree of a dataset is the Jensen-Shannon divergence between
its label distribution P_k and the global device-data distribution P_bar:

    D(P_k) = 1/2 KL(P_k || P_m) + 1/2 KL(P_bar || P_m),   P_m = (P_k + P_bar)/2

Only label histograms (P_k, n_k) travel to the server — never raw data —
matching the paper's privacy assumption (Section 3.1).
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def kl_divergence(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """KL(p || q) over the last axis, safe for zero entries (0*log0 = 0)."""
    p = jnp.asarray(p, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    ratio = jnp.log(jnp.clip(p, _EPS, None)) - jnp.log(jnp.clip(q, _EPS, None))
    return jnp.sum(jnp.where(p > 0, p * ratio, 0.0), axis=-1)


def js_divergence(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    m = 0.5 * (p + q)
    return 0.5 * kl_divergence(p, m) + 0.5 * kl_divergence(q, m)


def label_distribution(labels: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Empirical P_k from integer labels."""
    counts = jnp.bincount(labels.reshape(-1), length=num_classes).astype(jnp.float32)
    return counts / jnp.clip(jnp.sum(counts), 1.0, None)


def global_distribution(client_dists: jnp.ndarray, client_sizes: jnp.ndarray) -> jnp.ndarray:
    """P_bar = sum_k n_k P_k / sum_k n_k  over ALL devices (Formula 2 text).

    client_dists: [N, num_classes]; client_sizes: [N].
    """
    w = jnp.asarray(client_sizes, jnp.float32)
    w = w / jnp.clip(jnp.sum(w), 1.0, None)
    return jnp.einsum("k,kc->c", w, jnp.asarray(client_dists, jnp.float32))


def non_iid_degree(p_k: jnp.ndarray, p_bar: jnp.ndarray) -> jnp.ndarray:
    """D(P_k) — Formula 2. Higher = further from the global distribution."""
    return js_divergence(jnp.asarray(p_k, jnp.float32), jnp.asarray(p_bar, jnp.float32))


def round_distribution(client_dists: jnp.ndarray, client_sizes: jnp.ndarray,
                       selected: jnp.ndarray) -> jnp.ndarray:
    """P_bar'^t — distribution of the data held by the devices selected in
    round t (Formula 7).  ``selected`` is an index array into the clients."""
    return global_distribution(client_dists[selected], client_sizes[selected])
