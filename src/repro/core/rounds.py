"""Federated round driver — the paper's 6-step training loop (Section 3.1).

One round:
  (1) select a random device subset D^t, broadcast w^{t-1};
  (2) each device runs E local epochs (SGD, or restart-SGDM for FedDUM);
  (3) devices upload models;
  (4) server aggregates with FedAvg weights n_k/n';
  (5) server update on shared data with dynamic tau_eff (FedDU), optionally
      through the server-momentum pseudo-gradient path (FedDUM);
  (6) at the predefined round, FedAP prunes the model structurally.

This driver is the *simulation* engine (the paper's 100-device setting,
vectorized with vmap over the selected clients — all clients share n_k in
the paper's label-shard protocol, so local step counts are equal and vmap
is exact).  The pod-scale distributed execution lives in repro/launch.

Momentum modes (covers the paper's baselines):
  local_momentum = "none"         plain local SGD (FedAvg, FedDU)
                 = "restart"      FedDUM's zero-restart SGDM — no comm cost
                 = "communicated" FedDA-style: global momentum broadcast to
                                  devices and aggregated back (2x comm)
  server_momentum = True          SGDM on the server pseudo-gradient
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import niid
from repro.core.momentum import (
    FedDUMConfig,
    init_server_momentum,
    server_momentum_step,
    server_pseudo_gradient,
)
from repro.core.server_update import (
    FedDUConfig,
    feddu_apply,
    normalized_server_gradient_scan,
    tau_eff,
)
from repro.core.pruning import FedAPConfig
from repro.utils import tree_weighted_mean


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100
    clients_per_round: int = 10
    local_epochs: int = 5          # E
    batch_size: int = 10           # B
    lr: float = 0.1                # eta (local and server SGD)
    lr_decay: float = 0.99         # per-round learning-rate decay (paper 4.1)
    seed: int = 0
    # Feature switches — FedDUMAP = server update + restart momentum (+FedAP).
    use_server_update: bool = True       # FedDU
    local_momentum: str = "none"         # none | restart | communicated
    server_momentum: bool = False
    # Server data usage per round: tau = server_epochs * floor(n0 / B_server).
    server_epochs: int = 1
    server_batch_size: int = 32
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    feddum: FedDUMConfig = dataclasses.field(default_factory=FedDUMConfig)
    fedap: FedAPConfig = dataclasses.field(default_factory=FedAPConfig)


def feddumap_config(**kw) -> FLConfig:
    """The full method: FedDU + FedDUM (FedAP is wired via callback)."""
    kw.setdefault("use_server_update", True)
    kw.setdefault("local_momentum", "restart")
    kw.setdefault("server_momentum", True)
    return FLConfig(**kw)


class FederatedTrainer:
    """Simulation-grade FL trainer.

    model: an object exposing
        init(rng) -> params
        loss_and_acc(params, x, y) -> (scalar loss, scalar acc)
    data: repro.data.pipeline.FederatedData
    """

    def __init__(self, model, data, cfg: FLConfig):
        self.model, self.data, self.cfg = model, data, cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._build()

    # -- static, jit-compiled round step (rebuilt after pruning) ------------
    def _build(self):
        cfg, model = self.cfg, self.model

        def loss_fn(params, x, y):
            return model.loss_and_acc(params, x, y)[0]

        grad_fn = jax.grad(loss_fn)

        def local_train(params, m0, xs, ys, lr):
            """E local epochs on one client.  xs: [steps, B, ...]."""
            use_m = cfg.local_momentum != "none"
            beta = cfg.feddum.beta_local

            def body(carry, batch):
                p, m = carry
                g = grad_fn(p, batch[0], batch[1])
                if use_m:
                    m = jax.tree.map(
                        lambda mi, gi: beta * mi + (1 - beta) * gi.astype(jnp.float32), m, g)
                    upd = m
                else:
                    upd = g
                p = jax.tree.map(lambda pi, u: (pi - lr * u).astype(pi.dtype), p, upd)
                return (p, m), None

            (params, m), _ = jax.lax.scan(body, (params, m0), (xs, ys))
            return params, m

        def round_step(params, server_m, global_m, client_xs, client_ys, sizes,
                       server_xs, server_ys, d_round, d_server, n0, round_idx, lr):
            """One full round. client_xs: [K, steps, B, ...]."""
            w_prev = params
            if cfg.local_momentum == "communicated":
                m0 = global_m                         # FedDA: broadcast momentum
            else:
                m0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            locals_, local_ms = jax.vmap(
                local_train, in_axes=(None, None, 0, 0, None))(params, m0, client_xs,
                                                               client_ys, lr)
            per_client = [jax.tree.map(lambda l, i=i: l[i], locals_)
                          for i in range(cfg.clients_per_round)]
            w_half = tree_weighted_mean(per_client, sizes)
            if cfg.local_momentum == "communicated":  # FedDA aggregates momentum too
                global_m = tree_weighted_mean(
                    [jax.tree.map(lambda l, i=i: l[i], local_ms)
                     for i in range(cfg.clients_per_round)], sizes)

            if cfg.use_server_update:
                # acc of the aggregated model on the server data (Formula 7).
                acc = model.loss_and_acc(
                    w_half, server_xs.reshape((-1,) + server_xs.shape[2:]),
                    server_ys.reshape(-1))[1]
                tau = server_xs.shape[0]
                t_eff = tau_eff(cfg.feddu, acc=acc, round_idx=round_idx, n0=n0,
                                n_prime=jnp.sum(sizes), d_round=d_round,
                                d_server=d_server, tau=tau)
                g0 = normalized_server_gradient_scan(
                    w_half, (server_xs, server_ys),
                    lambda p, b: grad_fn(p, b[0], b[1]), lr)
                proposed = feddu_apply(w_half, g0, t_eff, lr)
            else:
                proposed = w_half
                t_eff = jnp.zeros(())

            if cfg.server_momentum:
                pseudo = server_pseudo_gradient(w_prev, proposed)
                new_params, server_m = server_momentum_step(w_prev, server_m, pseudo,
                                                            cfg.feddum)
            else:
                new_params = proposed
            return new_params, server_m, global_m, t_eff

        self._round = jax.jit(round_step)
        self._eval = jax.jit(model.loss_and_acc)

    # -- data plumbing -------------------------------------------------------
    def _client_batches(self, k: int):
        cfg, d = self.cfg, self.data
        n_k = int(d.sizes[k])
        steps = max(1, n_k // cfg.batch_size) * cfg.local_epochs
        idx = np.concatenate([
            self.rng.permutation(n_k) for _ in range(cfg.local_epochs + 1)
        ])[: steps * cfg.batch_size]
        xs = d.client_x[k][idx].reshape(steps, cfg.batch_size, *d.client_x.shape[2:])
        ys = d.client_y[k][idx].reshape(steps, cfg.batch_size)
        return xs, ys

    def _server_batches(self):
        cfg, d = self.cfg, self.data
        n0 = d.server_x.shape[0]
        tau = max(1, n0 // cfg.server_batch_size) * cfg.server_epochs
        idx = np.concatenate([
            self.rng.permutation(n0) for _ in range(cfg.server_epochs + 1)
        ])[: tau * cfg.server_batch_size]
        xs = d.server_x[idx].reshape(tau, cfg.server_batch_size, *d.server_x.shape[1:])
        ys = d.server_y[idx].reshape(tau, cfg.server_batch_size)
        return xs, ys

    # -- public API ----------------------------------------------------------
    def run(self, num_rounds: int, *, eval_every: int = 1,
            on_round_end: Callable | None = None, params=None):
        cfg, d = self.cfg, self.data
        params = self.model.init(jax.random.key(cfg.seed)) if params is None else params
        server_m = init_server_momentum(params)
        global_m = init_server_momentum(params)
        p_bar = niid.global_distribution(d.client_dists, d.sizes)
        d_server = niid.non_iid_degree(d.server_dist, p_bar)
        n0 = float(d.server_x.shape[0])
        history = {"round": [], "acc": [], "loss": [], "tau_eff": [], "time": []}
        t0 = time.time()

        for t in range(num_rounds):
            sel = self.rng.choice(cfg.num_clients, cfg.clients_per_round, replace=False)
            xs, ys = zip(*[self._client_batches(k) for k in sel])
            client_xs, client_ys = np.stack(xs), np.stack(ys)
            sxs, sys_ = self._server_batches()
            p_round = niid.round_distribution(d.client_dists, d.sizes, jnp.asarray(sel))
            d_round = niid.non_iid_degree(p_round, p_bar)
            lr = cfg.lr * (cfg.lr_decay ** t)
            params, server_m, global_m, t_eff = self._round(
                params, server_m, global_m, jnp.asarray(client_xs),
                jnp.asarray(client_ys), jnp.asarray(d.sizes[sel], jnp.float32),
                jnp.asarray(sxs), jnp.asarray(sys_),
                d_round, d_server, n0, jnp.asarray(t, jnp.float32), lr)

            if (t + 1) % eval_every == 0 or t == num_rounds - 1:
                loss, acc = self._eval(params, d.test_x, d.test_y)
                history["round"].append(t)
                history["acc"].append(float(acc))
                history["loss"].append(float(loss))
                history["tau_eff"].append(float(t_eff))
                history["time"].append(time.time() - t0)

            if on_round_end is not None:
                maybe = on_round_end(self, t, params)
                if maybe is not None:          # e.g. FedAP re-materialized the model
                    params = maybe
                    server_m = init_server_momentum(params)
                    global_m = init_server_momentum(params)
                    self._build()              # re-jit for the new shapes
        return params, history
