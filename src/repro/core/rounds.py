"""The simulation trainer: a thin facade over pluggable execution backends.

One round (Section 3.1):
  (1) select a random device subset D^t, broadcast w^{t-1};
  (2) each device runs E local epochs (SGD, or restart-SGDM for FedDUM);
  (3) devices upload models;
  (4) server aggregates with FedAvg weights n_k/n';
  (5) server update on shared data with dynamic tau_eff (FedDU), optionally
      through the server-momentum pseudo-gradient path (FedDUM);
  (6) at the predefined round, FedAP prunes the model — as a scheduled
      ``Prune`` event of the declarative :class:`~repro.core.plan.TrainPlan`.

The round itself lives in :mod:`repro.core.engine` (``round_core``).  HOW a
:class:`TrainPlan` over it executes is the job of
:mod:`repro.core.backend`: the backend-agnostic :class:`PlanExecutor`
drives a narrow backend protocol (init_state / run_chunk / evaluate /
prune_decision / apply_prune / snapshot / replace_params), and
:class:`FederatedTrainer` is only the user-facing facade that picks the
substrate::

    FederatedTrainer(model, data, cfg)                     # local scan
    FederatedTrainer(model, data, cfg, backend="mesh")     # client-sharded

  * ``backend="local"`` (:class:`~repro.core.backend.LocalScanBackend`)
    moves the federated dataset to ONE device
    (:meth:`FederatedData.device_arrays`); client selection and batch
    sampling run on device through `jax.random` keys in the scan carry
    (`engine.sample_round_batches`), and every ``Scan`` segment is ONE
    compiled ``jax.lax.scan`` over ``round_core``, with one jitted chunk
    program cached per (model, engine config, sampling shape, prefetch
    mode) in a session-scoped cache (:func:`compiled_engine`);
  * ``backend="mesh"`` (:class:`~repro.core.backend.MeshBackend`) runs the
    SAME chunk client-sharded over a device mesh: the dataset's client
    dimension and the sampled round batch shard over the mesh client axes
    (`sharding/fl_specs.py`), the FedAvg reduction becomes per-shard
    partial sums + one all-reduce, and ``Prune`` events run pod-side
    (`fedap_decision_sharded` + `launch.steps.with_masks`, no re-lower);
  * both backends double-buffer the in-scan sampling by default
    (``FLConfig.prefetch_sampling``): round t+1's gather is issued while
    round t computes, with a bit-identical key chain and batch sequence;
  * ``Prune(mode="mask")`` injects FedAP keep-masks into the scan carry
    (``EngineConfig.use_masks``) — the prune round and everything after it
    run inside the SAME compiled program; with
    ``FLConfig(masked_compute="kernel")`` filter-level masks also ride in
    the carry and the model fns route masked dense layers through the
    differentiable Pallas ``masked_matmul`` kernel; ``Prune(mode="shrink")``
    re-materializes the smaller model at the segment boundary, and
    ``fedap_plan(..., shrink_round=K)`` chains both (mask now, compact to
    the same decision later — no second FedAP run, no mid-scan re-jit,
    smaller steady-state model);
  * all clients share n_k in the paper's label-shard protocol, so local
    step counts are equal and the engine's client vmap is exact.

Momentum modes (covers the paper's baselines):
  local_momentum = "none"         plain local SGD (FedAvg, FedDU)
                 = "restart"      FedDUM's zero-restart SGDM — no comm cost
                 = "communicated" FedDA-style: global momentum broadcast to
                                  devices and aggregated back (2x comm)
  server_momentum = True          SGDM on the server pseudo-gradient

Every mode is differentially tested against the pure-NumPy oracle in
:mod:`repro.core.ref_engine` (tests/test_engine_diff.py), including the
masked mode; the mesh backend is additionally locked per round against the
local backend AND the oracle (tests/test_mesh_backend.py).

Migrating from the legacy callback API
--------------------------------------
The pre-plan API forced every observer into a per-round host hook, which
collapsed the scan into ``length=1`` chunks::

    hook = make_fedap_hook(model, data, apcfg, init_params=p0)   # OLD
    params, hist = trainer.run(60, eval_every=2, on_round_end=hook)
    kept = hook.result["kept"]

becomes a declarative schedule returning a structured result::

    plan = fedap_plan(60, prune_round=30, mode="mask", eval_every=2)  # NEW
    res = trainer.run(plan)
    params, hist = res.params, res.history
    kept = res.artifacts["prune"]["kept"]

Per-round hooks that must stay (distillation, baseline pruning) migrate to
``TrainPlan.with_callback(60, hook, eval_every=2)`` — the hook signature
``fn(trainer, round_idx, params) -> new params | None`` is unchanged, and
``round_idx`` is the number of COMPLETED rounds when the hook fires (the
first post-round hook of a run sees 1).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.backend import (  # noqa: F401  (public re-exports)
    CompiledEngine,
    LocalScanBackend,
    MeshBackend,
    PlanExecutor,
    clear_compiled_cache,
    compiled_engine,
    sim_sample_kw,
)
from repro.core.engine import (
    ALGORITHMS,
    EngineConfig,
    FedDynConfig,
    FedProxConfig,
)
from repro.core.momentum import FedDUMConfig
from repro.core.plan import RunResult, TrainPlan
from repro.core.pruning import FedAPConfig
from repro.core.server_update import FedDUConfig


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100
    clients_per_round: int = 10
    local_epochs: int = 5          # E
    batch_size: int = 10           # B
    lr: float = 0.1                # eta (local and server SGD)
    lr_decay: float = 0.99         # per-round learning-rate decay (paper 4.1)
    seed: int = 0
    # Feature switches — FedDUMAP = server update + restart momentum (+FedAP).
    use_server_update: bool = True       # FedDU
    local_momentum: str = "none"         # none | restart | communicated
    server_momentum: bool = False
    # Client-state algorithm: "fedavg" (stateless), "fedprox" (proximal
    # pull toward the round-start model), "feddyn" (per-client gradient
    # correction carried in the scan's client_state slot).
    algorithm: str = "fedavg"
    # Straggler/dropout simulation: each selected client independently drops
    # this round with probability dropout_rate; dropped clients contribute
    # zero aggregation weight and their client state is untouched.
    dropout_rate: float = 0.0
    # Masked-mode compute path: "params" zeroes the parameter tree only
    # (full-density matmuls); "kernel" threads filter masks into the model
    # so masked dense layers run the differentiable Pallas masked_matmul
    # (FedAP's FLOP savings realized during training).
    masked_compute: str = "params"
    # Double-buffered in-scan sampling: round t+1's client/server gather is
    # issued while round t computes (bit-identical batches and key chain —
    # purely a scheduling change; False restores the serial draw).
    prefetch_sampling: bool = True
    # In-scan health guard (see engine.round_core): "reject_client" zero-
    # weights non-finite client uploads; "skip_round" additionally discards
    # any round with a rejection.  Guards never change the compiled program
    # count (locked by the compile-budget sentinel).
    guard: str = "off"
    # Deterministic fault injection (tests/benchmarks only): a tuple /
    # reliability.FaultPlan of fault events.  Device faults (NaNGrad,
    # CorruptUpdate) are threaded into the engine config; host faults
    # (KillAfterChunk) fire in the PlanExecutor schedule loop.
    faults: tuple = ()
    # Server data usage per round: tau = server_epochs * floor(n0 / B_server).
    server_epochs: int = 1
    server_batch_size: int = 32
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    feddum: FedDUMConfig = dataclasses.field(default_factory=FedDUMConfig)
    fedap: FedAPConfig = dataclasses.field(default_factory=FedAPConfig)
    fedprox: FedProxConfig = dataclasses.field(default_factory=FedProxConfig)
    feddyn: FedDynConfig = dataclasses.field(default_factory=FedDynConfig)

    def __post_init__(self):
        # Mirror EngineConfig.__post_init__: a bad switch must fail HERE,
        # at construction, with a clear message — not at jit time.
        if self.local_momentum not in ("none", "restart", "communicated"):
            raise ValueError(
                f"unknown local_momentum: {self.local_momentum!r} "
                "(expected 'none', 'restart' or 'communicated')")
        if self.masked_compute not in ("params", "kernel"):
            raise ValueError(
                f"unknown masked_compute: {self.masked_compute!r} "
                "(expected 'params' or 'kernel')")
        if not 1 <= self.clients_per_round <= self.num_clients:
            raise ValueError(
                f"clients_per_round must be in [1, num_clients="
                f"{self.num_clients}], got {self.clients_per_round}")
        for name in ("local_epochs", "batch_size", "server_epochs",
                     "server_batch_size"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        if self.lr <= 0:
            raise ValueError(f"lr must be > 0, got {self.lr}")
        if self.lr_decay <= 0:
            raise ValueError(f"lr_decay must be > 0, got {self.lr_decay}")
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm: {self.algorithm!r} "
                             f"(expected one of {ALGORITHMS})")
        if not 0.0 <= self.dropout_rate < 1.0:
            raise ValueError(f"dropout_rate must be in [0, 1), got "
                             f"{self.dropout_rate}")
        if self.guard not in ("off", "reject_client", "skip_round"):
            raise ValueError(
                f"unknown guard: {self.guard!r} (expected 'off', "
                f"'reject_client' or 'skip_round')")
        for f in self.faults:
            if not (hasattr(f, "apply_client") or hasattr(f, "chunks")):
                raise ValueError(
                    f"FLConfig.faults entries must be reliability fault "
                    f"events (NaNGrad / CorruptUpdate / KillAfterChunk), "
                    f"got {f!r}")


def feddumap_config(**kw) -> FLConfig:
    """The full method: FedDU + FedDUM (+FedAP via a plan Prune event)."""
    kw.setdefault("use_server_update", True)
    kw.setdefault("local_momentum", "restart")
    kw.setdefault("server_momentum", True)
    return FLConfig(**kw)


def engine_config(cfg: FLConfig) -> EngineConfig:
    """The FLConfig -> EngineConfig wiring (locked against the pod path's
    FLRunConfig wiring by tests/test_engine_diff.py)."""
    return EngineConfig(
        lr=cfg.lr, lr_decay=cfg.lr_decay,
        use_server_update=cfg.use_server_update,
        local_momentum=cfg.local_momentum,
        server_momentum=cfg.server_momentum,
        masked_compute=cfg.masked_compute,
        algorithm=cfg.algorithm,
        guard=cfg.guard,
        faults=tuple(f for f in cfg.faults if hasattr(f, "apply_client")),
        feddu=cfg.feddu, feddum=cfg.feddum,
        fedprox=cfg.fedprox, feddyn=cfg.feddyn)


_BACKENDS = {"local": LocalScanBackend, "mesh": MeshBackend}


class FederatedTrainer:
    """Simulation-grade FL trainer — a facade that binds (model, data,
    config) to an execution backend and hands TrainPlans to the
    :class:`~repro.core.backend.PlanExecutor`.

    model: an object exposing
        init(rng) -> params
        loss_and_acc(params, x, y) -> (scalar loss, scalar acc)
        prune_spec(params) / feature_maps(params, x)   (only for Prune events)
    data: repro.data.pipeline.FederatedData
    backend: "local" (single-host scan) | "mesh" (client-sharded over a
        device mesh; ``mesh=`` overrides the default host mesh, and
        ``backend_opts`` forwards extra backend constructor switches —
        e.g. ``{"shard_server": False}`` / ``{"shard_eval": False}`` to
        fall back to the replicated server scan / evaluation, which the
        BENCH_mesh_server_eval benchmark uses as its baseline)
    """

    def __init__(self, model, data, cfg: FLConfig, *,
                 backend: str = "local", mesh=None,
                 backend_opts: dict | None = None):
        if backend not in _BACKENDS:
            raise ValueError(f"unknown backend: {backend!r} "
                             f"(expected one of {sorted(_BACKENDS)})")
        self.model, self.data, self.cfg = model, data, cfg
        self.backend_name = backend
        self._mesh = mesh
        self._backend_opts = dict(backend_opts or {})
        # fail HERE with a clear message, not as a TypeError (or a silent
        # override) deep inside the first run()'s backend construction
        if backend != "mesh" and self._backend_opts:
            raise ValueError(
                f"backend_opts={sorted(self._backend_opts)} are "
                f"mesh-backend switches; pass backend=\"mesh\" "
                f"(got backend={backend!r})")
        reserved = {"mesh", "use_masks", "data_cache"} & set(
            self._backend_opts)
        if reserved:
            raise ValueError(
                f"backend_opts may not override trainer-managed backend "
                f"arguments {sorted(reserved)}; use the mesh= trainer "
                f"parameter / plan-driven masking instead")
        self._key = jax.random.key(cfg.seed)
        self.engine_config = engine_config(cfg)
        self._sample_kw = sim_sample_kw(cfg, data)
        self._backends: dict = {}
        # both mask-mode backend instances share ONE device-resident copy
        # of the federated dataset
        self._data_cache: dict = {}

    # -- backend plumbing ----------------------------------------------------
    def backend(self, *, use_masks: bool = False):
        """The (cached) execution backend for this trainer; one instance
        per mask mode so the jitted programs persist across runs."""
        if use_masks not in self._backends:
            kw = dict(self._backend_opts)
            if self.backend_name == "mesh":
                if self._mesh is None:
                    # resolve the default host mesh ONCE: both mask-mode
                    # backend instances must agree on the mesh (and share
                    # the device-resident dataset keyed on it)
                    from repro.launch.mesh import make_host_mesh
                    self._mesh = make_host_mesh(model=1)
                kw["mesh"] = self._mesh
            self._backends[use_masks] = _BACKENDS[self.backend_name](
                self.model, self.data, self.cfg, use_masks=use_masks,
                data_cache=self._data_cache, **kw)
        return self._backends[use_masks]

    def _compiled(self, *, use_masks: bool = False) -> CompiledEngine:
        """The session-cached local jitted programs (differential tests and
        benchmarks drive the engine through these directly)."""
        eng = dataclasses.replace(self.engine_config, use_masks=use_masks)
        return compiled_engine(self.model, eng, self._sample_kw,
                               prefetch=self.cfg.prefetch_sampling)

    def round_step(self, state, batch):
        """One round at explicit batches — the engine exactly as the pod
        path runs it; used by the differential/parity tests."""
        return self._compiled().round_core(state, batch)

    def _device_data(self) -> dict:
        return self.backend().device_data()

    # -- public API ----------------------------------------------------------
    def run(self, plan: TrainPlan | int, *, eval_every: int = 1,
            params=None) -> RunResult:
        """Execute a :class:`TrainPlan` (an ``int`` builds the standard
        train+eval plan for that many rounds).  Returns a RunResult."""
        if isinstance(plan, int):
            plan = TrainPlan.standard(plan, eval_every=eval_every)
        params0 = (self.model.init(jax.random.key(self.cfg.seed))
                   if params is None else params)
        executor = PlanExecutor(self.backend(use_masks=plan.uses_masks),
                                trainer=self, faults=self.cfg.faults)
        result, self._key = executor.run(plan, params=params0, key=self._key)
        return result

    def resume(self, checkpoint_dir, *, plan: TrainPlan | None = None
               ) -> RunResult:
        """Continue a killed run from its chunk-boundary checkpoints,
        bit-identically to the uninterrupted run (round state, scan key
        chain, plan cursor and history are all restored from the snapshot).

        ``plan=None`` rebuilds the schedule from the checkpoint's stored
        plan spec (checkpointing re-enabled into the same directory).
        Plans containing :class:`~repro.core.plan.Callback` events cannot
        be reconstructed from disk — pass the original plan object, which
        is validated against the stored spec.
        """
        from repro.reliability.checkpoint import (
            load_checkpoint,
            plan_from_spec,
            plan_spec,
        )
        from repro.core.plan import CheckpointError

        payload = load_checkpoint(checkpoint_dir)
        if payload.get("backend") != self.backend_name:
            raise CheckpointError(
                f"checkpoint was written by the {payload.get('backend')!r} "
                f"backend but this trainer runs {self.backend_name!r} — "
                f"resume on the same backend (bit-identity is per-backend)")
        if plan is None:
            plan = plan_from_spec(
                payload["plan"],
                checkpoint_every=payload.get("checkpoint_every"),
                checkpoint_dir=payload.get("checkpoint_dir",
                                           checkpoint_dir))
        elif plan_spec(plan) != list(payload["plan"]):
            raise CheckpointError(
                "the plan passed to resume() does not match the plan the "
                "checkpoint was written under — resuming would replay a "
                "different schedule")
        executor = PlanExecutor(self.backend(use_masks=plan.uses_masks),
                                trainer=self, faults=self.cfg.faults)
        result, self._key = executor.run(plan, resume=payload)
        return result
