"""Scan-compiled federated simulation driver (the paper's 100-device setting).

One round (Section 3.1):
  (1) select a random device subset D^t, broadcast w^{t-1};
  (2) each device runs E local epochs (SGD, or restart-SGDM for FedDUM);
  (3) devices upload models;
  (4) server aggregates with FedAvg weights n_k/n';
  (5) server update on shared data with dynamic tau_eff (FedDU), optionally
      through the server-momentum pseudo-gradient path (FedDUM);
  (6) at the predefined round, FedAP prunes the model structurally.

The round itself lives in :mod:`repro.core.engine` (``round_core``) and is
SHARED with the pod-scale SPMD path in :mod:`repro.launch.steps` — this
module only adds the simulation plumbing around it:

  * the federated dataset is moved to device ONCE
    (:meth:`FederatedData.device_arrays`); client selection and batch
    sampling run on device through `jax.random` keys in the scan carry
    (`engine.sample_round_batches`) — no per-round host work;
  * multi-round training is ONE compiled ``jax.lax.scan`` over
    ``round_core`` (chunked at ``eval_every`` boundaries), so at fixed
    shapes there is no per-round Python dispatch and no re-jit — the
    engine re-compiles only when FedAP re-materializes the model;
  * all clients share n_k in the paper's label-shard protocol, so local
    step counts are equal and the engine's client vmap is exact.

Momentum modes (covers the paper's baselines):
  local_momentum = "none"         plain local SGD (FedAvg, FedDU)
                 = "restart"      FedDUM's zero-restart SGDM — no comm cost
                 = "communicated" FedDA-style: global momentum broadcast to
                                  devices and aggregated back (2x comm)
  server_momentum = True          SGDM on the server pseudo-gradient

Every mode is differentially tested against the pure-NumPy oracle in
:mod:`repro.core.ref_engine` (tests/test_engine_diff.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import EngineConfig
from repro.core.momentum import FedDUMConfig
from repro.core.pruning import FedAPConfig
from repro.core.server_update import FedDUConfig


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100
    clients_per_round: int = 10
    local_epochs: int = 5          # E
    batch_size: int = 10           # B
    lr: float = 0.1                # eta (local and server SGD)
    lr_decay: float = 0.99         # per-round learning-rate decay (paper 4.1)
    seed: int = 0
    # Feature switches — FedDUMAP = server update + restart momentum (+FedAP).
    use_server_update: bool = True       # FedDU
    local_momentum: str = "none"         # none | restart | communicated
    server_momentum: bool = False
    # Server data usage per round: tau = server_epochs * floor(n0 / B_server).
    server_epochs: int = 1
    server_batch_size: int = 32
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    feddum: FedDUMConfig = dataclasses.field(default_factory=FedDUMConfig)
    fedap: FedAPConfig = dataclasses.field(default_factory=FedAPConfig)


def feddumap_config(**kw) -> FLConfig:
    """The full method: FedDU + FedDUM (FedAP is wired via callback)."""
    kw.setdefault("use_server_update", True)
    kw.setdefault("local_momentum", "restart")
    kw.setdefault("server_momentum", True)
    return FLConfig(**kw)


def engine_config(cfg: FLConfig) -> EngineConfig:
    """The FLConfig -> EngineConfig wiring (locked against the pod path's
    FLRunConfig wiring by tests/test_engine_diff.py)."""
    return EngineConfig(
        lr=cfg.lr, lr_decay=cfg.lr_decay,
        use_server_update=cfg.use_server_update,
        local_momentum=cfg.local_momentum,
        server_momentum=cfg.server_momentum,
        feddu=cfg.feddu, feddum=cfg.feddum)


class FederatedTrainer:
    """Simulation-grade FL trainer over the scan-compiled engine.

    model: an object exposing
        init(rng) -> params
        loss_and_acc(params, x, y) -> (scalar loss, scalar acc)
    data: repro.data.pipeline.FederatedData
    """

    def __init__(self, model, data, cfg: FLConfig):
        self.model, self.data, self.cfg = model, data, cfg
        self._key = jax.random.key(cfg.seed)
        self._data_dev = None
        self._build()

    # -- compiled programs (rebuilt only after FedAP re-materializes) -------
    def _build(self):
        cfg, model = self.cfg, self.model
        self.engine_config = eng = engine_config(cfg)

        def grad_fn(p, b):
            return jax.grad(lambda q: model.loss_and_acc(q, b[0], b[1])[0])(p)

        def la_fn(p, b):
            return model.loss_and_acc(p, b[0], b[1])

        self._grad_fn, self._la_fn = grad_fn, la_fn

        n_k = int(self.data.client_x.shape[1])
        n0 = int(self.data.server_x.shape[0])
        sample_kw = dict(
            clients_per_round=cfg.clients_per_round,
            batch_size=cfg.batch_size,
            local_steps=max(1, n_k // cfg.batch_size) * cfg.local_epochs,
            server_batch=cfg.server_batch_size,
            server_tau=max(1, n0 // cfg.server_batch_size) * cfg.server_epochs,
        )

        def chunk(state, key, data_dev, length):
            def body(carry, _):
                st, k = carry
                k, sub = jax.random.split(k)
                batch = engine.sample_round_batches(sub, data_dev, **sample_kw)
                st, metrics = engine.round_core(eng, grad_fn, la_fn, st, batch)
                return (st, k), metrics["tau_eff"]

            (state, key), taus = jax.lax.scan(body, (state, key), None,
                                              length=length)
            return state, key, taus

        self._chunk = jax.jit(chunk, static_argnames=("length",),
                              donate_argnums=(0,))
        self._round_core = jax.jit(
            lambda state, batch: engine.round_core(eng, grad_fn, la_fn,
                                                   state, batch))
        self._eval = jax.jit(model.loss_and_acc)

    def round_step(self, state, batch):
        """One round at explicit batches — the engine exactly as the pod
        path runs it; used by the differential/parity tests."""
        return self._round_core(state, batch)

    def _device_data(self) -> dict:
        if self._data_dev is None:
            self._data_dev = self.data.device_arrays()
        return self._data_dev

    # -- public API ----------------------------------------------------------
    def run(self, num_rounds: int, *, eval_every: int = 1,
            on_round_end: Callable | None = None, params=None):
        cfg = self.cfg
        params = self.model.init(jax.random.key(cfg.seed)) if params is None else params
        # the scan chunk donates its input state — never the caller's arrays
        state = engine.init_round_state(jax.tree.map(jnp.copy, params),
                                        self.engine_config)
        data_dev = self._device_data()
        history = {"round": [], "acc": [], "loss": [], "tau_eff": [], "time": []}
        t0 = time.time()

        t = 0
        while t < num_rounds:
            if on_round_end is not None:
                length = 1                       # hooks observe every round
            else:
                length = min(eval_every - (t % eval_every), num_rounds - t)
            state, self._key, taus = self._chunk(state, self._key, data_dev,
                                                 length=length)
            t += length

            if t % eval_every == 0 or t == num_rounds:
                loss, acc = self._eval(state["params"], data_dev["test_x"],
                                       data_dev["test_y"])
                history["round"].append(t - 1)
                history["acc"].append(float(acc))
                history["loss"].append(float(loss))
                history["tau_eff"].append(float(taus[-1]))
                history["time"].append(time.time() - t0)

            if on_round_end is not None:
                # hooks get a copy: the next scan chunk donates the round
                # state, which would invalidate any params a hook retains
                maybe = on_round_end(self, t - 1,
                                     jax.tree.map(jnp.copy, state["params"]))
                if maybe is not None:          # e.g. FedAP re-materialized
                    old = jax.tree.map(jnp.shape, state["params"])
                    round_ = state["round"]
                    state = engine.init_round_state(
                        jax.tree.map(jnp.copy, maybe), self.engine_config)
                    state["round"] = round_    # keep the lr-decay schedule
                    if jax.tree.map(jnp.shape, maybe) != old:
                        self._build()          # re-jit for the new shapes
        return state["params"], history
