"""Scan-compiled federated simulation driver (the paper's 100-device setting).

One round (Section 3.1):
  (1) select a random device subset D^t, broadcast w^{t-1};
  (2) each device runs E local epochs (SGD, or restart-SGDM for FedDUM);
  (3) devices upload models;
  (4) server aggregates with FedAvg weights n_k/n';
  (5) server update on shared data with dynamic tau_eff (FedDU), optionally
      through the server-momentum pseudo-gradient path (FedDUM);
  (6) at the predefined round, FedAP prunes the model — as a scheduled
      ``Prune`` event of the declarative :class:`~repro.core.plan.TrainPlan`.

The round itself lives in :mod:`repro.core.engine` (``round_core``) and is
SHARED with the pod-scale SPMD path in :mod:`repro.launch.steps` — this
module only adds the simulation plumbing around it:

  * the federated dataset is moved to device ONCE
    (:meth:`FederatedData.device_arrays`); client selection and batch
    sampling run on device through `jax.random` keys in the scan carry
    (`engine.sample_round_batches`) — no per-round host work;
  * training follows a :class:`~repro.core.plan.TrainPlan`: every ``Scan``
    segment is ONE compiled ``jax.lax.scan`` over ``round_core``, and the
    executor caches one jitted chunk program per (model, engine config,
    sampling shape) in a session-scoped cache, so trainers sharing a model
    and config (e.g. the integration-test matrix) compile once;
  * ``Prune(mode="mask")`` injects FedAP keep-masks into the scan carry
    (``EngineConfig.use_masks``) — the prune round and everything after it
    run inside the SAME compiled program; with
    ``FLConfig(masked_compute="kernel")`` filter-level masks also ride in
    the carry and the model fns route masked dense layers through the
    differentiable Pallas ``masked_matmul`` kernel, realizing the pruned
    FLOP savings during training; ``Prune(mode="shrink")``
    re-materializes the smaller model at the segment boundary (the next
    chunk re-traces at the new shapes);
  * all clients share n_k in the paper's label-shard protocol, so local
    step counts are equal and the engine's client vmap is exact.

Momentum modes (covers the paper's baselines):
  local_momentum = "none"         plain local SGD (FedAvg, FedDU)
                 = "restart"      FedDUM's zero-restart SGDM — no comm cost
                 = "communicated" FedDA-style: global momentum broadcast to
                                  devices and aggregated back (2x comm)
  server_momentum = True          SGDM on the server pseudo-gradient

Every mode is differentially tested against the pure-NumPy oracle in
:mod:`repro.core.ref_engine` (tests/test_engine_diff.py), including the
masked mode.

Migrating from the legacy callback API
--------------------------------------
The pre-plan API forced every observer into a per-round host hook, which
collapsed the scan into ``length=1`` chunks::

    hook = make_fedap_hook(model, data, apcfg, init_params=p0)   # OLD
    params, hist = trainer.run(60, eval_every=2, on_round_end=hook)
    kept = hook.result["kept"]

becomes a declarative schedule returning a structured result::

    plan = fedap_plan(60, prune_round=30, mode="mask", eval_every=2)  # NEW
    res = trainer.run(plan)
    params, hist = res.params, res.history
    kept = res.artifacts["prune"]["kept"]

Per-round hooks that must stay (distillation, baseline pruning) migrate to
``TrainPlan.with_callback(60, hook, eval_every=2)`` — the hook signature
``fn(trainer, round_idx, params) -> new params | None`` is unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.engine import EngineConfig
from repro.core.momentum import FedDUMConfig
from repro.core.plan import (
    Callback,
    Eval,
    Prune,
    RunResult,
    Scan,
    Snapshot,
    TrainPlan,
)
from repro.core.pruning import FedAPConfig
from repro.core.server_update import FedDUConfig


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100
    clients_per_round: int = 10
    local_epochs: int = 5          # E
    batch_size: int = 10           # B
    lr: float = 0.1                # eta (local and server SGD)
    lr_decay: float = 0.99         # per-round learning-rate decay (paper 4.1)
    seed: int = 0
    # Feature switches — FedDUMAP = server update + restart momentum (+FedAP).
    use_server_update: bool = True       # FedDU
    local_momentum: str = "none"         # none | restart | communicated
    server_momentum: bool = False
    # Masked-mode compute path: "params" zeroes the parameter tree only
    # (full-density matmuls); "kernel" threads filter masks into the model
    # so masked dense layers run the differentiable Pallas masked_matmul
    # (FedAP's FLOP savings realized during training).
    masked_compute: str = "params"
    # Server data usage per round: tau = server_epochs * floor(n0 / B_server).
    server_epochs: int = 1
    server_batch_size: int = 32
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    feddum: FedDUMConfig = dataclasses.field(default_factory=FedDUMConfig)
    fedap: FedAPConfig = dataclasses.field(default_factory=FedAPConfig)

    def __post_init__(self):
        # Mirror EngineConfig.__post_init__: a bad switch must fail HERE,
        # at construction, with a clear message — not at jit time.
        if self.local_momentum not in ("none", "restart", "communicated"):
            raise ValueError(
                f"unknown local_momentum: {self.local_momentum!r} "
                "(expected 'none', 'restart' or 'communicated')")
        if self.masked_compute not in ("params", "kernel"):
            raise ValueError(
                f"unknown masked_compute: {self.masked_compute!r} "
                "(expected 'params' or 'kernel')")
        if not 1 <= self.clients_per_round <= self.num_clients:
            raise ValueError(
                f"clients_per_round must be in [1, num_clients="
                f"{self.num_clients}], got {self.clients_per_round}")
        for name in ("local_epochs", "batch_size", "server_epochs",
                     "server_batch_size"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        if self.lr <= 0:
            raise ValueError(f"lr must be > 0, got {self.lr}")
        if self.lr_decay <= 0:
            raise ValueError(f"lr_decay must be > 0, got {self.lr_decay}")


def feddumap_config(**kw) -> FLConfig:
    """The full method: FedDU + FedDUM (+FedAP via a plan Prune event)."""
    kw.setdefault("use_server_update", True)
    kw.setdefault("local_momentum", "restart")
    kw.setdefault("server_momentum", True)
    return FLConfig(**kw)


def engine_config(cfg: FLConfig) -> EngineConfig:
    """The FLConfig -> EngineConfig wiring (locked against the pod path's
    FLRunConfig wiring by tests/test_engine_diff.py)."""
    return EngineConfig(
        lr=cfg.lr, lr_decay=cfg.lr_decay,
        use_server_update=cfg.use_server_update,
        local_momentum=cfg.local_momentum,
        server_momentum=cfg.server_momentum,
        masked_compute=cfg.masked_compute,
        feddu=cfg.feddu, feddum=cfg.feddum)


# ---------------------------------------------------------------------------
# Session-scoped compiled-engine cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledEngine:
    """The jitted programs for one (model, engine config, sampling shape).

    ``model`` is held as a strong reference so the ``id(model)`` cache key
    stays valid for the lifetime of the entry.
    """

    model: Any
    eng: EngineConfig
    chunk: Any        # (state, key, data_dev, *, length) -> (state, key, taus)
    round_core: Any   # (state, batch) -> (state, metrics)
    evaluate: Any     # (params, x, y) -> (loss, acc)


_COMPILED_CACHE: dict[tuple, CompiledEngine] = {}
_EVAL_CACHE: dict[int, tuple] = {}


def clear_compiled_cache() -> None:
    _COMPILED_CACHE.clear()
    _EVAL_CACHE.clear()


def compiled_engine(model, eng: EngineConfig, sample_kw: dict) -> CompiledEngine:
    """Session-scoped cache of the jitted scan-chunk / round / eval programs.

    Trainers over the same model object and equal (engine config, sampling
    shape) share ONE compiled program set — e.g. the integration-test matrix
    re-running baselines over a module-scoped model fixture compiles each
    distinct configuration once per session instead of once per trainer.
    """
    key = (id(model), eng, tuple(sorted(sample_kw.items())))
    ce = _COMPILED_CACHE.get(key)
    if ce is not None:
        return ce

    if eng.use_masks and eng.masked_compute == "kernel":
        # Mask-aware model fns: round_core passes the carry's filter masks
        # as a third argument; the model routes masked dense layers through
        # the differentiable Pallas masked_matmul kernel.
        def grad_fn(p, b, fm):
            return jax.grad(
                lambda q: model.loss_and_acc(q, b[0], b[1], masks=fm)[0])(p)

        def la_fn(p, b, fm):
            return model.loss_and_acc(p, b[0], b[1], masks=fm)
    else:
        def grad_fn(p, b):
            return jax.grad(lambda q: model.loss_and_acc(q, b[0], b[1])[0])(p)

        def la_fn(p, b):
            return model.loss_and_acc(p, b[0], b[1])

    def chunk(state, key, data_dev, length):
        def body(carry, _):
            st, k = carry
            k, sub = jax.random.split(k)
            batch = engine.sample_round_batches(sub, data_dev, **sample_kw)
            st, metrics = engine.round_core(eng, grad_fn, la_fn, st, batch)
            return (st, k), metrics["tau_eff"]

        (state, key), taus = jax.lax.scan(body, (state, key), None,
                                          length=length)
        return state, key, taus

    ev = _EVAL_CACHE.get(id(model))
    if ev is None:
        ev = (model, jax.jit(model.loss_and_acc))
        _EVAL_CACHE[id(model)] = ev

    ce = CompiledEngine(
        model=model, eng=eng,
        chunk=jax.jit(chunk, static_argnames=("length",), donate_argnums=(0,)),
        round_core=jax.jit(
            lambda state, batch: engine.round_core(eng, grad_fn, la_fn,
                                                   state, batch)),
        evaluate=ev[1])
    _COMPILED_CACHE[key] = ce
    return ce


# ---------------------------------------------------------------------------
# The trainer: a TrainPlan executor over the scan-compiled engine
# ---------------------------------------------------------------------------

class FederatedTrainer:
    """Simulation-grade FL trainer over the scan-compiled engine.

    model: an object exposing
        init(rng) -> params
        loss_and_acc(params, x, y) -> (scalar loss, scalar acc)
        prune_spec(params) / feature_maps(params, x)   (only for Prune events)
    data: repro.data.pipeline.FederatedData
    """

    def __init__(self, model, data, cfg: FLConfig):
        self.model, self.data, self.cfg = model, data, cfg
        self._key = jax.random.key(cfg.seed)
        self._data_dev = None
        self.engine_config = engine_config(cfg)

        n_k = int(self.data.client_x.shape[1])
        n0 = int(self.data.server_x.shape[0])
        self._sample_kw = dict(
            clients_per_round=cfg.clients_per_round,
            batch_size=cfg.batch_size,
            local_steps=max(1, n_k // cfg.batch_size) * cfg.local_epochs,
            server_batch=cfg.server_batch_size,
            server_tau=max(1, n0 // cfg.server_batch_size) * cfg.server_epochs,
        )

    def _compiled(self, *, use_masks: bool = False) -> CompiledEngine:
        eng = dataclasses.replace(self.engine_config, use_masks=use_masks)
        return compiled_engine(self.model, eng, self._sample_kw)

    def _init_filter_masks(self, params):
        """All-ones per-layer filter masks (``masked_compute="kernel"``):
        the carry structure must be final from round 0 so the prune event
        only swaps contents, never re-traces."""
        from repro.core import pruning

        spec = self.model.prune_spec(params)
        return pruning.filter_masks(params, spec, {})

    def round_step(self, state, batch):
        """One round at explicit batches — the engine exactly as the pod
        path runs it; used by the differential/parity tests."""
        return self._compiled().round_core(state, batch)

    def _device_data(self) -> dict:
        if self._data_dev is None:
            self._data_dev = self.data.device_arrays()
        return self._data_dev

    # -- public API ----------------------------------------------------------
    def run(self, plan: TrainPlan | int, *, eval_every: int = 1,
            params=None) -> RunResult:
        """Execute a :class:`TrainPlan` (an ``int`` builds the standard
        train+eval plan for that many rounds).  Returns a RunResult."""
        if isinstance(plan, int):
            plan = TrainPlan.standard(plan, eval_every=eval_every)
        use_masks = plan.uses_masks
        eng = dataclasses.replace(self.engine_config, use_masks=use_masks)
        ce = self._compiled(use_masks=use_masks)
        cfg = self.cfg

        params0 = (self.model.init(jax.random.key(cfg.seed))
                   if params is None else params)
        # Prune events estimate the Lipschitz constant against the params
        # the run started from (the legacy hooks took them explicitly).
        init_params = jax.tree.map(jnp.copy, params0)
        fmasks0 = (self._init_filter_masks(params0)
                   if use_masks and eng.masked_compute == "kernel" else None)
        # the scan chunk donates its input state — never the caller's arrays
        state = engine.init_round_state(jax.tree.map(jnp.copy, params0), eng,
                                        filter_masks=fmasks0)
        data_dev = self._device_data()

        history = {"round": [], "acc": [], "loss": [], "tau_eff": [],
                   "time": []}
        artifacts: dict[str, Any] = {}
        t0 = time.time()
        t = 0
        last_tau = 0.0

        def record(name, value):
            key, k = name, 1
            while key in artifacts:
                key = f"{name}#{k}"
                k += 1
            artifacts[key] = value

        for ev in plan.compiled():
            if isinstance(ev, Scan):
                state, self._key, taus = ce.chunk(state, self._key, data_dev,
                                                  length=ev.rounds)
                t += ev.rounds
                last_tau = float(taus[-1])
            elif isinstance(ev, Eval):
                loss, acc = ce.evaluate(state["params"], data_dev["test_x"],
                                        data_dev["test_y"])
                # the TRUE round count: t rounds have completed when this
                # Eval runs, so a leading Eval() (evaluate-before-training)
                # records round 0, not a fabricated round -1
                history["round"].append(t)
                history["acc"].append(float(acc))
                history["loss"].append(float(loss))
                history["tau_eff"].append(last_tau)
                history["time"].append(time.time() - t0)
            elif isinstance(ev, Snapshot):
                record(ev.name, {"round": t, "params": jax.tree.map(
                    jnp.copy, state["params"])})
            elif isinstance(ev, Prune):
                state, art = self._prune_event(ev, state, eng, init_params)
                record(ev.name, art)
            elif isinstance(ev, Callback):
                # callbacks get a copy: the next scan chunk donates the
                # round state, which would invalidate retained params
                maybe = ev.fn(self, t - 1,
                              jax.tree.map(jnp.copy, state["params"]))
                if maybe is not None:   # legacy contract: replace + restart
                    round_ = state["round"]
                    masks = state.get("masks")
                    fmasks = state.get("filter_masks")
                    state = engine.init_round_state(
                        jax.tree.map(jnp.copy, maybe), eng,
                        filter_masks=fmasks)
                    state["round"] = round_
                    if masks is not None:
                        # keep an earlier Prune(mode="mask") decision in
                        # force across the state rebuild
                        state["masks"] = masks
                        state["params"] = engine.apply_masks(state["params"],
                                                             masks)
            else:  # pragma: no cover — TrainPlan validates event types
                raise TypeError(f"unknown plan event: {ev!r}")

        return RunResult(params=state["params"], history=history,
                         artifacts=artifacts, state=state)

    # -- FedAP plan event ----------------------------------------------------
    def _prune_event(self, ev: Prune, state: dict, eng: EngineConfig,
                     init_params) -> tuple[dict, dict]:
        """Algorithm 3 at a segment boundary.  mask: inject keep-masks into
        the carry (same compiled program keeps running); shrink:
        re-materialize (next chunk re-traces).  Both restart momentum with
        the round counter preserved, so the two modes train identically on
        normalization-free models."""
        from repro.core import fedap as fedap_mod
        from repro.core import pruning

        apcfg = self.cfg.fedap
        params = jax.tree.map(jnp.copy, state["params"])
        decision = fedap_mod.fedap_decision(
            self.model, self.data, apcfg, params, init_params=init_params,
            rng=np.random.default_rng(self.cfg.seed))
        spec = self.model.prune_spec(params)
        art = decision.summary()
        art["kept"] = decision.kept
        art["mode"] = ev.mode
        round_ = state["round"]

        if ev.mode == "mask":
            masks = pruning.param_masks(params, spec, decision.kept)
            fmasks = pruning.filter_masks(params, spec, decision.kept)
            new_state = engine.init_round_state(
                engine.apply_masks(params, masks), eng,
                filter_masks=(fmasks if eng.masked_compute == "kernel"
                              else None))
            new_state["masks"] = masks
            art["filter_masks"] = fmasks
        else:
            new_params = pruning.shrink_params(params, spec, decision.kept)
            # kernel mode (reachable when a mask-mode prune elsewhere in
            # the plan set use_masks): all-ones filter masks at the SHRUNK
            # shapes — the compacted model has nothing left to skip
            fm = (self._init_filter_masks(new_params)
                  if eng.use_masks and eng.masked_compute == "kernel"
                  else None)
            new_state = engine.init_round_state(new_params, eng,
                                                filter_masks=fm)
            art["params_before"] = params   # the shrink discards them
        new_state["round"] = round_
        return new_state, art
