"""FedDU — dynamic server update on shared insensitive server data.

Implements paper Formulas 4-7:

    w^t        = w^{t-1/2} - tau_eff^{t-1} * eta * g0_bar(w^{t-1/2})        (4)
    g0_bar     = (1/tau) * sum_{i=1..tau} g0(w^{t-1/2, i})                  (6)
    tau_eff^t  = f'(acc) * n0*D(Pbar') / (n0*D(Pbar') + n'*D(P0))
                 * C * decay^t * tau                                        (7)

The gradients are *normalized* by tau (FedNova-style, [71]) so that a large
server dataset cannot drag the objective toward the server distribution
(objective inconsistency).  tau_eff decays geometrically, so FedDU provably
degrades to FedAvg — convergence is inherited (Section 3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

from repro.utils import tree_scale, tree_sub, tree_add, tree_zeros_like


def f_prime(acc: jnp.ndarray, kind: str = "1-acc", eps: float = 1e-8) -> jnp.ndarray:
    """f'(acc) — accuracy gate for the server update.  The paper evaluates
    ``1 - acc`` and ``1/(acc+eps)`` (Table 3) and selects ``1 - acc``."""
    acc = jnp.asarray(acc, jnp.float32)
    if kind == "1-acc":
        return 1.0 - acc
    if kind == "inv":
        return 1.0 / (acc + eps)
    raise ValueError(f"unknown f'(acc) kind: {kind}")


@dataclasses.dataclass(frozen=True)
class FedDUConfig:
    """Hyper-parameters of the dynamic server update (Formula 7)."""

    C: float = 1.0            # paper Table 4: C = 1 is best
    decay: float = 0.99       # geometric decay ensures convergence to FedAvg
    f_prime_kind: str = "1-acc"  # paper Table 3: '1-acc' beats '1/(acc+eps)'
    eps: float = 1e-8
    # Static override for the ablation FedDU-S (Table 2). None = dynamic.
    static_tau_eff: float | None = None


def tau_eff(
    cfg: FedDUConfig,
    *,
    acc: jnp.ndarray,
    round_idx: jnp.ndarray,
    n0: jnp.ndarray,
    n_prime: jnp.ndarray,
    d_round: jnp.ndarray,
    d_server: jnp.ndarray,
    tau: jnp.ndarray,
) -> jnp.ndarray:
    """Formula 7.  All arguments may be traced scalars.

    n0:       number of samples on the server.
    n_prime:  total samples on the selected devices this round.
    d_round:  D(Pbar'^t)  non-IID degree of this round's selected devices.
    d_server: D(P0)       non-IID degree of the server data.
    tau:      ceil(n0 * E / B) server iterations per round.
    """
    if cfg.static_tau_eff is not None:
        return jnp.asarray(cfg.static_tau_eff, jnp.float32)
    n0 = jnp.asarray(n0, jnp.float32)
    n_prime = jnp.asarray(n_prime, jnp.float32)
    num = n0 * d_round
    den = num + n_prime * d_server + cfg.eps
    gate = f_prime(acc, cfg.f_prime_kind, cfg.eps)
    t = jnp.asarray(round_idx, jnp.float32)
    return gate * (num / den) * cfg.C * (cfg.decay ** t) * jnp.asarray(tau, jnp.float32)


def normalized_server_gradient(
    params: Any,
    server_batches: Sequence[Any],
    grad_fn: Callable[[Any, Any], Any],
    eta: float,
) -> Any:
    """g0_bar (Formula 6): run tau = len(server_batches) SGD iterations from
    ``params`` on the server data and return the *average* per-step gradient.

    Equivalently (and how we compute it): (w_start - w_end) / (tau * eta).
    This telescoping identity is exact for plain SGD and avoids storing
    per-step gradients.
    """
    tau = len(server_batches)
    if tau == 0:
        return tree_zeros_like(params)

    w = params
    for batch in server_batches:
        g = grad_fn(w, batch)
        w = jax.tree.map(lambda p, gi: (p - eta * gi).astype(p.dtype), w, g)
    # (w_start - w_end) / (tau*eta) == mean of gradients along the path.
    return jax.tree.map(
        lambda a, b: ((a.astype(jnp.float32) - b.astype(jnp.float32)) / (tau * eta)),
        params,
        w,
    )


def normalized_server_gradient_scan(
    params: Any,
    server_batch_stack: Any,
    grad_fn: Callable[[Any, Any], Any],
    eta: float,
) -> Any:
    """Same as :func:`normalized_server_gradient` but with a ``lax.scan`` over
    a stacked batch pytree (leading axis = tau).  Used inside jitted
    distributed train steps so tau does not unroll the HLO."""
    tau = jax.tree.leaves(server_batch_stack)[0].shape[0]

    def body(w, batch):
        g = grad_fn(w, batch)
        w = jax.tree.map(lambda p, gi: (p - eta * gi).astype(p.dtype), w, g)
        return w, None

    w_end, _ = jax.lax.scan(body, params, server_batch_stack)
    return jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)) / (tau * eta),
        params,
        w_end,
    )


def feddu_apply(
    w_half: Any,
    g0_bar: Any,
    t_eff: jnp.ndarray,
    eta: float,
) -> Any:
    """Formula 4: w^t = w^{t-1/2} - tau_eff * eta * g0_bar."""
    scale = jnp.asarray(t_eff, jnp.float32) * eta
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - scale * g).astype(p.dtype), w_half, g0_bar
    )


def server_update_term(g0_bar: Any, t_eff: jnp.ndarray, eta: float) -> Any:
    """tau_eff * eta * g0_bar — the additive server correction, exposed
    separately because FedDUM folds it into the server pseudo-gradient
    (Formula 12) instead of applying it directly."""
    scale = jnp.asarray(t_eff, jnp.float32) * eta
    return jax.tree.map(lambda g: scale * g, g0_bar)
