"""Declarative training plans — the schedule language of the FL trainer.

A :class:`TrainPlan` is a typed sequence of segments and events:

  Scan(n)      n federated rounds inside ONE compiled ``lax.scan`` chunk
  Eval()       score the global model on the held-out test split
  Prune(mode)  FedAP (Algorithm 3) as a first-class event:
                 mode="mask"    static-shape: keep-masks are injected into
                                the scan carry; training keeps running in
                                the SAME compiled program (no re-jit)
                 mode="shrink"  re-materialize the genuinely smaller model
                                at the segment boundary (forces a re-trace)
  Snapshot()   record a copy of the current global params as an artifact
  Callback(fn) host escape hatch at a segment boundary (distillation,
               baseline pruning hooks, ...); fn(trainer, round_idx, params)
               may return new params, which restart the round state exactly
               like the legacy ``on_round_end`` protocol did

The plan replaces the old ``FederatedTrainer.run(n, on_round_end=...)``
callback API, whose per-round hook forced the scan into ``length=1``
chunks and made FedAP — the paper's cheap efficiency win — the most
expensive thing in the system.  The executor
(`repro.core.backend.PlanExecutor`, driving a local-scan or mesh backend)
compiles a plan into the minimal set of jitted scan chunks: consecutive
``Scan`` segments merge, and chunk programs are cached per (engine config,
chunk length), so a plan with ten ``Scan(5)`` segments compiles exactly
one program.

Execution returns a structured :class:`RunResult` (history + per-event
artifacts) instead of closure-mutated ``hook.result`` dicts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Union


class CheckpointError(ValueError):
    """A checkpoint directory is partial, corrupted, or mismatched.

    Subclasses :class:`ValueError` so legacy ``except ValueError`` callers
    keep working; raised by :func:`load_artifact` and by the run-checkpoint
    store in :mod:`repro.reliability.checkpoint` instead of raw
    ``KeyError`` / ``FileNotFoundError`` / ``zipfile.BadZipFile`` crashes.
    """


@dataclasses.dataclass(frozen=True)
class Scan:
    """``rounds`` federated rounds in one compiled scan chunk."""

    rounds: int

    def __post_init__(self):
        if self.rounds < 1:
            raise ValueError(f"Scan.rounds must be >= 1, got {self.rounds}")


@dataclasses.dataclass(frozen=True)
class Eval:
    """Evaluate the global model on the test split; appends to history.

    ``history["round"]`` records the number of completed rounds at the
    Eval, so a leading ``Eval()`` (evaluate-before-training) logs round 0
    and a trailing one logs ``plan.total_rounds``."""

    name: str = "eval"


@dataclasses.dataclass(frozen=True)
class Prune:
    """FedAP (Algorithm 3) at this point of the schedule.

    mode="mask":   static shapes — keep-masks enter the scan carry and the
                   engine applies them every round (`EngineConfig.use_masks`);
                   the surrounding Scan segments stay one compiled program.
                   With ``FLConfig(masked_compute="kernel")`` filter-level
                   masks ride along too and masked dense layers run the
                   differentiable Pallas ``masked_matmul`` kernel — pruned
                   blocks are skipped on the MXU during training, not just
                   zeroed in the parameter tree.
    mode="shrink": re-materialize the pruned model (true FLOP shrink on
                   device); the next Scan segment re-traces at the new
                   shapes, exactly like the legacy hook path.
    Both modes restart the server momentum (the paper's prune round resets
    optimizer state), so they produce identical training trajectories on
    normalization-free models.

    ``reuse`` (mode="shrink" only) names an EARLIER Prune event's artifact
    whose kept-filter decision this event compacts to — no second FedAP
    run, and the momentum buffers are compacted rather than restarted, so
    the event is a pure re-materialization of the masked training state.
    This is the mask-now-shrink-later pattern (``fedap_plan(...,
    shrink_round=K)``): the prune round stays inside the compiled scan
    (mask), and the next segment boundary compacts to the genuinely
    smaller — and faster per round — model.
    """

    mode: str = "mask"
    name: str = "prune"
    reuse: str | None = None

    def __post_init__(self):
        if self.mode not in ("mask", "shrink"):
            raise ValueError(f"Prune.mode must be 'mask' or 'shrink', "
                             f"got {self.mode!r}")
        if self.reuse is not None and self.mode != "shrink":
            raise ValueError(
                "Prune.reuse compacts to an earlier event's decision and "
                f"needs mode='shrink', got mode={self.mode!r}")


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Copy the current global params into ``RunResult.artifacts[name]``."""

    name: str = "snapshot"


@dataclasses.dataclass(frozen=True)
class Callback:
    """Host callback at a segment boundary — the migration target for the
    legacy ``on_round_end`` hooks (distillation, baseline pruning, ...).

    ``fn(trainer, round_idx, params)`` receives a COPY of the params (the
    next scan chunk donates the round state) and may return replacement
    params; a non-None return re-initializes the round state (momentum
    restart) with the round counter preserved — the legacy hook contract.
    """

    fn: Callable
    name: str = "callback"


Event = Union[Scan, Eval, Prune, Snapshot, Callback]


class TrainPlan:
    """An ordered schedule of :data:`Event` items.

    ``TrainPlan(Scan(30), Eval(), Prune(mode="mask"), Scan(30), Eval())``

    Iterables flatten, so builders can splice sub-schedules in place.

    ``checkpoint_dir`` makes the executor durably snapshot the run (round
    state + key chain + plan cursor + history/artifacts) at chunk
    boundaries — every ``checkpoint_every`` completed Scan chunks (default
    1 = every chunk).  A killed run then continues bit-identically via
    ``FederatedTrainer.resume(checkpoint_dir)``.  Checkpointing is an
    execution setting, not part of the schedule: it does not participate
    in plan equality.
    """

    def __init__(self, *events: Event | Iterable[Event],
                 checkpoint_every: int | None = None,
                 checkpoint_dir=None):
        flat: list[Event] = []
        for e in events:
            if isinstance(e, (Scan, Eval, Prune, Snapshot, Callback)):
                flat.append(e)
            else:
                flat.extend(e)
        for e in flat:
            if not isinstance(e, (Scan, Eval, Prune, Snapshot, Callback)):
                raise TypeError(f"not a TrainPlan event: {e!r}")
        self.events: tuple[Event, ...] = tuple(flat)
        if checkpoint_every is not None and checkpoint_dir is None:
            raise ValueError("checkpoint_every without checkpoint_dir: "
                             "there is nowhere to write the snapshots")
        if checkpoint_every is None and checkpoint_dir is not None:
            checkpoint_every = 1
        if checkpoint_every is not None and checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, "
                             f"got {checkpoint_every}")
        self.checkpoint_every = checkpoint_every
        self.checkpoint_dir = checkpoint_dir

    def with_checkpointing(self, directory, *, every: int = 1) -> "TrainPlan":
        """A copy of this plan that checkpoints into ``directory`` every
        ``every`` completed Scan chunks."""
        return TrainPlan(self.events, checkpoint_every=every,
                         checkpoint_dir=directory)

    def __repr__(self):
        return f"TrainPlan({', '.join(map(repr, self.events))})"

    def __eq__(self, other):
        return isinstance(other, TrainPlan) and self.events == other.events

    @property
    def total_rounds(self) -> int:
        return sum(e.rounds for e in self.events if isinstance(e, Scan))

    @property
    def uses_masks(self) -> bool:
        """True iff the plan schedules a mask-mode prune — the executor then
        builds the engine with ``use_masks=True`` from round 0 (all-ones
        masks are a bit-exact no-op), so the prune event never re-jits."""
        return any(isinstance(e, Prune) and e.mode == "mask"
                   for e in self.events)

    def compiled(self) -> tuple[Event, ...]:
        """The minimal executable form: consecutive Scan segments merged.

        The executor jit-caches one chunk program per (engine config, chunk
        length); merging means a plan's distinct chunk lengths — not its
        event count — determine how many programs compile.
        """
        out: list[Event] = []
        for e in self.events:
            if isinstance(e, Scan) and out and isinstance(out[-1], Scan):
                out[-1] = Scan(out[-1].rounds + e.rounds)
            else:
                out.append(e)
        return tuple(out)

    def chunk_lengths(self) -> tuple[int, ...]:
        """Distinct Scan lengths after merging — the number of scan programs
        the executor will compile."""
        return tuple(sorted({e.rounds for e in self.compiled()
                             if isinstance(e, Scan)}))

    # -- builders ------------------------------------------------------------
    @classmethod
    def standard(cls, num_rounds: int, *, eval_every: int = 1) -> "TrainPlan":
        """``num_rounds`` of training with an Eval every ``eval_every``
        rounds — the plan equivalent of the legacy ``run(n, eval_every=k)``."""
        if eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {eval_every}")
        events: list[Event] = []
        t = 0
        while t < num_rounds:
            n = min(eval_every - (t % eval_every), num_rounds - t)
            events.append(Scan(n))
            t += n
            if t % eval_every == 0 or t == num_rounds:
                events.append(Eval())
        return cls(events)

    @classmethod
    def with_callback(cls, num_rounds: int, fn: Callable, *,
                      every: int = 1, eval_every: int = 1,
                      name: str = "callback") -> "TrainPlan":
        """Training with ``fn`` invoked every ``every`` rounds — the
        migration path for legacy ``on_round_end`` hooks (the hook's own
        round gating keeps working: it still receives ``round_idx``).
        ``eval_every=0`` schedules no Eval events at all."""
        events: list[Event] = []
        t = 0
        while t < num_rounds:
            stops = [t + every - (t % every)]
            if eval_every:
                stops.append(t + eval_every - (t % eval_every))
            stop = min(min(stops), num_rounds)
            events.append(Scan(stop - t))
            t = stop
            if eval_every and (t % eval_every == 0 or t == num_rounds):
                events.append(Eval())
            if t % every == 0 or t == num_rounds:
                events.append(Callback(fn, name=name))
        return cls(events)


def fedap_plan(num_rounds: int, *, prune_round: int, mode: str = "mask",
               eval_every: int = 1,
               shrink_round: int | None = None) -> TrainPlan:
    """The paper's FedDUMAP schedule: train, FedAP once at ``prune_round``,
    keep training.  ``mode="mask"`` keeps every round inside the compiled
    scan; ``mode="shrink"`` re-materializes (legacy-hook behaviour).

    ``shrink_round=K`` (mask mode only) schedules the mask-now-shrink-later
    pattern: the FedAP decision at ``prune_round`` is applied as masks (no
    mid-scan re-jit), and at round ``K`` a follow-up
    ``Prune(mode="shrink", reuse="prune")`` compacts the state to the SAME
    kept filters — momentum included, no second FedAP run — so the
    steady-state rounds after ``K`` train the genuinely smaller model.
    On normalization-free models the result is exactly
    shrink-from-``prune_round`` training (locked by tests/test_plan.py).
    """
    if not 0 < prune_round <= num_rounds:
        raise ValueError(f"prune_round must be in (0, {num_rounds}], "
                         f"got {prune_round}")
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    if shrink_round is not None:
        if mode != "mask":
            raise ValueError("shrink_round schedules a follow-up compaction "
                             "of a MASK prune; use mode='mask' (got "
                             f"mode={mode!r})")
        if not prune_round < shrink_round <= num_rounds:
            raise ValueError(
                f"shrink_round must be in (prune_round={prune_round}, "
                f"{num_rounds}], got {shrink_round}")
    events: list[Event] = []
    t = 0
    while t < num_rounds:
        stops = [t + eval_every - (t % eval_every), num_rounds]
        if t < prune_round:
            stops.append(prune_round)
        if shrink_round is not None and t < shrink_round:
            stops.append(shrink_round)
        stop = min(stops)
        events.append(Scan(stop - t))
        t = stop
        if t % eval_every == 0 or t == num_rounds:
            events.append(Eval())
        if t == prune_round:
            events.append(Prune(mode=mode))
        if shrink_round is not None and t == shrink_round:
            events.append(Prune(mode="shrink", reuse="prune", name="shrink"))
    return TrainPlan(events)


@dataclasses.dataclass
class RunResult:
    """What a plan execution returns.

    params     final global params (masked-to-zero coordinates included in
               mask mode — ``artifacts["prune"]["kept"]`` compacts them)
    history    {"round", "acc", "loss", "tau_eff", "time"} from Eval events
               ("round" = completed rounds at the Eval; a leading Eval
               logs 0, and its "tau_eff" is 0.0 — no round has run yet)
    artifacts  per-event outputs keyed by event name (deduplicated with
               ``#k`` suffixes): Prune -> {"p_star", "layer_rates", "kept",
               "filter_masks"|"params_before"}, Snapshot -> {"round",
               "params"}, Callback -> whatever the callback returned
    state      the final engine round state (params/momentum/masks/round)
    """

    params: Any
    history: dict[str, list]
    artifacts: dict[str, Any]
    state: dict

    def save(self, path, *, model_config=None, params=None) -> None:
        """Persist the run as a serving-consumable checkpoint directory:
        ``arrays.npz`` (params + prune kept-filters/masks, keys are
        '/'-joined pytree paths) + ``meta.json`` (prune mode / p_star /
        layer_rates / kept_counts, eval history, and — when given — the
        :class:`repro.configs.base.ModelConfig` so the loader can rebuild
        the model without out-of-band knowledge).

        The LAST Prune event's artifact (if any) is exported; ``params``
        overrides the final params (e.g. to save a mid-run ``Snapshot``
        artifact's copy instead).  Load back with :func:`load_artifact`.

        Both files are written atomically (temp file + ``os.replace``), so
        a crash mid-save never leaves a half-written ``arrays.npz`` or
        ``meta.json`` for the loader to trip over — at worst one of the
        two is stale, which :func:`load_artifact` reports by name.
        """
        import json
        import os
        import pathlib

        import numpy as np

        out = pathlib.Path(path)
        out.mkdir(parents=True, exist_ok=True)
        prune_name, prune_art = None, None
        for name, art in self.artifacts.items():
            if isinstance(art, dict) and "kept" in art:
                prune_name, prune_art = name, art

        arrays = _flatten_arrays({"params": params if params is not None
                                  else self.params})
        meta: dict = {
            "format": "repro-checkpoint-v1",
            "history": _json_safe(self.history),
            "model_config": (model_config.to_dict()
                             if model_config is not None else None),
            "prune": None,
        }
        if prune_art is not None:
            kept = prune_art.get("kept") or {}
            arrays.update(_flatten_arrays({"kept": dict(kept)}))
            fmasks = prune_art.get("filter_masks")
            if fmasks:
                arrays.update(_flatten_arrays({"masks": dict(fmasks)}))
            meta["prune"] = _json_safe({
                "event": prune_name,
                "mode": prune_art.get("mode"),
                "p_star": prune_art.get("p_star"),
                "layer_rates": prune_art.get("layer_rates"),
                "kept_counts": prune_art.get(
                    "kept_counts",
                    {k: int(np.asarray(v).shape[-1]) for k, v in kept.items()}),
            })
        tmp = out / f".arrays.npz.tmp-{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, **{k: np.asarray(v) for k, v in arrays.items()})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, out / "arrays.npz")
        tmp = out / f".meta.json.tmp-{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=2)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, out / "meta.json")


def _flatten_arrays(tree, prefix: str = "") -> dict:
    """Nested dicts of arrays -> flat {'a/b/c': leaf}.  Keys must be
    '/'-free strings (true for every model param tree in this repo)."""
    flat: dict = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            k = str(k)
            if "/" in k:
                raise ValueError(f"checkpoint keys may not contain '/': {k!r}")
            flat.update(_flatten_arrays(v, f"{prefix}{k}/"))
        return flat
    flat[prefix[:-1]] = tree
    return flat


def _unflatten_arrays(flat: dict) -> dict:
    tree: dict = {}
    for key, leaf in flat.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def _json_safe(x):
    """numpy scalars/arrays -> python, recursively (checkpoint metadata)."""
    import numpy as np

    if isinstance(x, dict):
        return {str(k): _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    if isinstance(x, (np.generic,)):
        return x.item()
    if hasattr(x, "tolist") and hasattr(x, "ndim"):     # np/jnp arrays
        return np.asarray(x).tolist()
    return x


def load_artifact(path) -> dict:
    """Load a :meth:`RunResult.save` checkpoint directory.

    Returns ``{"params", "kept", "filter_masks", "mode", "model_config",
    "history", "meta"}`` — ``kept``/``filter_masks`` are None for a dense
    (never-pruned) run, ``model_config`` is a rebuilt
    :class:`~repro.configs.base.ModelConfig` or None if the save didn't
    record one.  ``repro.serving`` consumes this to decode the checkpoint
    dense, masked (block-skipping kernel at dense shapes) or shrunk
    (compacted shapes).

    Partial directories (a crash between the two file writes, a copy that
    dropped a file) and corrupted/mismatched saves raise
    :class:`CheckpointError` naming what is wrong, instead of a raw
    ``FileNotFoundError`` / ``zipfile.BadZipFile`` / ``KeyError``.
    """
    import json
    import pathlib
    import zipfile

    import numpy as np

    p = pathlib.Path(path)
    if not (p / "meta.json").exists():
        raise CheckpointError(
            f"{p}: not a checkpoint directory (missing meta.json)")
    try:
        with open(p / "meta.json") as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"{p}: unreadable meta.json ({e})") from e
    if meta.get("format") != "repro-checkpoint-v1":
        raise CheckpointError(f"{p}: not a repro checkpoint "
                              f"(format={meta.get('format')!r})")
    if not (p / "arrays.npz").exists():
        raise CheckpointError(
            f"{p}: partial checkpoint (meta.json present but arrays.npz "
            f"missing — interrupted or incomplete save)")
    try:
        with np.load(p / "arrays.npz") as z:
            tree = _unflatten_arrays({k: z[k] for k in z.files})
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointError(f"{p}: corrupted arrays.npz ({e})") from e
    from repro.configs.base import ModelConfig

    prune = meta.get("prune") or {}
    return {
        "params": tree.get("params", {}),
        "kept": tree.get("kept"),
        "filter_masks": tree.get("masks"),
        "mode": prune.get("mode"),
        "model_config": (ModelConfig.from_dict(meta["model_config"])
                         if meta.get("model_config") else None),
        "history": meta.get("history", {}),
        "meta": meta,
    }
