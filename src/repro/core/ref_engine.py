"""Pure-NumPy reference oracle for the unified round engine.

A deliberately naive, loop-based float64 implementation of the paper's
Formulas 4-12 — no jit, no scan, no vmap, no clever telescoping — used as
the differential-test target for :func:`repro.core.engine.round_core`
(tests/test_engine_diff.py).  Every algorithm mode the engine supports is
mirrored here:

  * local SGD / restart-SGDM (Formula 11) / communicated-momentum (FedDA);
  * FedAvg aggregation with n_k/n' weights (steps 3-4);
  * FedDU dynamic server update (Formulas 4-7) with g0_bar computed
    LITERALLY as the average of the per-step gradients along the server
    SGD path (Formula 6) — the engine uses the telescoping identity
    (w_start - w_end)/(tau*eta), which is exact for plain SGD, so any
    disagreement beyond float tolerance is a bug;
  * FedDUM server momentum on the pseudo-gradient (Formulas 8/12, with
    the descent-consistent sign — see repro.core.momentum);
  * the static-shape masked mode (``cfg.use_masks``): params, gradients
    and momentum buffers are multiplied by the 0/1 keep-masks in
    ``state["masks"]`` every round, exactly where the engine does;
  * the client-state algorithms: FedProx's proximal pull
    ``g + mu * (theta - anchor)`` inside each local step, and FedDyn's
    per-client correction in the engine's alpha-scaled parameterization
    (``h`` stores ``alpha * h_paper``): local gradient
    ``g + alpha * (theta - anchor) - h_k``, per-client update
    ``h_k <- h_k - alpha * act_k * (theta_k^end - anchor)``, shared
    ``h <- h - (alpha / N) * sum_k act_k * drift_k``, and server
    correction ``w_half <- w_half - h / alpha`` (skipped entirely when
    ``alpha == 0``, where ``h`` is identically zero);
  * straggler/dropout: when ``batch["active"]`` is present, aggregation
    runs in delta form ``base + sum w_k (local_k - base)`` with
    ``w = sizes * active / max(sum, 1e-12)``, so an all-dropped round is
    exactly a no-op and dropped clients' state is untouched.

The Formula-7 accuracy gate matches the engine's fused semantics: the
accuracy of w^{t-1/2} evaluated on the FIRST server batch.

`jax.tree` is used ONLY for pytree structure traversal; every number is
produced by NumPy in float64.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax  # tree structure only — no jnp math in this module
import numpy as np

from repro.core.engine import EngineConfig


def tree_f64(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(x, np.float64), tree)


def _zeros_like(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.zeros_like(np.asarray(x, np.float64)), tree)


def _index(tree: Any, *idx) -> Any:
    sl = tuple(idx)
    return jax.tree.map(lambda x: np.asarray(x, np.float64)[sl]
                        if np.issubdtype(np.asarray(x).dtype, np.floating)
                        else np.asarray(x)[sl], tree)


def ref_tau_eff(feddu, *, acc: float, round_idx: float, n0: float,
                n_prime: float, d_round: float, d_server: float,
                tau: int) -> float:
    """Formula 7, scalar float64."""
    if feddu.static_tau_eff is not None:
        return float(feddu.static_tau_eff)
    if feddu.f_prime_kind == "1-acc":
        gate = 1.0 - acc
    elif feddu.f_prime_kind == "inv":
        gate = 1.0 / (acc + feddu.eps)
    else:
        raise ValueError(feddu.f_prime_kind)
    num = n0 * d_round
    den = num + n_prime * d_server + feddu.eps
    return gate * (num / den) * feddu.C * (feddu.decay ** round_idx) * tau


def ref_local_train(cfg: EngineConfig, grad_fn: Callable, params: Any,
                    m0: Any, batches: list, lr: float,
                    anchor: Any = None, h: Any = None):
    """E local epochs on one client — Formula 11 when momentum is on.

    ``anchor`` is the round-start global model for the FedProx/FedDyn
    correction terms; ``h`` is this client's (alpha-scaled) FedDyn
    correction.  Both are ignored under plain FedAvg.
    """
    use_m = cfg.local_momentum != "none"
    beta = cfg.feddum.beta_local
    p, m = params, m0
    for b in batches:
        g = grad_fn(p, b)
        if cfg.algorithm == "fedprox":
            mu = cfg.fedprox.mu
            g = jax.tree.map(lambda gi, pi, ai: gi + mu * (pi - ai),
                             g, p, anchor)
        elif cfg.algorithm == "feddyn":
            alpha = cfg.feddyn.alpha
            g = jax.tree.map(lambda gi, pi, ai, hi: gi + alpha * (pi - ai) - hi,
                             g, p, anchor, h)
        if use_m:
            m = jax.tree.map(lambda mi, gi: beta * mi + (1 - beta) * gi, m, g)
            upd = m
        else:
            upd = g
        p = jax.tree.map(lambda pi, u: pi - lr * u, p, upd)
    return p, m


def ref_round(cfg: EngineConfig, grad_fn: Callable, loss_and_acc_fn: Callable,
              state: dict, batch: dict) -> tuple[dict, dict]:
    """One federated round, naive float64 — mirrors ``engine.round_core``.

    grad_fn(params, batch) and loss_and_acc_fn(params, batch) must be pure
    NumPy (see :class:`SoftmaxRegression` for the differential-test model);
    ``batch`` has the same layout as the engine's round batch, with NumPy
    leaves.
    """
    if cfg.use_masks:
        masks = tree_f64(state["masks"])
        _m = lambda t: jax.tree.map(lambda x, mk: x * mk, t, masks)
        base_grad_fn = grad_fn
        grad_fn = lambda p, b: _m(base_grad_fn(p, b))
    else:
        _m = lambda t: t

    params = _m(tree_f64(state["params"]))
    lr = cfg.lr * (cfg.lr_decay ** float(state["round"]))
    sizes = np.asarray(batch["sizes"], np.float64)
    num_clients = sizes.shape[0]
    steps = len(jax.tree.leaves(batch["client"])[0][0])

    # (2) local epochs on every selected client
    if cfg.local_momentum == "communicated":
        m0 = _m(tree_f64(state["global_m"]))
    else:
        m0 = _zeros_like(params)
    anchor = params if cfg.algorithm in ("fedprox", "feddyn") else None
    if cfg.algorithm == "feddyn":
        if "sel" not in batch:
            raise ValueError("feddyn needs batch['sel'] to index client state")
        sel = np.asarray(batch["sel"], np.int64)
        h_all = tree_f64(state["client_state"]["per_client"]["h"])
        h_sels = [_m(jax.tree.map(lambda x: x[sel[c]], h_all))
                  for c in range(num_clients)]
    else:
        h_sels = [None] * num_clients
    locals_, local_ms = [], []
    for c in range(num_clients):
        bs = [_index(batch["client"], c, s) for s in range(steps)]
        p, m = ref_local_train(cfg, grad_fn, params, m0, bs, lr,
                               anchor=anchor, h=h_sels[c])
        locals_.append(p)
        local_ms.append(m)

    # Fault injection + in-scan health guard, mirroring the engine: faults
    # corrupt the uploaded updates first, then non-finite clients are
    # scrubbed back to the broadcast point and zero-weighted.
    active = batch.get("active")
    guard_on = cfg.guard != "off"
    if cfg.faults:
        sel_ids = np.asarray(batch.get("sel", np.arange(num_clients)))
        for f in cfg.faults:
            locals_ = f.ref_apply_client(locals_, params, sel_ids,
                                         float(state["round"]))
    base_act = (np.asarray(active, np.float64) if active is not None
                else np.ones_like(sizes))
    if guard_on:
        client_ok = np.ones(num_clients, bool)
        for c in range(num_clients):
            checked = [locals_[c]]
            if cfg.local_momentum == "communicated":
                checked.append(local_ms[c])
            for tree in checked:
                for leaf in jax.tree.leaves(tree):
                    client_ok[c] &= bool(np.isfinite(leaf).all())
        rejected = float((base_act * (~client_ok)).sum())
        act = base_act * client_ok
        locals_ = [locals_[c] if client_ok[c] else
                   jax.tree.map(np.copy, params)
                   for c in range(num_clients)]
        if cfg.local_momentum == "communicated":
            local_ms = [local_ms[c] if client_ok[c] else
                        jax.tree.map(np.copy, m0)
                        for c in range(num_clients)]
    else:
        rejected = 0.0
        act = base_act

    # (3-4) FedAvg aggregation with n_k/n' weights; when the batch carries
    # an "active" vector (straggler/dropout) or the guard is on, run in
    # delta form so dropped clients contribute exactly zero and an
    # all-dropped round is a no-op.
    if active is not None or guard_on:
        w = sizes * act
        w = w / max(w.sum(), 1e-12)

        def weighted_mean(trees, base):
            return jax.tree.map(
                lambda b_, *leaves: b_ + sum(wi * (li - b_)
                                             for wi, li in zip(w, leaves)),
                base, *trees)

        w_half = weighted_mean(locals_, params)
        new_global_m = (weighted_mean(local_ms, m0)
                        if cfg.local_momentum == "communicated" else None)
    else:
        w = sizes / sizes.sum()

        def weighted_mean(trees):
            return jax.tree.map(
                lambda *leaves: sum(wi * li for wi, li in zip(w, leaves)),
                *trees)

        w_half = weighted_mean(locals_)
        new_global_m = (weighted_mean(local_ms)
                        if cfg.local_momentum == "communicated" else None)

    # (4b) FedDyn correction updates + server-side correction of w_half
    new_client_state = state.get("client_state")
    if cfg.algorithm == "feddyn":
        alpha = cfg.feddyn.alpha
        n_total = jax.tree.leaves(h_all)[0].shape[0]
        drifts = [jax.tree.map(lambda l, p0: l - p0, locals_[c], params)
                  for c in range(num_clients)]

        def scatter(ha, *rows):
            out = ha.copy()
            for c in range(num_clients):
                out[sel[c]] = rows[c]
            return out

        h_sel_new = [jax.tree.map(lambda hk, d, a=act[c]: hk - alpha * a * d,
                                  h_sels[c], drifts[c])
                     for c in range(num_clients)]
        h_new = jax.tree.map(scatter, h_all, *h_sel_new)
        h_shared = _m(tree_f64(state["client_state"]["shared"]["h"]))
        h_shared_new = jax.tree.map(
            lambda hs, *ds: hs - (alpha / n_total) * sum(
                a * d for a, d in zip(act, ds)),
            h_shared, *drifts)
        if alpha > 0:  # static branch: at alpha == 0, h is identically zero
            w_half = jax.tree.map(lambda wh, hs: wh - hs / alpha,
                                  w_half, h_shared_new)
        new_client_state = {"per_client": {"h": _m(h_new)},
                            "shared": {"h": _m(h_shared_new)}}

    # (5a) FedDU: tau server SGD steps; g0_bar is the literal Formula-6
    # average of the per-step gradients; acc gate from the first forward.
    if cfg.use_server_update:
        tau = len(jax.tree.leaves(batch["server"])[0])
        p = w_half
        grads = []
        acc = 0.0
        for i in range(tau):
            b = _index(batch["server"], i)
            _, a = loss_and_acc_fn(p, b)
            if i == 0:
                acc = float(a)
            g = grad_fn(p, b)
            grads.append(g)
            p = jax.tree.map(lambda pi, gi: pi - lr * gi, p, g)
        g0 = jax.tree.map(lambda *gs: sum(gs) / tau, *grads)
        t_eff = ref_tau_eff(cfg.feddu, acc=acc, round_idx=float(state["round"]),
                            n0=float(batch["n0"]), n_prime=float(sizes.sum()),
                            d_round=float(batch["d_round"]),
                            d_server=float(batch["d_server"]), tau=tau)
        proposed = jax.tree.map(lambda pi, gi: pi - t_eff * lr * gi, w_half, g0)
    else:
        proposed = w_half
        t_eff, acc = 0.0, 0.0

    # Server-step guard mirror: a non-finite proposal falls back to w_half.
    server_ok = True
    if guard_on and cfg.use_server_update:
        server_ok = bool(np.isfinite(t_eff) and np.isfinite(acc)
                         and all(np.isfinite(l).all()
                                 for l in jax.tree.leaves(proposed)))
        if not server_ok:
            proposed = w_half
            t_eff, acc = 0.0, 0.0

    # (5b) FedDUM server momentum on the pseudo-gradient (Formulas 8/12)
    if cfg.server_momentum:
        pseudo = jax.tree.map(lambda a, b_: a - b_, params, proposed)
        bs_ = cfg.feddum.beta_server
        m = jax.tree.map(lambda mi, g: bs_ * mi + (1 - bs_) * g,
                         tree_f64(state["server_m"]), pseudo)
        new_params = jax.tree.map(
            lambda pi, mi: pi - cfg.feddum.eta_server * mi, params, m)
    else:
        m = tree_f64(state["server_m"])
        new_params = proposed

    new_state = {"params": _m(new_params), "server_m": _m(m),
                 "round": float(state["round"]) + 1.0}
    if cfg.local_momentum == "communicated":
        new_state["global_m"] = _m(new_global_m)
    if cfg.use_masks:
        new_state["masks"] = masks
    if new_client_state is not None:
        new_state["client_state"] = new_client_state

    # Round-discard mirror: restore the round-start carry (round counter
    # advances) when the guard voids the round.
    if guard_on:
        survivors = float(np.sum(act)) > 0
        if cfg.guard == "reject_client":
            discard = not survivors
        else:  # skip_round
            discard = (not survivors) or rejected > 0 or not server_ok
        health = rejected + (0.0 if server_ok else 1.0)
        if discard:
            for k in ("params", "server_m", "global_m", "client_state"):
                if k in new_state:
                    new_state[k] = tree_f64(state[k])
            t_eff, acc = 0.0, 0.0
    else:
        health = 0.0
    return new_state, {"tau_eff": t_eff, "server_acc": acc,
                       "health": health}


def ref_init_state(params: Any, cfg: EngineConfig, masks: Any = None,
                   num_clients: int | None = None) -> dict:
    state = {"params": tree_f64(params), "server_m": _zeros_like(params),
             "round": 0.0}
    if cfg.local_momentum == "communicated":
        state["global_m"] = _zeros_like(params)
    if cfg.use_masks:
        state["masks"] = (tree_f64(masks) if masks is not None else
                          jax.tree.map(lambda x: np.ones_like(
                              np.asarray(x, np.float64)), params))
    if cfg.algorithm == "fedprox":
        state["client_state"] = {"per_client": {}, "shared": {}}
    elif cfg.algorithm == "feddyn":
        if num_clients is None:
            raise ValueError("feddyn needs num_clients for its per-client h")
        state["client_state"] = {
            "per_client": {"h": jax.tree.map(
                lambda x: np.zeros((num_clients,) + np.shape(x), np.float64),
                params)},
            "shared": {"h": _zeros_like(params)},
        }
    return state


# ---------------------------------------------------------------------------
# Differential-test model: softmax regression with a CLOSED-FORM NumPy
# gradient (no autodiff anywhere on the oracle side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SoftmaxRegression:
    """Linear softmax classifier, d features -> c classes.

    NumPy methods feed the oracle; the jnp-free closed-form gradient also
    cross-checks `jax.grad` on the engine side.  ``loss_and_acc`` (the
    PaperModel-style (params, x, y) interface) is provided by the test via
    jnp so the engine path stays pure-JAX.
    """

    dim: int = 6
    num_classes: int = 4

    def init(self, seed: int = 0) -> dict:
        rng = np.random.default_rng(seed)
        return {
            "w": (0.5 * rng.standard_normal((self.dim, self.num_classes))
                  ).astype(np.float32),
            "b": np.zeros((self.num_classes,), np.float32),
        }

    @staticmethod
    def _logits(params, x):
        return x @ params["w"] + params["b"]

    def np_loss_and_acc(self, params, batch):
        x, y = np.asarray(batch[0]), np.asarray(batch[1])
        z = self._logits(params, x)
        z = z - z.max(axis=1, keepdims=True)
        logp = z - np.log(np.exp(z).sum(axis=1, keepdims=True))
        loss = -logp[np.arange(len(y)), y].mean()
        acc = (z.argmax(axis=1) == y).mean()
        return loss, acc

    def np_grad(self, params, batch):
        x, y = np.asarray(batch[0]), np.asarray(batch[1])
        z = self._logits(params, x)
        z = z - z.max(axis=1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=1, keepdims=True)
        p[np.arange(len(y)), y] -= 1.0
        p /= len(y)
        return {"w": x.T @ p, "b": p.sum(axis=0)}
