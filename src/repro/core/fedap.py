"""FedAP as a first-class plan event: the end-to-end Algorithm 3 decision.

Runs ONCE, at the ``Prune`` event of a :class:`repro.core.plan.TrainPlan`
(paper: round 30):
  * per-participant expected rates from the empirical-Fisher eigen-gap
    (server + every device, in parallel in the real system; sequentially
    in the simulation),
  * Formula 15 aggregation weighted by n_k / (D(P_k)+eps),
  * global magnitude threshold -> per-layer rates,
  * HRank filter selection on server data.

The DECISION (which filters to keep) is computed here, once, on the host;
how it is APPLIED is the plan event's mode:

  Prune(mode="mask")    `pruning.param_masks` -> keep-masks injected into
                        the scan carry; training never leaves the compiled
                        scan (`EngineConfig.use_masks`).
  Prune(mode="shrink")  `pruning.shrink_params` -> genuinely smaller model,
                        re-traced at the segment boundary (the legacy
                        ``on_round_end`` hook behaviour).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import niid
from repro.core.pruning import (
    FedAPConfig,
    PruneSpec,
    aggregate_rates,
    expected_rate_from_spectrum,
    fisher_spectrum,
    global_threshold,
    lipschitz_estimate,
    per_layer_rates,
    feature_map_ranks,
    select_filters,
)


def participant_rate(model, params, init_params, x, y, cfg: FedAPConfig):
    """p*_k for one participant from its local probe data."""

    def loss_one(p, xi, yi):
        return model.loss_and_acc(p, xi[None], yi[None])[0]

    def per_sample_grads(p, batch):
        return jax.vmap(lambda xi, yi: jax.grad(loss_one)(p, xi, yi))(*batch)

    probe = (x[: cfg.probe_size], y[: cfg.probe_size])
    eigs = fisher_spectrum(per_sample_grads, params, probe)

    def grad_fn(p, batch):
        return jax.grad(lambda pp: model.loss_and_acc(pp, batch[0], batch[1])[0])(p)

    lip = lipschitz_estimate(grad_fn, params, init_params, probe)
    return expected_rate_from_spectrum(eigs, lip, cfg.max_rate)


@dataclasses.dataclass
class FedAPDecision:
    """The output of Algorithm 3: which filters each prunable layer keeps."""

    kept: dict[str, np.ndarray]        # layer -> sorted kept-filter indices
    p_star: float                      # Formula-15 aggregate rate
    layer_rates: dict[str, float]      # per-layer rates (Alg. 3 lines 9-11)

    def summary(self) -> dict[str, Any]:
        """JSON-friendly view (kept reduced to per-layer counts)."""
        return {"p_star": self.p_star, "layer_rates": dict(self.layer_rates),
                "kept_counts": {k: int(len(v)) for k, v in self.kept.items()}}


def _draw_participants(data, cfg: FedAPConfig, rng: np.random.Generator
                       ) -> np.ndarray:
    """The probed client subset (index 0 of the rate vectors is always the
    server), clamped to the available clients with a warning."""
    num_clients = data.client_x.shape[0]
    draw = min(cfg.participants, num_clients)
    if draw < cfg.participants:
        warnings.warn(
            f"FedAPConfig.participants={cfg.participants} exceeds the "
            f"{num_clients} available clients; probing all {num_clients} "
            "instead (every client's local data contributes a rate)",
            stacklevel=3)
    return rng.choice(num_clients, size=draw, replace=False)


def _finish_decision(model, data, cfg: FedAPConfig, params: Any,
                     rates, sizes, degrees) -> FedAPDecision:
    """Algorithm 3, steps 2-4 (shared by the host-side and the pod-side
    step-1 implementations): Formula 15 -> global magnitude threshold ->
    per-layer rates -> HRank selection on server data."""
    p_star = aggregate_rates(jnp.asarray(rates), jnp.asarray(sizes),
                             jnp.asarray(degrees), cfg.eps)
    # optional compression-budget floor (cfg.min_rate=0 keeps Algorithm 3's
    # pure eigen-gap decision, which may legitimately prune nothing)
    p_star = jnp.clip(p_star, cfg.min_rate, cfg.max_rate)

    spec: PruneSpec = model.prune_spec(params)
    thr = global_threshold(params, spec, p_star)
    layer_rates = per_layer_rates(params, spec, thr)

    fmaps = model.feature_maps(params,
                               jnp.asarray(data.server_x[: cfg.probe_size]))
    kept = {}
    for layer in spec.layers:
        scores = feature_map_ranks(fmaps[layer.feature_key or layer.name])
        kept[layer.name] = select_filters(scores,
                                          float(layer_rates[layer.name]),
                                          align=cfg.align)
    return FedAPDecision(kept=kept, p_star=float(p_star),
                         layer_rates={k: float(v)
                                      for k, v in layer_rates.items()})


def fedap_decision(model, data, cfg: FedAPConfig, params: Any, *,
                   init_params: Any, rng: np.random.Generator | None = None
                   ) -> FedAPDecision:
    """Algorithm 3, steps 1-4: expected rates -> Formula 15 -> per-layer
    rates -> HRank selection.  Pure host-side decision; applying it is the
    caller's (plan executor's) job.

    ``cfg.participants``: number of devices (beyond the server) whose local
    data contributes a rate — the paper uses all of D; the simulation
    samples a subset for tractability (rates concentrate quickly).
    """
    rng = np.random.default_rng(0) if rng is None else rng
    p_bar = niid.global_distribution(data.client_dists, data.sizes)

    # --- per-participant expected rates (index 0 = server) ----------------
    ids = _draw_participants(data, cfg, rng)
    rates, sizes, degrees = [], [], []
    r0 = participant_rate(model, params, init_params,
                          jnp.asarray(data.server_x),
                          jnp.asarray(data.server_y), cfg)
    rates.append(r0)
    sizes.append(data.server_x.shape[0])
    degrees.append(niid.non_iid_degree(data.server_dist, p_bar))
    for k in ids:
        rk = participant_rate(model, params, init_params,
                              jnp.asarray(data.client_x[k]),
                              jnp.asarray(data.client_y[k]), cfg)
        rates.append(rk)
        sizes.append(float(data.sizes[k]))
        degrees.append(niid.non_iid_degree(data.client_dists[k], p_bar))

    return _finish_decision(model, data, cfg, params,
                            jnp.stack(rates), jnp.asarray(sizes),
                            jnp.stack(degrees))


def fedap_decision_sharded(model, data, cfg: FedAPConfig, params: Any, *,
                           init_params: Any,
                           rng: np.random.Generator | None = None,
                           mesh=None, client_axes: tuple = ("data",)
                           ) -> FedAPDecision:
    """Algorithm 3 with step 1 executed POD-SIDE (the MeshBackend's Prune
    path): the participants' probe sets are STACKED into one
    ``[participants+1, probe, ...]`` batch, placed with the participant
    axis sharded over the mesh client axes, and the per-participant Fisher
    spectra + Lipschitz estimates run as ONE vmapped program — every device
    probes its own participants in parallel, and the resulting rate vector
    is gathered back for the host-side Formula-15 aggregation.  Steps 2-4
    are shared with :func:`fedap_decision`, so the two entry points make
    the same decision up to float tolerance (locked by
    tests/test_mesh_backend.py).

    Requires every probed participant to hold at least ``cfg.probe_size``
    samples (the stacked probe must be rectangular).
    """
    rng = np.random.default_rng(0) if rng is None else rng
    p_bar = niid.global_distribution(data.client_dists, data.sizes)
    ids = _draw_participants(data, cfg, rng)

    probe = cfg.probe_size
    n0 = data.server_x.shape[0]
    n_k = data.client_x.shape[1]
    if min(n0, n_k) < probe:
        raise ValueError(
            f"fedap_decision_sharded stacks rectangular probes: every "
            f"participant needs >= probe_size={probe} samples, but "
            f"n0={n0}, n_k={n_k}")
    xs = np.stack([np.asarray(data.server_x[:probe])]
                  + [np.asarray(data.client_x[k][:probe]) for k in ids])
    ys = np.stack([np.asarray(data.server_y[:probe])]
                  + [np.asarray(data.client_y[k][:probe]) for k in ids])
    sizes = jnp.asarray([float(n0)] + [float(data.sizes[k]) for k in ids])
    degrees = jnp.stack(
        [niid.non_iid_degree(data.server_dist, p_bar)]
        + [niid.non_iid_degree(data.client_dists[k], p_bar) for k in ids])

    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    if mesh is not None and client_axes:
        from repro.sharding.fl_specs import client_dim_sharding

        sh = client_dim_sharding(mesh, client_axes, xs.shape[0])
        xs_d, ys_d = jax.device_put(xs_d, sh), jax.device_put(ys_d, sh)
    # the probes are already probe_size-sliced, so participant_rate (the
    # host path's step 1, unchanged) vmaps over the participant axis
    rates = jax.jit(jax.vmap(
        lambda x, y: participant_rate(model, params, init_params, x, y,
                                      cfg)))(xs_d, ys_d)

    return _finish_decision(model, data, cfg, params, rates, sizes, degrees)
