"""FedAP as a first-class plan event: the end-to-end Algorithm 3 decision.

Runs ONCE, at the ``Prune`` event of a :class:`repro.core.plan.TrainPlan`
(paper: round 30):
  * per-participant expected rates from the empirical-Fisher eigen-gap
    (server + every device, in parallel in the real system; sequentially
    in the simulation),
  * Formula 15 aggregation weighted by n_k / (D(P_k)+eps),
  * global magnitude threshold -> per-layer rates,
  * HRank filter selection on server data.

The DECISION (which filters to keep) is computed here, once, on the host;
how it is APPLIED is the plan event's mode:

  Prune(mode="mask")    `pruning.param_masks` -> keep-masks injected into
                        the scan carry; training never leaves the compiled
                        scan (`EngineConfig.use_masks`).
  Prune(mode="shrink")  `pruning.shrink_params` -> genuinely smaller model,
                        re-traced at the segment boundary (the legacy
                        ``on_round_end`` hook behaviour).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import niid
from repro.utils.arrays import pad_rows_with_first
from repro.core.pruning import (
    FedAPConfig,
    PruneSpec,
    aggregate_rates,
    expected_rate_from_spectrum,
    feature_map_ranks,
    feature_map_scores,
    fisher_spectrum,
    global_threshold,
    lipschitz_estimate,
    per_layer_rates,
    select_filters,
)


def participant_rate(model, params, init_params, x, y, cfg: FedAPConfig):
    """p*_k for one participant from its local probe data."""

    def loss_one(p, xi, yi):
        return model.loss_and_acc(p, xi[None], yi[None])[0]

    def per_sample_grads(p, batch):
        return jax.vmap(lambda xi, yi: jax.grad(loss_one)(p, xi, yi))(*batch)

    probe = (x[: cfg.probe_size], y[: cfg.probe_size])
    eigs = fisher_spectrum(per_sample_grads, params, probe)

    def grad_fn(p, batch):
        return jax.grad(lambda pp: model.loss_and_acc(pp, batch[0], batch[1])[0])(p)

    lip = lipschitz_estimate(grad_fn, params, init_params, probe)
    return expected_rate_from_spectrum(eigs, lip, cfg.max_rate)


def participant_rate_padded(model, params, init_params, x, y, row_mask,
                            n_valid, cfg: FedAPConfig):
    """p*_k from a PADDED probe set (the sharded ragged-probe path).

    ``x``/``y`` hold ``n_valid`` real samples followed by padding rows
    (copies — their values never matter); ``row_mask`` is the matching
    [rows] 0/1 validity vector.  Padded rows contribute NOTHING to the
    statistics: their per-sample gradients are zeroed before the Gram
    product (the padded spectrum is then the valid spectrum plus exact
    zero eigenvalues, masked out of the eigen-gap search via
    ``valid=n_valid``), and the Lipschitz estimate differentiates the
    validity-weighted mean loss.  With an all-ones mask this computes the
    same decision as :func:`participant_rate` up to float association
    (vmapped per-sample losses vs one batched forward)."""

    def loss_one(p, xi, yi):
        return model.loss_and_acc(p, xi[None], yi[None])[0]

    def per_sample_grads(p, batch):
        bx, by, bm, _ = batch
        g = jax.vmap(lambda xi, yi: jax.grad(loss_one)(p, xi, yi))(bx, by)
        return jax.tree.map(
            lambda t: t * bm.reshape((t.shape[0],) + (1,) * (t.ndim - 1)), g)

    batch = (x, y, row_mask, n_valid)
    eigs = fisher_spectrum(per_sample_grads, params, batch,
                           n_valid=n_valid.astype(jnp.float32))

    def masked_loss(p, b):
        bx, by, bm, nv = b
        losses = jax.vmap(lambda xi, yi: loss_one(p, xi, yi))(bx, by)
        return jnp.sum(losses * bm) / nv.astype(jnp.float32)

    lip = lipschitz_estimate(jax.grad(masked_loss), params, init_params,
                             batch)
    return expected_rate_from_spectrum(eigs, lip, cfg.max_rate,
                                       valid=n_valid)


@dataclasses.dataclass
class FedAPDecision:
    """The output of Algorithm 3: which filters each prunable layer keeps."""

    kept: dict[str, np.ndarray]        # layer -> sorted kept-filter indices
    p_star: float                      # Formula-15 aggregate rate
    layer_rates: dict[str, float]      # per-layer rates (Alg. 3 lines 9-11)

    def summary(self) -> dict[str, Any]:
        """JSON-friendly view (kept reduced to per-layer counts).  The
        kept entries are [keep] index vectors (CNN) or [L, keep] index
        ROWS (scanned LM stacks) — the count is the trailing dim."""
        return {"p_star": self.p_star, "layer_rates": dict(self.layer_rates),
                "kept_counts": {k: int(np.asarray(v).shape[-1])
                                for k, v in self.kept.items()}}


def _draw_participants(data, cfg: FedAPConfig, rng: np.random.Generator
                       ) -> np.ndarray:
    """The probed client subset (index 0 of the rate vectors is always the
    server), clamped to the available clients with a warning."""
    num_clients = data.client_x.shape[0]
    draw = min(cfg.participants, num_clients)
    if draw < cfg.participants:
        warnings.warn(
            f"FedAPConfig.participants={cfg.participants} exceeds the "
            f"{num_clients} available clients; probing all {num_clients} "
            "instead (every client's local data contributes a rate)",
            stacklevel=3)
    return rng.choice(num_clients, size=draw, replace=False)


def _finish_decision(model, data, cfg: FedAPConfig, params: Any,
                     rates, sizes, degrees, *, mesh=None,
                     client_axes: tuple = ()) -> FedAPDecision:
    """Algorithm 3, steps 2-4 (shared by the host-side and the pod-side
    step-1 implementations): Formula 15 -> global magnitude threshold ->
    per-layer rates -> HRank selection on server data.

    With ``mesh``/``client_axes`` the HRank probe forward is BATCH-SHARDED
    over the mesh like the eval pass: the server probe batch is padded to a
    multiple of the client axes with copies of row 0, each shard computes
    PER-SAMPLE scores (:func:`pruning.feature_map_scores` — each row
    depends only on its own activations) summed over its rows, and the
    padded rows' contribution is subtracted back out exactly with one
    single-row forward:

        scores_true = (sum_pad - k * scores(row 0)) / n_true

    Conv ranks are integer-valued per sample, so the float32 sums — and
    therefore the sharded decision — equal the host decision exactly
    (locked by tests/test_mesh_backend.py's decision-equality tests)."""
    p_star = aggregate_rates(jnp.asarray(rates), jnp.asarray(sizes),
                             jnp.asarray(degrees), cfg.eps)
    # optional compression-budget floor (cfg.min_rate=0 keeps Algorithm 3's
    # pure eigen-gap decision, which may legitimately prune nothing)
    p_star = jnp.clip(p_star, cfg.min_rate, cfg.max_rate)

    if hasattr(model, "decide_kept"):
        # Scanned-stack models (repro.models.lm.LM) select kept units from
        # the aggregate rate directly: weight-norm product scores stand in
        # for HRank inside the scan (interior activations are not
        # observable without unrolling — see core.pruning_lm), with a
        # uniform lane-aligned kept count per stack.  A pure host function
        # of (params, p_star), so the host and mesh entry points — which
        # only differ in how step 1 computed the rates — decide
        # identically.
        kept = {k: np.asarray(v) for k, v in
                model.decide_kept(params, float(p_star),
                                  align=cfg.align).items()}
        widths = {k: int(np.asarray(m).shape[-1])
                  for k, m in model.filter_masks(params, kept).items()}
        return FedAPDecision(
            kept=kept, p_star=float(p_star),
            layer_rates={k: 1.0 - v.shape[-1] / widths[k]
                         for k, v in kept.items()})

    spec: PruneSpec = model.prune_spec(params)
    thr = global_threshold(params, spec, p_star)
    layer_rates = per_layer_rates(params, spec, thr)

    probe_x = np.asarray(data.server_x[: cfg.probe_size])
    scores_by = _probe_scores(model, params, spec, probe_x,
                              mesh=mesh, client_axes=client_axes)
    kept = {}
    for layer in spec.layers:
        kept[layer.name] = select_filters(scores_by[layer.name],
                                          float(layer_rates[layer.name]),
                                          align=cfg.align)
    return FedAPDecision(kept=kept, p_star=float(p_star),
                         layer_rates={k: float(v)
                                      for k, v in layer_rates.items()})


def _probe_scores(model, params, spec: PruneSpec, probe_x, *, mesh=None,
                  client_axes: tuple = ()) -> dict[str, np.ndarray]:
    """{layer name: [d_l] HRank scores} over the server probe batch —
    host-side single forward, or mesh-sharded (see ``_finish_decision``)."""
    if mesh is None or not client_axes:
        fmaps = model.feature_maps(params, jnp.asarray(probe_x))
        return {l.name: feature_map_ranks(fmaps[l.feature_key or l.name])
                for l in spec.layers}

    from repro.sharding.fl_specs import client_dim_sharding

    size = 1
    for a in client_axes:
        size *= mesh.shape[a]
    n_true = probe_x.shape[0]
    n_pad = -(-n_true // size) * size

    def score_sums(x):
        fmaps = model.feature_maps(params, x)
        return {l.name: jnp.sum(
            feature_map_scores(fmaps[l.feature_key or l.name]), axis=0)
            for l in spec.layers}

    xd = jax.device_put(jnp.asarray(pad_rows_with_first(probe_x, n_pad)),
                        client_dim_sharding(mesh, client_axes, n_pad))
    sums = jax.jit(score_sums)(xd)
    if n_pad == n_true:
        return {k: np.asarray(v) / n_true for k, v in sums.items()}
    k_pad = float(n_pad - n_true)
    s0 = jax.jit(score_sums)(jnp.asarray(probe_x[:1]))
    return {k: (np.asarray(sums[k]) - k_pad * np.asarray(s0[k])) / n_true
            for k in sums}


def fedap_decision(model, data, cfg: FedAPConfig, params: Any, *,
                   init_params: Any, rng: np.random.Generator | None = None
                   ) -> FedAPDecision:
    """Algorithm 3, steps 1-4: expected rates -> Formula 15 -> per-layer
    rates -> HRank selection.  Pure host-side decision; applying it is the
    caller's (plan executor's) job.

    ``cfg.participants``: number of devices (beyond the server) whose local
    data contributes a rate — the paper uses all of D; the simulation
    samples a subset for tractability (rates concentrate quickly).
    """
    rng = np.random.default_rng(0) if rng is None else rng
    p_bar = niid.global_distribution(data.client_dists, data.sizes)

    # --- per-participant expected rates (index 0 = server) ----------------
    ids = _draw_participants(data, cfg, rng)
    rates, sizes, degrees = [], [], []
    r0 = participant_rate(model, params, init_params,
                          jnp.asarray(data.server_x),
                          jnp.asarray(data.server_y), cfg)
    rates.append(r0)
    sizes.append(data.server_x.shape[0])
    degrees.append(niid.non_iid_degree(data.server_dist, p_bar))
    for k in ids:
        rk = participant_rate(model, params, init_params,
                              jnp.asarray(data.client_x[k]),
                              jnp.asarray(data.client_y[k]), cfg)
        rates.append(rk)
        sizes.append(float(data.sizes[k]))
        degrees.append(niid.non_iid_degree(data.client_dists[k], p_bar))

    return _finish_decision(model, data, cfg, params,
                            jnp.stack(rates), jnp.asarray(sizes),
                            jnp.stack(degrees))


def fedap_decision_sharded(model, data, cfg: FedAPConfig, params: Any, *,
                           init_params: Any,
                           rng: np.random.Generator | None = None,
                           mesh=None, client_axes: tuple = ("data",)
                           ) -> FedAPDecision:
    """Algorithm 3 with step 1 executed POD-SIDE (the MeshBackend's Prune
    path): the participants' probe sets are STACKED into one
    ``[participants+1, probe, ...]`` batch, placed with the participant
    axis sharded over the mesh client axes, and the per-participant Fisher
    spectra + Lipschitz estimates run as ONE vmapped program — every device
    probes its own participants in parallel, and the resulting rate vector
    is gathered back for the host-side Formula-15 aggregation.  Steps 2-4
    are shared with :func:`fedap_decision`, so the two entry points make
    the same decision up to float tolerance (locked by
    tests/test_mesh_backend.py).

    RAGGED probe sets — participants holding fewer than ``cfg.probe_size``
    samples (e.g. a small server pool next to larger clients) — are
    handled by padding: every participant's probe is padded to the widest
    actual probe with copies of its own first row, and a per-row validity
    mask zeroes the padded rows out of the Fisher spectrum and the
    Lipschitz estimate (:func:`participant_rate_padded`), so each
    participant's rate is computed over exactly the samples the host path
    would probe.  Rectangular probes keep the host path's
    :func:`participant_rate` verbatim.
    """
    rng = np.random.default_rng(0) if rng is None else rng
    p_bar = niid.global_distribution(data.client_dists, data.sizes)
    ids = _draw_participants(data, cfg, rng)

    probe = cfg.probe_size
    n0 = data.server_x.shape[0]
    n_k = data.client_x.shape[1]
    takes = np.asarray([min(probe, n0)] + [min(probe, n_k)] * len(ids))
    p_max = int(takes.max())

    def pad0(a, take):
        return pad_rows_with_first(np.asarray(a[:take]), p_max)

    xs = np.stack([pad0(data.server_x, takes[0])]
                  + [pad0(data.client_x[k], t)
                     for k, t in zip(ids, takes[1:])])
    ys = np.stack([pad0(data.server_y, takes[0])]
                  + [pad0(data.client_y[k], t)
                     for k, t in zip(ids, takes[1:])])
    sizes = jnp.asarray([float(n0)] + [float(data.sizes[k]) for k in ids])
    degrees = jnp.stack(
        [niid.non_iid_degree(data.server_dist, p_bar)]
        + [niid.non_iid_degree(data.client_dists[k], p_bar) for k in ids])

    ragged = bool((takes != p_max).any())
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    sh = None
    if mesh is not None and client_axes:
        from repro.sharding.fl_specs import client_dim_sharding

        sh = client_dim_sharding(mesh, client_axes, xs.shape[0])
        xs_d, ys_d = jax.device_put(xs_d, sh), jax.device_put(ys_d, sh)
    if ragged:
        row_mask = (np.arange(p_max)[None, :]
                    < takes[:, None]).astype(np.float32)
        mask_d = jnp.asarray(row_mask)
        nv_d = jnp.asarray(takes, jnp.int32)
        if sh is not None:
            mask_d, nv_d = jax.device_put(mask_d, sh), jax.device_put(nv_d,
                                                                      sh)
        rates = jax.jit(jax.vmap(
            lambda x, y, m, nv: participant_rate_padded(
                model, params, init_params, x, y, m, nv, cfg)))(
                    xs_d, ys_d, mask_d, nv_d)
    else:
        # rectangular probes, already probe-sliced: participant_rate (the
        # host path's step 1, unchanged) vmaps over the participant axis
        rates = jax.jit(jax.vmap(
            lambda x, y: participant_rate(model, params, init_params, x, y,
                                          cfg)))(xs_d, ys_d)

    return _finish_decision(model, data, cfg, params, rates, sizes, degrees,
                            mesh=mesh, client_axes=client_axes)
