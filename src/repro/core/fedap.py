"""FedAP glue: the end-to-end adaptive-pruning hook for the FL engine.

Runs ONCE at ``cfg.prune_round`` (paper: round 30):
  * per-participant expected rates from the empirical-Fisher eigen-gap
    (server + every device, in parallel in the real system; sequentially
    in the simulation),
  * Formula 15 aggregation weighted by n_k / (D(P_k)+eps),
  * global magnitude threshold -> per-layer rates,
  * HRank filter selection on server data,
  * structural shrink + engine re-jit.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import niid
from repro.core.pruning import (
    FedAPConfig,
    PruneSpec,
    aggregate_rates,
    expected_rate_from_spectrum,
    fisher_spectrum,
    global_threshold,
    lipschitz_estimate,
    per_layer_rates,
    feature_map_ranks,
    select_filters,
    shrink_params,
)


def participant_rate(model, params, init_params, x, y, cfg: FedAPConfig):
    """p*_k for one participant from its local probe data."""

    def loss_one(p, xi, yi):
        return model.loss_and_acc(p, xi[None], yi[None])[0]

    def per_sample_grads(p, batch):
        return jax.vmap(lambda xi, yi: jax.grad(loss_one)(p, xi, yi))(*batch)

    probe = (x[: cfg.probe_size], y[: cfg.probe_size])
    eigs = fisher_spectrum(per_sample_grads, params, probe)

    def grad_fn(p, batch):
        return jax.grad(lambda pp: model.loss_and_acc(pp, batch[0], batch[1])[0])(p)

    lip = lipschitz_estimate(grad_fn, params, init_params, probe)
    return expected_rate_from_spectrum(eigs, lip, cfg.max_rate)


def make_fedap_hook(model, data, cfg: FedAPConfig, *, init_params: Any,
                    participants: int = 8, seed: int = 0):
    """``on_round_end`` hook implementing Algorithm 3.

    ``participants``: number of devices (beyond the server) whose local data
    contributes a rate — the paper uses all of D; the simulation samples a
    subset for tractability (rates concentrate quickly).
    """
    rng = np.random.default_rng(seed)
    result: dict[str, Any] = {"kept": None, "p_star": None, "layer_rates": None}

    def hook(trainer, t, params):
        if t + 1 != cfg.prune_round:
            return None
        p_bar = niid.global_distribution(data.client_dists, data.sizes)

        # --- per-participant expected rates (index 0 = server) ------------
        ids = rng.choice(data.client_x.shape[0], size=participants, replace=False)
        spectra_rates, sizes, degrees = [], [], []
        r0 = participant_rate(model, params, init_params,
                              jnp.asarray(data.server_x), jnp.asarray(data.server_y), cfg)
        spectra_rates.append(r0)
        sizes.append(data.server_x.shape[0])
        degrees.append(niid.non_iid_degree(data.server_dist, p_bar))
        for k in ids:
            rk = participant_rate(model, params, init_params,
                                  jnp.asarray(data.client_x[k]),
                                  jnp.asarray(data.client_y[k]), cfg)
            spectra_rates.append(rk)
            sizes.append(float(data.sizes[k]))
            degrees.append(niid.non_iid_degree(data.client_dists[k], p_bar))

        p_star = aggregate_rates(jnp.stack(spectra_rates), jnp.asarray(sizes),
                                 jnp.stack(degrees), cfg.eps)

        # --- per-layer rates from the global magnitude threshold ----------
        spec: PruneSpec = model.prune_spec(params)
        thr = global_threshold(params, spec, p_star)
        layer_rates = per_layer_rates(params, spec, thr)

        # --- HRank selection on server data + structural shrink -----------
        fmaps = model.feature_maps(params, jnp.asarray(data.server_x[: cfg.probe_size]))
        kept = {}
        for layer in spec.layers:
            scores = feature_map_ranks(fmaps[layer.feature_key or layer.name])
            kept[layer.name] = select_filters(scores, float(layer_rates[layer.name]),
                                              align=cfg.align)
        new_params = shrink_params(params, spec, kept)
        result.update(kept=kept, p_star=float(p_star),
                      layer_rates={k: float(v) for k, v in layer_rates.items()})
        return new_params

    hook.result = result
    return hook
