"""repro.core — the paper's contribution: FedDU + FedDUM + FedAP.

Public surface:
  niid            — non-IID degrees (JS divergence), Formulas 2-3
  server_update   — FedDU dynamic server update, Formulas 4-7
  momentum        — FedDUM decoupled two-sided momentum, Formulas 8/11/12
  pruning, fedap  — FedAP layer-adaptive structured pruning, Algorithm 3
  engine          — the unified scan/shard_map-safe round (round_core)
  ref_engine      — pure-NumPy oracle for differential-testing the engine
  plan            — declarative TrainPlan (Scan/Eval/Prune/Snapshot events)
  backend         — the backend-agnostic PlanExecutor + the pluggable
                    execution backends (LocalScanBackend / MeshBackend)
  rounds          — FederatedTrainer facade over the backends
  baselines       — FedAvg / Data-sharing / Hybrid-FL / ServerM / DeviceM /
                    FedDA / FedDF / FedKT / IMC / PruneFL / HRank
"""
from repro.core import (
    backend,
    baselines,
    engine,
    fedap,
    momentum,
    niid,
    plan,
    pruning,
    pruning_lm,
    ref_engine,
    rounds,
    server_update,
)
from repro.core.backend import (
    LocalScanBackend,
    MeshBackend,
    PlanExecutor,
)
from repro.core.engine import (
    EngineConfig,
    FedDynConfig,
    FedProxConfig,
    init_round_state,
    round_core,
)
from repro.core.plan import (
    Callback,
    Eval,
    Prune,
    RunResult,
    Scan,
    Snapshot,
    TrainPlan,
    fedap_plan,
    load_artifact,
)
from repro.core.rounds import FederatedTrainer, FLConfig, feddumap_config
from repro.core.server_update import FedDUConfig, tau_eff
from repro.core.momentum import FedDUMConfig
from repro.core.pruning import FedAPConfig, PruneSpec, PrunableLayer, CoupledParam

__all__ = [
    "backend", "baselines", "engine", "fedap", "momentum", "niid", "plan",
    "pruning", "pruning_lm", "ref_engine", "rounds", "server_update",
    "PlanExecutor", "LocalScanBackend", "MeshBackend",
    "EngineConfig", "FedProxConfig", "FedDynConfig",
    "init_round_state", "round_core",
    "FederatedTrainer", "FLConfig", "feddumap_config",
    "TrainPlan", "Scan", "Eval", "Prune", "Snapshot", "Callback",
    "RunResult", "fedap_plan", "load_artifact",
    "FedDUConfig", "FedDUMConfig", "FedAPConfig",
    "PruneSpec", "PrunableLayer", "CoupledParam", "tau_eff",
]
