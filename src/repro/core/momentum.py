"""FedDUM — decoupled two-sided momentum (paper Section 3.3).

Key ideas (Formulas 8, 11, 12):

* On each device, run SGDM with the momentum buffer RESET TO ZERO at the
  start of every round (m'_k^{t,0} = 0, w'_k^{t,0} = w^t).  Restarting
  avoids communicating momentum; Theorem 3.1 bounds the deviation from
  centralized SGDM by O(e^{lambda+ E}) for small E.

* On the server, form the pseudo-gradient

      g(w^{t-1}) = w^{t-1/2} + tau_eff * eta * g0_bar - w^{t-1}      (12)

  NOTE on sign: the paper's Formula 12 as printed has "+ tau_eff eta g0"
  but Formula 4 applies the server term with a MINUS (descent).  Formula 8
  then does w^t = w^{t-1} - eta_s * m^t.  For the composition to reduce to
  FedDU when beta=0 and eta_s=1 we need

      g = w^{t-1} - (w^{t-1/2} - tau_eff*eta*g0_bar),

  i.e. (old - proposed).  With the paper's literal "+" the server update
  would ASCEND on the server data, contradicting Formula 4; we treat the
  printed sign as a typo and implement the descent-consistent form.  Unit
  test ``test_feddum_beta0_reduces_to_feddu`` locks this in.

* Server momentum then smooths the pseudo-gradient exactly like
  centralized SGDM (Formula 8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.utils import tree_sub, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class FedDUMConfig:
    beta_server: float = 0.9   # beta  in Formula 8
    beta_local: float = 0.9    # beta' in Formula 11
    eta_server: float = 1.0    # eta   in Formula 8 (server step on pseudo-grad)


def local_sgdm_step(params: Any, m: Any, grads: Any, *, beta: float, eta: float):
    """One local iteration of Formula 11 (damped SGDM)."""
    m = jax.tree.map(lambda mi, g: beta * mi + (1.0 - beta) * g.astype(jnp.float32), m, grads)
    params = jax.tree.map(lambda p, mi: (p - eta * mi).astype(p.dtype), params, m)
    return params, m


def init_local_momentum(params: Any) -> Any:
    """m'_k^{t,0} = 0 — the restart that removes momentum communication."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def server_pseudo_gradient(w_prev: Any, w_half_plus_server: Any) -> Any:
    """Formula 12 (descent-consistent form): g = w^{t-1} - proposed.

    ``w_half_plus_server`` is the FedAvg aggregate with the FedDU server
    correction already folded in (w^{t-1/2} - tau_eff*eta*g0_bar).
    """
    return jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), w_prev, w_half_plus_server
    )


def server_momentum_step(w_prev: Any, m: Any, pseudo_grad: Any, cfg: FedDUMConfig):
    """Formula 8 on the server: m^t = beta m + (1-beta) g; w^t = w - eta_s m^t."""
    m = jax.tree.map(
        lambda mi, g: cfg.beta_server * mi + (1.0 - cfg.beta_server) * g, m, pseudo_grad
    )
    w = jax.tree.map(lambda p, mi: (p.astype(jnp.float32) - cfg.eta_server * mi).astype(p.dtype),
                     w_prev, m)
    return w, m


def init_server_momentum(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
