"""Baseline FL algorithms from the paper's evaluation (Section 4).

Every baseline reuses the :class:`~repro.core.rounds.FederatedTrainer`
engine so comparisons are apples-to-apples:

  FedAvg        — plain local SGD + weighted averaging [5].
  Data-sharing  — server data is SHIPPED TO the devices and mixed into the
                  local datasets [1] (privacy + comm cost; the paper's foil).
  Hybrid-FL     — the server participates as just another (big) client [11].
  ServerM       — FedDU + server-side momentum only [25].
  DeviceM       — FedDU + device-side restart momentum only [75].
  FedDA         — two-sided momentum with COMMUNICATED buffers [32].
  FedDF         — ensemble distillation on server data [22]: after FedAvg,
                  the global model is trained toward the average of the
                  client models' logits on server data.
  FedKT         — one-shot-style knowledge transfer [4]: like FedDF but with
                  hard pseudo-labels voted by the client ensemble.
  IMC           — unstructured global magnitude pruning at the prune round,
                  rate from the eigen-gap criterion [62]; mask kept forever.
  PruneFL       — unstructured magnitude pruning, fixed rate, re-evaluated
                  periodically [33].
  HRank         — structured rank-based pruning with a FIXED global rate
                  (no layer adaptation, no non-IID weighting) [34].

Unstructured baselines keep dense shapes (mask only) — which is exactly why
the paper reports unchanged device FLOPs for them (Tables 6-9); structured
FedAP/HRank actually shrink the model.

The distillation/pruning factories below return legacy-signature callbacks
``fn(trainer, round_idx, params) -> new params | None``; schedule them with
``TrainPlan.with_callback(rounds, fn, eval_every=...)`` (see
repro.core.plan) — the old ``run(..., on_round_end=fn)`` API is gone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import (
    FedAPConfig,
    PruneSpec,
    fedap_prune,
    feature_map_ranks,
    select_filters,
    shrink_params,
)
from repro.core.rounds import FederatedTrainer, FLConfig


# ---------------------------------------------------------------------------
# Optimization baselines — pure FLConfig recipes
# ---------------------------------------------------------------------------

def fedavg_config(**kw) -> FLConfig:
    kw.setdefault("use_server_update", False)
    return FLConfig(**kw)


def feddu_config(**kw) -> FLConfig:
    kw.setdefault("use_server_update", True)
    return FLConfig(**kw)


def server_momentum_config(**kw) -> FLConfig:
    kw.setdefault("use_server_update", True)
    kw.setdefault("server_momentum", True)
    kw.setdefault("local_momentum", "none")
    return FLConfig(**kw)


def device_momentum_config(**kw) -> FLConfig:
    kw.setdefault("use_server_update", True)
    kw.setdefault("server_momentum", False)
    kw.setdefault("local_momentum", "restart")
    return FLConfig(**kw)


def fedda_config(**kw) -> FLConfig:
    kw.setdefault("use_server_update", True)
    kw.setdefault("server_momentum", True)
    kw.setdefault("local_momentum", "communicated")
    return FLConfig(**kw)


def fedprox_config(**kw) -> FLConfig:
    """FedProx: plain FedAvg plus a proximal pull toward the round-start
    global model in every local step (heterogeneity-robust baseline)."""
    kw.setdefault("use_server_update", False)
    kw.setdefault("algorithm", "fedprox")
    return FLConfig(**kw)


def feddyn_config(**kw) -> FLConfig:
    """FedDyn: per-client dynamic regularization — a gradient-correction
    term carried in the engine's client_state slot across rounds."""
    kw.setdefault("use_server_update", False)
    kw.setdefault("algorithm", "feddyn")
    return FLConfig(**kw)


# ---------------------------------------------------------------------------
# Data-placement baselines — transform the federated dataset
# ---------------------------------------------------------------------------

def apply_data_sharing(data, rng: np.random.Generator):
    """Data-sharing [1]: distribute the server data evenly to all clients
    and train with plain FedAvg (server keeps a copy for evaluation)."""
    from repro.data.pipeline import FederatedData

    n_clients = data.client_x.shape[0]
    per = data.server_x.shape[0] // n_clients
    if per == 0:
        return data
    perm = rng.permutation(data.server_x.shape[0])
    sx, sy = np.asarray(data.server_x)[perm], np.asarray(data.server_y)[perm]
    new_x = np.concatenate(
        [np.asarray(data.client_x), sx[: per * n_clients].reshape(n_clients, per, *sx.shape[1:])],
        axis=1)
    new_y = np.concatenate(
        [np.asarray(data.client_y), sy[: per * n_clients].reshape(n_clients, per)], axis=1)
    num_classes = data.client_dists.shape[1]
    dists = np.stack([np.bincount(y, minlength=num_classes) for y in new_y]).astype(np.float32)
    dists /= dists.sum(1, keepdims=True)
    return FederatedData(
        client_x=new_x, client_y=new_y, sizes=data.sizes + per,
        client_dists=dists, server_x=data.server_x, server_y=data.server_y,
        server_dist=data.server_dist, test_x=data.test_x, test_y=data.test_y)


def apply_hybrid_fl(data):
    """Hybrid-FL [11]: the server data becomes one extra ordinary client
    (truncated/padded to the common client size so the vmapped engine can
    treat it uniformly — the paper's point is that this under-uses n0)."""
    from repro.data.pipeline import FederatedData

    n_k = data.client_x.shape[1]
    sx, sy = np.asarray(data.server_x), np.asarray(data.server_y)
    reps = int(np.ceil(n_k / sx.shape[0]))
    sx = np.tile(sx, (reps,) + (1,) * (sx.ndim - 1))[:n_k]
    sy = np.tile(sy, reps)[:n_k]
    num_classes = data.client_dists.shape[1]
    sdist = np.bincount(sy, minlength=num_classes).astype(np.float32)
    sdist /= sdist.sum()
    return FederatedData(
        client_x=np.concatenate([np.asarray(data.client_x), sx[None]], axis=0),
        client_y=np.concatenate([np.asarray(data.client_y), sy[None]], axis=0),
        sizes=np.concatenate([data.sizes, [n_k]]),
        client_dists=np.concatenate([data.client_dists, sdist[None]], axis=0),
        server_x=data.server_x, server_y=data.server_y, server_dist=data.server_dist,
        test_x=data.test_x, test_y=data.test_y)


# ---------------------------------------------------------------------------
# Distillation baselines — post-aggregation server phase
# ---------------------------------------------------------------------------

def make_distillation_round_end(model, data, *, mode: str = "feddf",
                                steps: int = 20, batch: int = 64, lr: float = 0.01,
                                seed: int = 0):
    """FedDF [22] / FedKT [4] server phase as a per-round plan Callback.

    After each aggregation the global model is nudged toward the client
    ensemble's predictions on the server data.  The trainer stores the last
    round's client models?  No — to stay engine-agnostic (and because the
    ensemble teacher changes little between consecutive models), we use the
    pre-update global model as the teacher, which is the standard
    self-distillation reduction used when client models are unavailable.
    """
    rng = np.random.default_rng(seed)
    sx, sy = np.asarray(data.server_x), np.asarray(data.server_y)

    @jax.jit
    def distill_steps(params, teacher_params, xs):
        def one(p, x):
            t_logits = model.apply(teacher_params, x)
            if mode == "fedkt":
                targets = jnp.argmax(t_logits, -1)

                def loss(pp):
                    lg = model.apply(pp, x)
                    lp = jax.nn.log_softmax(lg)
                    return -jnp.mean(jnp.take_along_axis(lp, targets[:, None], 1))
            else:
                def loss(pp):
                    lg = model.apply(pp, x)
                    return jnp.mean(
                        jnp.sum(jax.nn.softmax(t_logits)
                                * (jax.nn.log_softmax(t_logits) - jax.nn.log_softmax(lg)),
                                axis=-1))
            g = jax.grad(loss)(p)
            return jax.tree.map(lambda pi, gi: (pi - lr * gi).astype(pi.dtype), p, g), None

        params, _ = jax.lax.scan(one, params, xs)
        return params

    def hook(trainer, t, params):
        idx = rng.integers(0, sx.shape[0], steps * batch)
        xs = jnp.asarray(sx[idx].reshape(steps, batch, *sx.shape[1:]))
        return distill_steps(params, params, xs)

    return hook


# ---------------------------------------------------------------------------
# Pruning baselines — plan Callback factories
# ---------------------------------------------------------------------------

def unstructured_magnitude_mask(params, rate: float):
    """Global magnitude mask at ``rate`` (IMC / PruneFL style)."""
    flat = jnp.concatenate([jnp.abs(x).reshape(-1).astype(jnp.float32)
                            for x in jax.tree.leaves(params)])
    k = int(np.clip(rate * flat.size, 0, flat.size - 1))
    thr = jnp.sort(flat)[k]
    return jax.tree.map(lambda x: (jnp.abs(x) >= thr).astype(x.dtype), params)


def make_unstructured_pruning_hook(*, rate: float, prune_round: int,
                                   refresh_every: int | None = None):
    """IMC (refresh_every=None) / PruneFL (periodic re-evaluation) hook.
    Masks are applied multiplicatively — shapes (and device FLOPs) do not
    change, matching the paper's Tables 6-9."""
    state = {"mask": None}

    def hook(trainer, t, params):
        # t is the number of COMPLETED rounds when the callback fires (the
        # first post-round hook sees t=1) — the executor's Eval/Callback
        # round bookkeeping agree
        redo = (t == prune_round) or (
            refresh_every and state["mask"] is not None
            and (t - prune_round) % refresh_every == 0 and t > prune_round)
        if redo:
            state["mask"] = unstructured_magnitude_mask(params, rate)
        if state["mask"] is not None:
            return jax.tree.map(lambda p, m: p * m, params, state["mask"])
        return None

    return hook


def make_hrank_pruning_hook(model, data, *, rate: float, prune_round: int,
                            probe: int = 64, align: int | None = None):
    """HRank [34]: structured, rank-based, FIXED rate for every layer —
    the paper's foil for FedAP's layer-adaptive rates."""

    def hook(trainer, t, params):
        if t != prune_round:   # t = completed rounds at the callback
            return None
        spec: PruneSpec = model.prune_spec(params)
        fmaps = model.feature_maps(params, jnp.asarray(data.server_x[:probe]))
        kept = {}
        for layer in spec.layers:
            scores = feature_map_ranks(fmaps[layer.feature_key or layer.name])
            kept[layer.name] = select_filters(scores, rate, align=align)
        new_params = shrink_params(params, spec, kept)
        trainer.model = model.with_pruned(kept)
        return new_params

    return hook
