"""FedAP — layer-adaptive structured pruning (paper Section 3.4, Algorithm 3).

Pipeline (executed ONCE, on the server, at a predefined round):

  1. Every participant k (server = 0) derives an *expected pruning rate*
     p*_k from the eigen-gap of a loss-curvature spectrum (the IMC /
     inertial-manifold criterion [62]): sort eigenvalues ascending and take
     the largest prefix m_k with  lambda_{m+1} - lambda_m > 4 * L_k, then
     p*_k = m_k / d_k.

     Hardware adaptation: the exact Hessian is not computable at any of the
     assigned scales, so the spectrum is the *empirical Fisher* spectrum
     obtained via the Gram trick — eigenvalues of (1/n) G G^T where G is the
     [n_probe, P] per-sample gradient matrix; G G^T is [n_probe, n_probe]
     and shares all nonzero eigenvalues with the Fisher (1/n) G^T G.

  2. Rates are aggregated with non-IID-degree weights (Formula 15):
         p* = sum_k [ (n_k / (D(P_k)+eps)) / sum_k' (...) ] * p*_k

  3. A global magnitude threshold V = |v_(floor(R * p*))| (the R*p*-th
     smallest |weight| over ALL prunable weights) converts p* into a
     per-layer rate p*_l = #{|w| < V in layer l} / q_l  (Alg. 3 lines 6-11).

  4. Within each layer, filters with the lowest HRank feature-map rank
     (computed on server data) are removed; we keep the top
     d_l - floor(p*_l * d_l) filters (lines 12-15).

Structured pruning is expressed model-agnostically through a
``PruneSpec``: each prunable layer names its weight tensor, the filter
axis, and every coupled tensor/axis that must shrink with it (bias, the
next layer's input axis, norm scales).  Models publish their own spec.

TPU note: kept-filter counts can optionally be rounded UP to a multiple of
128 (MXU lane width) so the shrunken matmuls stay hardware-aligned; this
only ever prunes *less* than p*_l, preserving the paper's p_l <= p*_l
inequality (Alg. 3 line 14).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Path = tuple


# ---------------------------------------------------------------------------
# Pytree path addressing — jax.tree_util key-paths, so PruneSpec works on ANY
# registered pytree (dicts, lists/tuples, namedtuples, registered dataclasses)
# ---------------------------------------------------------------------------

def _norm_key(entry) -> Any:
    """Normalize a jax.tree_util key entry to the plain key a PruneSpec
    path uses: dict key, sequence index, or attribute name."""
    jtu = jax.tree_util
    if isinstance(entry, jtu.DictKey):
        return entry.key
    if isinstance(entry, jtu.SequenceKey):
        return entry.idx
    if isinstance(entry, jtu.GetAttrKey):
        return entry.name
    if isinstance(entry, jtu.FlattenedIndexKey):
        return entry.key
    return entry


def get_path(tree: Any, path: Path):
    """The leaf at ``path``, resolved through tree_flatten_with_path."""
    path = tuple(path)
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if tuple(_norm_key(e) for e in kp) == path:
            return leaf
    raise KeyError(f"no leaf at path {path!r}")


def set_path(tree: Any, path: Path, value: Any):
    """Functional leaf replacement on any registered pytree."""
    path = tuple(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves, hit = [], False
    for kp, leaf in flat:
        if tuple(_norm_key(e) for e in kp) == path:
            leaves.append(value)
            hit = True
        else:
            leaves.append(leaf)
    if not hit:
        raise KeyError(f"no leaf at path {path!r}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Prune spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoupledParam:
    path: Path
    axis: int


@dataclasses.dataclass(frozen=True)
class PrunableLayer:
    """One structurally-prunable layer.

    weight:      the tensor holding the filters (conv kernel [kh,kw,cin,cout],
                 FFN up-proj [d_model, d_ff], ...).
    filter_axis: the output-filter axis of ``weight``.
    coupled:     tensors that must be sliced along the same filter dimension
                 (bias of this layer; NEXT layer's input axis; norms).
    feature_key: key under which the model reports this layer's feature maps.
    """

    name: str
    weight: Path
    filter_axis: int
    coupled: tuple[CoupledParam, ...] = ()
    feature_key: str | None = None


@dataclasses.dataclass(frozen=True)
class PruneSpec:
    layers: tuple[PrunableLayer, ...]


# ---------------------------------------------------------------------------
# Step 1 — expected pruning rate from curvature spectrum (IMC criterion)
# ---------------------------------------------------------------------------

def fisher_spectrum(
    per_sample_grad_fn: Callable[[Any, Any], Any],
    params: Any,
    probe_batch: Any,
    *,
    n_valid: jnp.ndarray | int | None = None,
) -> jnp.ndarray:
    """Empirical-Fisher eigenvalues via the Gram trick.

    ``per_sample_grad_fn(params, batch) -> pytree with leading axis n`` must
    return per-sample gradients (e.g. ``jax.vmap(jax.grad(loss_one))``).
    Returns eigenvalues sorted ASCENDING (paper convention).

    ``n_valid`` supports PADDED probe batches (the sharded ragged-probe
    path): the Gram normalizer becomes ``n_valid`` instead of the row
    count, and — provided ``per_sample_grad_fn`` zeroes the padded rows —
    the padded Gram's spectrum is exactly the valid-row spectrum plus
    ``n - n_valid`` zero eigenvalues (zero rows/columns), which
    :func:`expected_rate_from_spectrum` masks out via its ``valid=``
    argument.
    """
    g = per_sample_grad_fn(params, probe_batch)
    flat = jnp.concatenate(
        [x.reshape(x.shape[0], -1).astype(jnp.float32) for x in jax.tree.leaves(g)], axis=1
    )
    n = flat.shape[0] if n_valid is None else n_valid
    gram = flat @ flat.T / n                      # [n, n], same nonzero spectrum
    eigs = jnp.linalg.eigvalsh(gram)              # ascending
    return jnp.clip(eigs, 0.0, None)


def lipschitz_estimate(
    grad_fn: Callable[[Any, Any], Any],
    params_a: Any,
    params_b: Any,
    batch: Any,
) -> jnp.ndarray:
    """L_k ~= ||grad(a) - grad(b)|| / ||a - b||  — finite-difference estimate
    of the Lipschitz constant of the base function B_k (Section 3.4)."""
    ga, gb = grad_fn(params_a, batch), grad_fn(params_b, batch)
    num = jnp.sqrt(sum(jnp.sum(jnp.square(x - y)) for x, y in
                       zip(jax.tree.leaves(ga), jax.tree.leaves(gb))))
    den = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32) - y.astype(jnp.float32)))
                       for x, y in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b))))
    return num / jnp.clip(den, 1e-12, None)


def expected_rate_from_spectrum(eigs: jnp.ndarray, lipschitz: jnp.ndarray,
                                max_rate: float = 0.9, *,
                                valid: jnp.ndarray | int | None = None
                                ) -> jnp.ndarray:
    """p*_k = m_k / d_k where m_k is the FIRST index (ascending order) with
    eig[m_k+1] - eig[m_k] > 4 L — the paper's Section 3.4 criterion: the
    modes below the first spectral gap form the prunable complement of the
    inertial manifold [62].

    ``valid`` restricts the search to a PADDED spectrum's valid tail (the
    sharded ragged-probe path): after clipping at 0, the ascending padded
    spectrum is value-for-value ``[0]*(len(eigs)-valid) + sorted(valid
    spectrum)``, so the eigen-gap search over its last ``valid`` entries —
    with indices re-based and the pad|valid boundary gap excluded — is
    exactly the search the host path runs on the unpadded spectrum.

    If no gap clears the bar, p*_k = 0 (prune nothing — safe default).
    """
    d_pad = eigs.shape[0]
    d = jnp.asarray(d_pad if valid is None else valid, jnp.int32)
    gaps = eigs[1:] - eigs[:-1]                      # [d_pad-1]
    # index of each gap within the valid tail; <= 0 means padding or the
    # pad|valid boundary, which the host path's spectrum has no gap for
    idx = jnp.arange(1, d_pad, dtype=jnp.int32) - (jnp.int32(d_pad) - d)
    ok = (gaps > 4.0 * lipschitz) & (idx >= 1)
    m = jnp.min(jnp.where(ok, idx, d))
    m = jnp.where(m >= d, jnp.int32(0), m)           # no qualifying gap
    return jnp.clip(m.astype(jnp.float32) / d.astype(jnp.float32),
                    0.0, max_rate)


# ---------------------------------------------------------------------------
# Step 2 — Formula 15 aggregation
# ---------------------------------------------------------------------------

def aggregate_rates(
    rates: jnp.ndarray,       # [K+1] p*_k, index 0 = server
    sizes: jnp.ndarray,       # [K+1] n_k
    niid: jnp.ndarray,        # [K+1] D(P_k)
    eps: float = 1e-8,
) -> jnp.ndarray:
    w = jnp.asarray(sizes, jnp.float32) / (jnp.asarray(niid, jnp.float32) + eps)
    w = w / jnp.sum(w)
    return jnp.sum(w * jnp.asarray(rates, jnp.float32))


# ---------------------------------------------------------------------------
# Step 3 — global magnitude threshold -> per-layer rates
# ---------------------------------------------------------------------------

def global_threshold(params: Any, spec: PruneSpec, p_star: jnp.ndarray) -> jnp.ndarray:
    """V = |v_(floor(R * p*))| over all prunable weights (Alg. 3 lines 6-7)."""
    vals = jnp.concatenate(
        [jnp.abs(get_path(params, l.weight).astype(jnp.float32)).reshape(-1)
         for l in spec.layers]
    )
    r = vals.shape[0]
    k = jnp.clip((jnp.asarray(p_star, jnp.float32) * r).astype(jnp.int32), 0, r - 1)
    return jnp.sort(vals)[k]


def per_layer_rates(params: Any, spec: PruneSpec, threshold: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """p*_l = (#weights with |w| < V) / q_l per layer (Alg. 3 lines 9-11)."""
    out = {}
    for l in spec.layers:
        w = jnp.abs(get_path(params, l.weight).astype(jnp.float32))
        out[l.name] = jnp.mean((w < threshold).astype(jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Step 4 — HRank filter selection
# ---------------------------------------------------------------------------

def feature_map_ranks(fmap: jnp.ndarray) -> jnp.ndarray:
    """HRank score per filter.

    fmap: [B, ..., d_l] activations with filters LAST.
      * conv maps  [B, H, W, d]: per-sample matrix rank of each [H, W] map,
        averaged over the batch (the HRank criterion).
      * 1-D features [B, d] (FFN neurons): rank degenerates; we use the
        batch singular-value mass |a| per neuron (activation energy), the
        shape-generalized analogue (see DESIGN.md Section 3).
    Returns [d_l] float scores — HIGHER = keep.
    """
    fmap = fmap.astype(jnp.float32)
    if fmap.ndim >= 4:
        return jnp.mean(feature_map_scores(fmap), axis=0)
    # [B, d] (or flatten middle dims): activation energy per neuron.
    flat = fmap.reshape(fmap.shape[0], -1, fmap.shape[-1])
    return jnp.mean(jnp.abs(flat), axis=(0, 1))


def feature_map_scores(fmap: jnp.ndarray) -> jnp.ndarray:
    """PER-SAMPLE HRank scores — [B, d_l], each row depending only on that
    sample's activations, so a batch-sharded forward can sum them and
    correct padded rows out exactly (the mesh path of
    ``fedap._finish_decision``).  ``feature_map_ranks`` is the batch mean
    of these scores: conv ranks per sample are integer-valued (<=
    min(H, W*)), so float32 sums over any probe batch are exact.
    """
    fmap = fmap.astype(jnp.float32)
    if fmap.ndim >= 4:
        b = fmap.shape[0]
        d = fmap.shape[-1]
        maps = jnp.moveaxis(fmap, -1, 1).reshape(b, d, fmap.shape[1], -1)  # [B,d,H,W*]
        s = jnp.linalg.svd(maps, compute_uv=False)                          # [B,d,min]
        tol = jnp.max(s, axis=-1, keepdims=True) * max(maps.shape[-2:]) * 1e-6
        return jnp.sum(s > tol, axis=-1).astype(jnp.float32)                # [B,d]
    flat = fmap.reshape(fmap.shape[0], -1, fmap.shape[-1])
    return jnp.mean(jnp.abs(flat), axis=1)


def select_filters(
    scores: jnp.ndarray,
    rate: jnp.ndarray | float,
    *,
    align: int | None = None,
    min_keep: int = 1,
) -> np.ndarray:
    """Keep the d_l - floor(rate * d_l) filters with the HIGHEST rank
    (Alg. 3 lines 13-14).  ``align`` rounds the kept count UP to a multiple
    (TPU lane alignment), so the realized rate p_l <= p*_l.

    Returns a sorted numpy index array (static — drives re-materialization).
    """
    scores = np.asarray(scores)
    d = scores.shape[0]
    keep = d - int(np.floor(float(rate) * d))
    keep = max(keep, min_keep)
    if align is not None and d >= align:
        keep = min(d, int(np.ceil(keep / align) * align))
    order = np.argsort(scores)[::-1]  # descending: highest rank first
    return np.sort(order[:keep])


# ---------------------------------------------------------------------------
# Structural shrink + masked (jit-static) variants
# ---------------------------------------------------------------------------

def shrink_params(params: Any, spec: PruneSpec, kept: Mapping[str, np.ndarray]) -> Any:
    """Re-materialize a genuinely smaller model: slice each pruned layer's
    filter axis and every coupled tensor (Alg. 3 line 15)."""
    for l in spec.layers:
        if l.name not in kept:
            continue
        idx = jnp.asarray(kept[l.name])
        w = get_path(params, l.weight)
        params = set_path(params, l.weight, jnp.take(w, idx, axis=l.filter_axis))
        for c in l.coupled:
            t = get_path(params, c.path)
            params = set_path(params, c.path, jnp.take(t, idx, axis=c.axis))
    return params


def filter_masks(params: Any, spec: PruneSpec, kept: Mapping[str, np.ndarray]) -> dict[str, jnp.ndarray]:
    """Binary keep-mask per layer ([d_l] of 0/1) for the static-shape masked
    execution mode (used inside long-lived jitted training programs where we
    cannot change shapes; the Pallas ``pruned_matmul`` kernel consumes the
    compacted index form instead)."""
    masks = {}
    for l in spec.layers:
        d = get_path(params, l.weight).shape[l.filter_axis]
        m = np.zeros((d,), np.float32)
        # `kept` is a host-resident index mapping (never traced), so this
        # numpy work constant-folds at trace time.
        idx = np.asarray(kept.get(l.name, np.arange(d)))  # lint: host-sync-ok
        m[idx] = 1.0
        masks[l.name] = jnp.asarray(m)
    return masks


def param_masks(params: Any, spec: PruneSpec, kept: Mapping[str, np.ndarray]) -> Any:
    """Param-structured multiplicative keep-masks — the static-shape dual of
    :func:`shrink_params`.

    Returns a pytree with the SAME structure/shapes as ``params`` (f32, 0/1),
    with zeros on exactly the coordinates ``shrink_params`` would slice away:
    the weight's filter axis AND every coupled tensor's coupled axis.

    Because the zeroed set is closed under the layer coupling (the pruned
    filter's weights, its bias, and the next layer's matching input slices
    all vanish), a masked model's forward activations and its gradients on
    the KEPT coordinates are exactly those of the re-materialized model for
    normalization-free architectures — and the gradients on masked
    coordinates are exactly zero, so masked training is self-sustaining
    inside a compiled scan.  (GroupNorm/LayerNorm models normalize over the
    zeroed channels and therefore only approximate the shrunk model.)
    """
    masks = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), params)

    def mask_axis(m: jnp.ndarray, axis: int, idx: np.ndarray) -> jnp.ndarray:
        d = m.shape[axis]
        keep = np.zeros((d,), np.float32)
        keep[idx] = 1.0
        shape = [1] * m.ndim
        shape[axis] = d
        return m * jnp.asarray(keep).reshape(shape)

    for l in spec.layers:
        if l.name not in kept:
            continue
        idx = np.asarray(kept[l.name])
        masks = set_path(masks, l.weight,
                         mask_axis(get_path(masks, l.weight), l.filter_axis, idx))
        for c in l.coupled:
            masks = set_path(masks, c.path,
                             mask_axis(get_path(masks, c.path), c.axis, idx))
    return masks


def model_flops_fraction(params_before: Any, params_after: Any) -> float:
    """Crude FLOP-reduction proxy: ratio of parameter counts (matmul FLOPs
    scale linearly in each pruned dimension)."""
    a = sum(int(x.size) for x in jax.tree.leaves(params_after))
    b = sum(int(x.size) for x in jax.tree.leaves(params_before))
    return a / b


# ---------------------------------------------------------------------------
# End-to-end FedAP driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedAPConfig:
    prune_round: int = 30          # paper: pruning happens once, at round 30
    eps: float = 1e-8              # Formula 15
    align: int | None = None       # 128 on TPU; None on CPU repro
    max_rate: float = 0.9
    min_rate: float = 0.0          # compression-budget floor on p* (0 = off;
                                   # the eigen-gap rule alone decides, which
                                   # on easy tasks can be "prune nothing")
    probe_size: int = 32
    participants: int = 8          # devices (beyond the server) probed for p*_k

    def __post_init__(self):
        # Mirror FLConfig.__post_init__: bad switches fail HERE, at
        # construction, with a clear message — not as an opaque numpy
        # error deep inside fedap_decision's probe draw.
        if not 0.0 <= self.min_rate <= self.max_rate:
            raise ValueError(f"need 0 <= min_rate <= max_rate, got "
                             f"min_rate={self.min_rate} max_rate={self.max_rate}")
        if self.participants < 0:
            raise ValueError(
                f"participants must be >= 0, got {self.participants}")
        if self.probe_size < 1:
            raise ValueError(f"probe_size must be >= 1, got {self.probe_size}")
        if self.prune_round < 1:
            raise ValueError(
                f"prune_round must be >= 1, got {self.prune_round}")


def fedap_rates(
    *,
    spectra: Sequence[jnp.ndarray],
    lipschitzes: Sequence[jnp.ndarray],
    sizes: jnp.ndarray,
    niid: jnp.ndarray,
    params: Any,
    spec: PruneSpec,
    cfg: FedAPConfig,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Steps 1-3: per-participant rates -> Formula 15 -> per-layer rates."""
    rates = jnp.stack([
        expected_rate_from_spectrum(e, l, cfg.max_rate)
        for e, l in zip(spectra, lipschitzes)
    ])
    p_star = aggregate_rates(rates, sizes, niid, cfg.eps)
    thr = global_threshold(params, spec, p_star)
    return p_star, per_layer_rates(params, spec, thr)


def fedap_prune(
    params: Any,
    spec: PruneSpec,
    layer_rates: Mapping[str, jnp.ndarray],
    feature_maps: Mapping[str, jnp.ndarray],
    cfg: FedAPConfig,
) -> tuple[Any, dict[str, np.ndarray]]:
    """Step 4 + shrink.  Returns (pruned params, kept-index map)."""
    kept = {}
    for l in spec.layers:
        fkey = l.feature_key or l.name
        if fkey not in feature_maps:
            continue
        scores = feature_map_ranks(feature_maps[fkey])
        kept[l.name] = select_filters(scores, layer_rates[l.name], align=cfg.align)
    return shrink_params(params, spec, kept), kept
