"""FedAP for the transformer zoo — structured pruning of scanned stacks.

The paper prunes conv filters with HRank feature-map ranks.  For the
assigned LLM architectures the "filter-like" axes are:

  * FFN hidden units (rows of W_up / W_gate, cols of W_down) — dense archs;
  * whole experts — MoE archs (router mass = the rank analogue);
  * mLSTM projection channels — xlstm.

Two adaptations make this work on TPU with scan-over-layers stacks:

  1. UNIFORM KEPT COUNT across the stack: layer params are stacked
     [L, ...], so every layer must keep the same NUMBER of units (indices
     may differ per layer — a vectorized take_along_axis gather).  The
     count comes from the FedAP per-layer rates via the max-preserved rule
     (p_l <= p*_l, Alg. 3 line 14), then rounds UP to the 128-lane
     boundary (align).

  2. WEIGHT-NORM x WEIGHT-NORM scores (||wi_col|| * ||wo_row||) stand in
     for feature-map ranks inside the scan: activations of interior layers
     are not observable without unrolling, and the product-norm is the
     standard magnitude surrogate with the same keep-the-energetic-units
     semantics.  (On the CNN repro path the true HRank criterion is used —
     see repro.core.pruning.)

Pruning re-materializes a smaller model + config; the framework re-jits
once (the paper prunes once, at round 30).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _aligned_keep(d: int, rate: float, align: int | None,
                  *, layer: str = "layer") -> int:
    """Uniform kept count for one scanned stack: ``d - floor(rate * d)``,
    rounded UP to the alignment boundary (realized rate <= requested rate).

    Construction-time validation (the FedAPConfig.__post_init__ pattern):
    a rate or alignment that would keep 0 units or overflow the layer
    width fails HERE, naming the rate, the alignment and the layer —
    not as an opaque ``take_along_axis`` shape error downstream.
    """
    rate = float(rate)
    if not 0.0 <= rate < 1.0:
        raise ValueError(
            f"prune rate for {layer} must be in [0, 1), got {rate} "
            f"(rate >= 1 would keep 0 of the {d} units)")
    keep = d - int(np.floor(rate * d))
    if align and d >= align:
        aligned = int(np.ceil(keep / align) * align)
        if aligned > d:
            raise ValueError(
                f"{layer}: the {align}-lane-aligned kept count {aligned} "
                f"exceeds the layer width {d} (width is not a multiple of "
                f"the alignment; rate={rate} keeps {keep} unaligned units)")
        keep = aligned
    if not 1 <= keep <= d:   # unreachable given the guards above
        raise ValueError(
            f"{layer}: kept count {keep} outside [1, {d}] "
            f"(rate={rate}, align={align})")
    return keep


def ffn_unit_scores(layers: Any, act: str) -> jnp.ndarray:
    """[L, d_ff] product-norm scores for stacked dense FFN layers."""
    mlp = layers["mlp"]
    s_in = jnp.linalg.norm(mlp["wi"].astype(jnp.float32), axis=1)      # [L, ff]
    if "wg" in mlp:
        s_in = s_in * jnp.linalg.norm(mlp["wg"].astype(jnp.float32), axis=1)
    s_out = jnp.linalg.norm(mlp["wo"].astype(jnp.float32), axis=2)     # [L, ff]
    return s_in * s_out


def expert_scores(layers: Any) -> jnp.ndarray:
    """[L, E] scores for stacked MoE layers: router column norm (expected
    routing mass under random inputs) x expert weight norms."""
    moe = layers["moe"]
    r = jnp.linalg.norm(moe["router"].astype(jnp.float32), axis=1)     # [L, E]
    wi = jnp.linalg.norm(moe["wi"].astype(jnp.float32), axis=(2, 3))   # [L, E]
    wo = jnp.linalg.norm(moe["wo"].astype(jnp.float32), axis=(2, 3))
    return r * wi * wo


def ffn_kept_indices(params: Any, cfg: ModelConfig, rate: float,
                     *, align: int | None = 128) -> np.ndarray:
    """[L, keep] kept-unit index rows (sorted per layer) for the FFN hidden
    dim of a scanned dense/vlm/hybrid stack — the FedAP decision in index
    form, shared by the shrink (:func:`shrink_ffn_at`) and the static-shape
    mask application (:func:`ffn_param_masks` / :func:`ffn_filter_masks`).

    Host-resident numpy (the decision is static — it drives either a
    re-materialization or constant-folded masks, never a traced value).
    """
    if cfg.family not in ("dense", "vlm", "hybrid"):
        raise ValueError(f"prune_lm_ffn does not apply to family {cfg.family}")
    scores = ffn_unit_scores(params["layers"], cfg.act)                # [L, ff]
    d_ff = scores.shape[1]
    keep = _aligned_keep(d_ff, rate, align,
                         layer=f"mlp stack (d_ff={d_ff})")
    idx = jnp.argsort(scores, axis=1)[:, ::-1][:, :keep]               # [L, keep]
    return np.asarray(jnp.sort(idx, axis=1))


def shrink_ffn_at(params: Any, idx: Any) -> Any:
    """Gather the kept FFN units at the given [L, keep] index rows — wi/wg
    columns and wo rows.  Applies to the param tree AND any tree sharing
    its structure (momentum buffers, FedDyn corrections)."""
    idx = jnp.asarray(idx)
    layers = params["layers"]
    mlp = dict(layers["mlp"])
    mlp["wi"] = jnp.take_along_axis(layers["mlp"]["wi"], idx[:, None, :], axis=2)
    if "wg" in mlp:
        mlp["wg"] = jnp.take_along_axis(layers["mlp"]["wg"], idx[:, None, :], axis=2)
    mlp["wo"] = jnp.take_along_axis(layers["mlp"]["wo"], idx[:, :, None], axis=1)
    new_layers = dict(layers)
    new_layers["mlp"] = mlp
    new_params = dict(params)
    new_params["layers"] = new_layers
    return new_params


def _unit_masks(params: Any, kept: Any) -> np.ndarray | None:
    """[L, d_ff] 0/1 kept-unit masks from a ``{"mlp": [L, keep]}`` kept
    map; None when no decision is in force (all-ones)."""
    idx = kept.get("mlp") if kept else None
    if idx is None:
        return None
    wi = params["layers"]["mlp"]["wi"]
    m = np.zeros((wi.shape[0], wi.shape[2]), np.float32)
    np.put_along_axis(m, np.asarray(idx), 1.0, axis=1)
    return m


def ffn_filter_masks(params: Any, kept: Any) -> dict:
    """``{"mlp": [L, d_ff] 0/1}`` filter keep-masks for kernel-mode masked
    compute — one mask row per scanned layer, riding into the layer scan
    alongside that layer's params."""
    m = _unit_masks(params, kept)
    if m is None:
        wi = params["layers"]["mlp"]["wi"]
        m = np.ones((wi.shape[0], wi.shape[2]), np.float32)
    return {"mlp": jnp.asarray(m)}


def ffn_param_masks(params: Any, kept: Any) -> Any:
    """Param-structured 0/1 masks with zeros on exactly the coordinates
    :func:`shrink_ffn_at` would slice away (wi/wg columns AND the coupled
    wo rows) — the scanned-stack analogue of ``pruning.param_masks``.  The
    zeroed set is closed under the FFN coupling, so the masked forward
    equals the shrunk forward exactly: a zero pre-activation unit
    contributes silu(0) = gelu(0) = 0 through wo."""
    masks = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), params)
    m = _unit_masks(params, kept)
    if m is None:
        return masks
    unit = jnp.asarray(m)                                              # [L, ff]
    mlp = dict(masks["layers"]["mlp"])
    mlp["wi"] = mlp["wi"] * unit[:, None, :]
    if "wg" in mlp:
        mlp["wg"] = mlp["wg"] * unit[:, None, :]
    mlp["wo"] = mlp["wo"] * unit[:, :, None]
    new_layers = dict(masks["layers"])
    new_layers["mlp"] = mlp
    masks = dict(masks)
    masks["layers"] = new_layers
    return masks


def prune_lm_ffn(params: Any, cfg: ModelConfig, rate: float,
                 *, align: int | None = 128) -> tuple[Any, ModelConfig, dict]:
    """Structurally shrink the FFN hidden dim of a scanned dense/vlm/hybrid
    stack.  Returns (new params, new config, info)."""
    idx = ffn_kept_indices(params, cfg, rate, align=align)
    d_ff = int(params["layers"]["mlp"]["wi"].shape[2])
    keep = int(idx.shape[1])
    new_params = shrink_ffn_at(params, idx)
    new_cfg = dataclasses.replace(cfg, d_ff=keep)
    return new_params, new_cfg, {"kept": keep, "of": d_ff,
                                 "realized_rate": 1.0 - keep / d_ff}


def prune_lm_experts(params: Any, cfg: ModelConfig, rate: float,
                     *, align: int | None = None,
                     min_keep: int | None = None) -> tuple[Any, ModelConfig, dict]:
    """Remove whole experts from a scanned MoE stack (expert-parallel-aware:
    keep counts stay divisible by the TP axis when align is set)."""
    if not cfg.moe:
        raise ValueError("not a MoE config")
    layers = params["layers"]
    scores = expert_scores(layers)                                     # [L, E]
    e = scores.shape[1]
    keep = _aligned_keep(e, rate, align, layer=f"moe expert stack (E={e})")
    if min_keep:
        keep = max(keep, min_keep)
    keep = min(max(keep, cfg.moe.top_k), e)
    idx = jnp.sort(jnp.argsort(scores, axis=1)[:, ::-1][:, :keep], axis=1)

    moe = dict(layers["moe"])
    moe["router"] = jnp.take_along_axis(layers["moe"]["router"], idx[:, None, :], axis=2)
    for name, ax in [("wi", 1), ("wg", 1), ("wo", 1)]:
        shaped = idx.reshape(idx.shape[0], keep, 1, 1)
        moe[name] = jnp.take_along_axis(layers["moe"][name], shaped, axis=ax)
    new_layers = dict(layers)
    new_layers["moe"] = moe
    new_params = dict(params)
    new_params["layers"] = new_layers
    new_cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=keep))
    return new_params, new_cfg, {"kept": keep, "of": e,
                                 "realized_rate": 1.0 - keep / e}


def fedap_lm(params: Any, cfg: ModelConfig, p_star: float,
             *, align: int | None = 128) -> tuple[Any, ModelConfig, dict]:
    """FedAP entry point for the LLM zoo: dispatch per family."""
    if cfg.moe:
        return prune_lm_experts(params, cfg, p_star, align=None,
                                min_keep=max(8, cfg.moe.top_k * 4))
    return prune_lm_ffn(params, cfg, p_star, align=align)
