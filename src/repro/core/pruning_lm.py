"""FedAP for the transformer zoo — structured pruning of scanned stacks.

The paper prunes conv filters with HRank feature-map ranks.  For the
assigned LLM architectures the "filter-like" axes are:

  * FFN hidden units (rows of W_up / W_gate, cols of W_down) — dense archs;
  * whole experts — MoE archs (router mass = the rank analogue);
  * mLSTM projection channels — xlstm.

Two adaptations make this work on TPU with scan-over-layers stacks:

  1. UNIFORM KEPT COUNT across the stack: layer params are stacked
     [L, ...], so every layer must keep the same NUMBER of units (indices
     may differ per layer — a vectorized take_along_axis gather).  The
     count comes from the FedAP per-layer rates via the max-preserved rule
     (p_l <= p*_l, Alg. 3 line 14), then rounds UP to the 128-lane
     boundary (align).

  2. WEIGHT-NORM x WEIGHT-NORM scores (||wi_col|| * ||wo_row||) stand in
     for feature-map ranks inside the scan: activations of interior layers
     are not observable without unrolling, and the product-norm is the
     standard magnitude surrogate with the same keep-the-energetic-units
     semantics.  (On the CNN repro path the true HRank criterion is used —
     see repro.core.pruning.)

Pruning re-materializes a smaller model + config; the framework re-jits
once (the paper prunes once, at round 30).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _aligned_keep(d: int, rate: float, align: int | None) -> int:
    keep = d - int(np.floor(float(rate) * d))
    keep = max(keep, 1)
    if align and d >= align:
        keep = min(d, int(np.ceil(keep / align) * align))
    return keep


def ffn_unit_scores(layers: Any, act: str) -> jnp.ndarray:
    """[L, d_ff] product-norm scores for stacked dense FFN layers."""
    mlp = layers["mlp"]
    s_in = jnp.linalg.norm(mlp["wi"].astype(jnp.float32), axis=1)      # [L, ff]
    if "wg" in mlp:
        s_in = s_in * jnp.linalg.norm(mlp["wg"].astype(jnp.float32), axis=1)
    s_out = jnp.linalg.norm(mlp["wo"].astype(jnp.float32), axis=2)     # [L, ff]
    return s_in * s_out


def expert_scores(layers: Any) -> jnp.ndarray:
    """[L, E] scores for stacked MoE layers: router column norm (expected
    routing mass under random inputs) x expert weight norms."""
    moe = layers["moe"]
    r = jnp.linalg.norm(moe["router"].astype(jnp.float32), axis=1)     # [L, E]
    wi = jnp.linalg.norm(moe["wi"].astype(jnp.float32), axis=(2, 3))   # [L, E]
    wo = jnp.linalg.norm(moe["wo"].astype(jnp.float32), axis=(2, 3))
    return r * wi * wo


def prune_lm_ffn(params: Any, cfg: ModelConfig, rate: float,
                 *, align: int | None = 128) -> tuple[Any, ModelConfig, dict]:
    """Structurally shrink the FFN hidden dim of a scanned dense/vlm/hybrid
    stack.  Returns (new params, new config, info)."""
    if cfg.family not in ("dense", "vlm", "hybrid"):
        raise ValueError(f"prune_lm_ffn does not apply to family {cfg.family}")
    layers = params["layers"]
    scores = ffn_unit_scores(layers, cfg.act)                          # [L, ff]
    d_ff = scores.shape[1]
    keep = _aligned_keep(d_ff, rate, align)
    idx = jnp.argsort(scores, axis=1)[:, ::-1][:, :keep]               # [L, keep]
    idx = jnp.sort(idx, axis=1)

    mlp = dict(layers["mlp"])
    mlp["wi"] = jnp.take_along_axis(layers["mlp"]["wi"], idx[:, None, :], axis=2)
    if "wg" in mlp:
        mlp["wg"] = jnp.take_along_axis(layers["mlp"]["wg"], idx[:, None, :], axis=2)
    mlp["wo"] = jnp.take_along_axis(layers["mlp"]["wo"], idx[:, :, None], axis=1)
    new_layers = dict(layers)
    new_layers["mlp"] = mlp
    new_params = dict(params)
    new_params["layers"] = new_layers
    new_cfg = dataclasses.replace(cfg, d_ff=keep)
    return new_params, new_cfg, {"kept": keep, "of": d_ff,
                                 "realized_rate": 1.0 - keep / d_ff}


def prune_lm_experts(params: Any, cfg: ModelConfig, rate: float,
                     *, align: int | None = None,
                     min_keep: int | None = None) -> tuple[Any, ModelConfig, dict]:
    """Remove whole experts from a scanned MoE stack (expert-parallel-aware:
    keep counts stay divisible by the TP axis when align is set)."""
    if not cfg.moe:
        raise ValueError("not a MoE config")
    layers = params["layers"]
    scores = expert_scores(layers)                                     # [L, E]
    e = scores.shape[1]
    keep = _aligned_keep(e, rate, align)
    if min_keep:
        keep = max(keep, min_keep)
    keep = min(max(keep, cfg.moe.top_k), e)
    idx = jnp.sort(jnp.argsort(scores, axis=1)[:, ::-1][:, :keep], axis=1)

    moe = dict(layers["moe"])
    moe["router"] = jnp.take_along_axis(layers["moe"]["router"], idx[:, None, :], axis=2)
    for name, ax in [("wi", 1), ("wg", 1), ("wo", 1)]:
        shaped = idx.reshape(idx.shape[0], keep, 1, 1)
        moe[name] = jnp.take_along_axis(layers["moe"][name], shaped, axis=ax)
    new_layers = dict(layers)
    new_layers["moe"] = moe
    new_params = dict(params)
    new_params["layers"] = new_layers
    new_cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=keep))
    return new_params, new_cfg, {"kept": keep, "of": e,
                                 "realized_rate": 1.0 - keep / e}


def fedap_lm(params: Any, cfg: ModelConfig, p_star: float,
             *, align: int | None = 128) -> tuple[Any, ModelConfig, dict]:
    """FedAP entry point for the LLM zoo: dispatch per family."""
    if cfg.moe:
        return prune_lm_experts(params, cfg, p_star, align=None,
                                min_keep=max(8, cfg.moe.top_k * 4))
    return prune_lm_ffn(params, cfg, p_star, align=align)
