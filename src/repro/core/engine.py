"""The unified federated round engine — ONE implementation of the paper's
round (steps 1-5 of Section 3.1), shared by every execution path.

``round_core`` is a pure function of (config, model fns, state, batch) and is
safe under ``jit``, ``lax.scan``, ``vmap`` and ``shard_map``:

  * the simulation driver (`repro.core.rounds.FederatedTrainer`) scans it
    over rounds, with client selection and batch sampling done ON DEVICE
    through `jax.random` keys threaded in the scan carry — no host sync;
  * the pod-scale SPMD path (`repro.launch.steps.make_fl_train_step`) wraps
    it once per mesh program and shard_maps it via `sharding/fl_specs.py`.

Model access is abstracted to two callables over an opaque batch pytree:

  grad_fn(params, batch)          -> grads            (local/server SGD)
  loss_and_acc_fn(params, batch)  -> (loss, acc)      (Formula-7 acc gate)

The Formula-7 accuracy gate is taken from the FIRST server step's own
forward (``value_and_grad`` with aux) rather than a separate evaluation
pass over the full server set — one server-batch forward saved per round
(§Perf iteration B2).  The pure-NumPy oracle in `repro.core.ref_engine`
implements the same semantics naively and is the differential-test target.

Nothing here is sharding-aware by construction: under the MeshBackend the
client dim of ``batch["client"]`` AND the per-step batch dim of
``batch["server"]`` arrive sharding-constrained
(`sharding.fl_specs.fl_sim_batch_specs`), so the local-epoch vmap, the
FedAvg einsum and every one of the (5a) server-SGD steps partition over
the mesh with GSPMD-inserted collectives — the scan below compiles to
per-shard partial gradients + one all-reduce per step, with this source
unchanged (locked against the f64 oracle, first-step acc gate included).

Round state is a dict ``{"params", "server_m", ["global_m"], ["masks"],
["client_state"], "round"}``; ``global_m`` is present only for
``local_momentum == "communicated"`` (FedDA), where the globally-aggregated
momentum buffer is broadcast back to the devices (2x communication — the
baseline FedDUM's restart removes).

``client_state`` (present iff ``cfg.algorithm != "fedavg"``) is the
per-client persistent slot of the heterogeneity-robust client algorithms —
the carry structure is keyed by ``cfg.algorithm`` and FIXED from round 0,
so prune events and chunk caching never re-trace:

  "fedprox"  {"per_client": {}, "shared": {}} — FedProx is stateless (the
             proximal pull ``mu * (theta - theta_global)`` needs only the
             broadcast round-start params), but the slot exists so the
             plumbing (sharding specs, mask scrub, shrink reset) is
             uniform across algorithms;
  "feddyn"   {"per_client": {"h": [N, ...] per param},
              "shared":     {"h": param tree}} — the ALPHA-SCALED FedDyn
             gradient-correction state.  We store h'_k = alpha * h_k (and
             the server average likewise), so the local gradient is
             ``g + alpha (theta - theta_global) - h'_k``, the update is
             ``h'_k <- h'_k - alpha (theta_k^end - theta_global)`` and the
             server correction divides back: ``w_half - h'/alpha`` (a
             static python branch — skipped entirely at alpha == 0, where
             h' is identically zero and the round is bit-exact FedAvg).

The FedAvg reduction supports a straggler/dropout axis: when the batch
carries ``"active"`` ([C] 0/1), dropped clients contribute ZERO weight and
the aggregation runs in DELTA form around the broadcast point
(``base + sum_k w_k (theta_k - base)``) so an all-dropped round is exactly
a no-op; dropped clients' FedDyn state is left untouched (their correction
term is multiplied by ``active``).  Without ``"active"`` the legacy direct
einsum is used, bit-identical to the pre-dropout engine.

``masks`` (present iff ``cfg.use_masks``) is a param-structured 0/1 pytree
that rides in the scan carry: every round the engine multiplies params,
gradients, and momentum buffers by it, so FedAP's static-shape mask mode
(``repro.core.plan.Prune(mode="mask")``) prunes INSIDE a live compiled
scan — no shape change, no re-jit.  With all-ones masks the round is
bit-for-bit the unmasked round, so the masked engine can be compiled once
up front and the prune event only swaps the carry contents.

``cfg.masked_compute`` selects HOW the masked round computes:

  "params"  (default) the mask is applied to the parameter tree only —
            every matmul still runs at full density (correct, but none of
            FedAP's FLOP savings are realized during training);
  "kernel"  filter-level keep-masks (``pruning.filter_masks``) ride in the
            carry as ``state["filter_masks"]`` alongside the param masks,
            and the model fns are called as ``grad_fn(params, batch,
            filter_masks)`` / ``loss_and_acc_fn(params, batch,
            filter_masks)`` — the model routes masked dense layers through
            the differentiable Pallas ``masked_matmul`` kernel (custom
            VJP), so pruned blocks are skipped on the MXU in BOTH the
            forward and the backward pass.  The param masks still multiply
            params/grads/momentum every round, keeping aggregation and
            momentum semantics identical to "params" mode (differentially
            tested to <= 1e-5 on norm-free models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.momentum import (
    FedDUMConfig,
    server_momentum_step,
    server_pseudo_gradient,
)
from repro.core.server_update import FedDUConfig, feddu_apply, tau_eff


@dataclasses.dataclass(frozen=True)
class FedProxConfig:
    """FedProx's proximal term: local grad = g + mu * (theta - theta_global).
    mu = 0 is bit-identical to FedAvg (the term multiplies to exact zero)."""

    mu: float = 0.01

    def __post_init__(self):
        if self.mu < 0:
            raise ValueError(f"FedProx mu must be >= 0, got {self.mu}")


@dataclasses.dataclass(frozen=True)
class FedDynConfig:
    """FedDyn's dynamic regularizer (alpha-scaled parameterization — see the
    module docstring).  alpha = 0 reduces to FedAvg within float identity:
    the correction state stays exactly zero and the server division is a
    static python branch that never enters the graph."""

    alpha: float = 0.01

    def __post_init__(self):
        if self.alpha < 0:
            raise ValueError(f"FedDyn alpha must be >= 0, got {self.alpha}")


ALGORITHMS = ("fedavg", "fedprox", "feddyn")

GUARD_MODES = ("off", "reject_client", "skip_round")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Algorithm switches of the unified round — covers FedAvg / FedDU /
    FedDUM / FedDA / FedDUMAP (FedAP prunes BETWEEN rounds; see rounds.py),
    plus the heterogeneity-robust client algorithms (FedProx / FedDyn)."""

    lr: float = 0.1                 # eta: local AND server SGD step size
    lr_decay: float = 1.0           # per-round geometric decay (paper 4.1)
    use_server_update: bool = True  # FedDU (Formulas 4-7)
    local_momentum: str = "none"    # none | restart | communicated
    server_momentum: bool = False   # FedDUM server SGDM (Formulas 8/12)
    use_masks: bool = False         # static-shape FedAP: masks in the carry
    masked_compute: str = "params"  # params | kernel (see module docstring)
    algorithm: str = "fedavg"       # fedavg | fedprox | feddyn
    guard: str = "off"              # off | reject_client | skip_round
    faults: tuple = ()              # test-only device-fault injection
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    feddum: FedDUMConfig = dataclasses.field(default_factory=FedDUMConfig)
    fedprox: FedProxConfig = dataclasses.field(default_factory=FedProxConfig)
    feddyn: FedDynConfig = dataclasses.field(default_factory=FedDynConfig)

    def __post_init__(self):
        if self.local_momentum not in ("none", "restart", "communicated"):
            raise ValueError(f"unknown local_momentum: {self.local_momentum}")
        if self.masked_compute not in ("params", "kernel"):
            raise ValueError(
                f"unknown masked_compute: {self.masked_compute!r} "
                "(expected 'params' or 'kernel')")
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm: {self.algorithm!r} "
                             f"(expected one of {ALGORITHMS})")
        if self.guard not in GUARD_MODES:
            raise ValueError(f"unknown guard: {self.guard!r} "
                             f"(expected one of {GUARD_MODES})")
        for f in self.faults:
            if not hasattr(f, "apply_client"):
                raise ValueError(
                    f"EngineConfig.faults takes DEVICE faults (objects with "
                    f"an apply_client hook, e.g. reliability.NaNGrad); got "
                    f"{f!r} — host faults like KillAfterChunk belong to the "
                    f"executor (pass them via FLConfig.faults)")


def init_client_state(params: Any, cfg: EngineConfig,
                      num_clients: int | None) -> dict:
    """The algorithm-keyed ``client_state`` subtree (see module docstring).
    Per-client leaves carry a leading [num_clients] dim — the same dim the
    federated dataset leads with, so ``fl_specs.fl_state_specs`` shards
    them over the mesh client axes exactly like the data."""
    if cfg.algorithm == "fedprox":
        return {"per_client": {}, "shared": {}}
    if num_clients is None:
        raise ValueError(
            "algorithm='feddyn' keeps per-client correction state in the "
            "scan carry: pass num_clients=N (the TOTAL client count) to "
            "init_round_state")
    return {
        "per_client": {"h": jax.tree.map(
            lambda p: jnp.zeros((num_clients,) + p.shape, jnp.float32),
            params)},
        "shared": {"h": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)},
    }


def init_round_state(params: Any, cfg: EngineConfig,
                     filter_masks: Any = None,
                     num_clients: int | None = None) -> dict:
    """{"params", "server_m", ["global_m"], ["masks"], ["filter_masks"],
    ["client_state"], "round"} — the scan carry.  Masks start as all-ones
    (a bit-exact no-op round) so a masked engine compiles once and the
    prune event only swaps carry contents.

    ``filter_masks`` (required iff ``cfg.masked_compute == "kernel"``) is
    the per-layer {name: [d] 0/1} dict of ``pruning.filter_masks``; its
    pytree STRUCTURE must already be final (all-ones before the prune
    decision), because the prune event may only swap carry contents, never
    the carry structure, without forcing a re-trace.

    ``num_clients`` (required iff ``cfg.algorithm == "feddyn"``) sizes the
    per-client leaves of ``client_state``.
    """
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"params": params, "server_m": zeros,
             "round": jnp.zeros((), jnp.float32)}
    if cfg.local_momentum == "communicated":
        state["global_m"] = jax.tree.map(jnp.copy, zeros)
    if cfg.algorithm != "fedavg":
        state["client_state"] = init_client_state(params, cfg, num_clients)
    if cfg.use_masks:
        state["masks"] = jax.tree.map(
            lambda p: jnp.ones(p.shape, jnp.float32), params)
        if cfg.masked_compute == "kernel":
            if filter_masks is None:
                raise ValueError(
                    "masked_compute='kernel' needs filter_masks in the scan "
                    "carry: pass pruning.filter_masks(params, spec, {}) "
                    "(all-ones) to init_round_state")
            # copy, not asarray: the scan chunk donates the state, and the
            # caller may retain the same mask arrays (prune artifacts)
            state["filter_masks"] = jax.tree.map(
                lambda m: jnp.array(m, jnp.float32), filter_masks)
    return state


def apply_masks(tree: Any, masks: Any) -> Any:
    """Multiply a param-structured pytree by 0/1 keep-masks (dtype kept)."""
    return jax.tree.map(lambda x, m: (x * m).astype(x.dtype), tree, masks)


def build_model_fns(cfg: EngineConfig, loss_fn: Callable,
                    la_fn: Callable) -> tuple[Callable, Callable]:
    """The ONE place the kernel-mode model-fn arity is decided — shared by
    the executor backends (``core.backend.model_fns``) and the pod path
    (``launch.steps.make_fl_train_step``) so the 3-arg kernel signature
    cannot drift between them.

    Callers adapt their model to two mask-aware callables over an opaque
    batch:

      loss_fn(params, batch, filter_masks) -> scalar loss
      la_fn(params, batch, filter_masks)   -> (loss, acc)

    (``filter_masks`` is ``None`` outside kernel mode.)  Returns
    ``(grad_fn, loss_and_acc_fn)`` in the arity ``round_core`` expects:
    3-arg ``(params, batch, filter_masks)`` when ``cfg.masked_compute ==
    "kernel"``, else the plain 2-arg ``(params, batch)`` signature.
    """
    if cfg.use_masks and cfg.masked_compute == "kernel":
        def grad_fn(p, b, fm):
            return jax.grad(lambda q: loss_fn(q, b, fm))(p)

        def loss_and_acc_fn(p, b, fm):
            return la_fn(p, b, fm)
    else:
        def grad_fn(p, b):
            return jax.grad(lambda q: loss_fn(q, b, None))(p)

        def loss_and_acc_fn(p, b):
            return la_fn(p, b, None)
    return grad_fn, loss_and_acc_fn


def local_train(cfg: EngineConfig, grad_fn: Callable, params: Any, m0: Any,
                batches: Any, lr, anchor: Any = None,
                h: Any = None) -> tuple[Any, Any]:
    """E local epochs on ONE client (Formula 11 when momentum is on).

    ``batches`` is a pytree with a leading [steps] axis; scanned, so the
    local loop never unrolls into the HLO.

    ``anchor`` is the broadcast round-start global model (the proximal /
    dynamic-regularizer reference point; required for fedprox/feddyn);
    ``h`` is this client's alpha-scaled FedDyn correction (required for
    feddyn), held FIXED over the local epochs.  Both corrections feed the
    momentum recursion like any other gradient term, so they compose with
    every local-momentum mode unchanged.
    """
    use_m = cfg.local_momentum != "none"
    beta = cfg.feddum.beta_local

    def corrected(g, p):
        if cfg.algorithm == "fedprox":
            mu = cfg.fedprox.mu
            return jax.tree.map(
                lambda gi, pi, ai: (gi + mu * (pi - ai)).astype(gi.dtype),
                g, p, anchor)
        if cfg.algorithm == "feddyn":
            alpha = cfg.feddyn.alpha
            return jax.tree.map(
                lambda gi, pi, ai, hi:
                (gi + alpha * (pi - ai) - hi).astype(gi.dtype),
                g, p, anchor, h)
        return g

    def body(carry, batch):
        p, m = carry
        g = corrected(grad_fn(p, batch), p)
        if use_m:
            m = jax.tree.map(
                lambda mi, gi: beta * mi + (1 - beta) * gi.astype(jnp.float32),
                m, g)
            upd = m
        else:
            upd = g
        p = jax.tree.map(lambda pi, u: (pi - lr * u).astype(pi.dtype), p, upd)
        return (p, m), None

    (params, m), _ = jax.lax.scan(body, (params, m0), batches)
    return params, m


def round_core(cfg: EngineConfig, grad_fn: Callable, loss_and_acc_fn: Callable,
               state: dict, batch: dict) -> tuple[dict, dict]:
    """One full federated round (paper steps 2-5), pure and scan-safe.

    batch:
      client    pytree, leading dims [C, steps, ...] (per-client batches)
      sizes     [C] f32 n_k
      server    pytree, leading dim [tau, ...] (server SGD batches)
      d_round   D(Pbar'^t) — non-IID degree of this round's selection
      d_server  D(P0)      — non-IID degree of the server data
      n0        scalar f32 — number of server samples
      sel       [C] int32, OPTIONAL — the selected clients' global indices
                (required for algorithm="feddyn": indexes client_state)
      active    [C] 0/1 f32, OPTIONAL — straggler/dropout mask; when
                present the FedAvg reduction runs in delta form and
                dropped clients contribute zero weight (state untouched)

    ``cfg.guard != "off"`` adds the in-scan health guard: every selected
    client's uploaded update (and, for FedDA, its communicated momentum)
    is finiteness-checked on device; non-finite clients are scrubbed back
    to the broadcast point and get exactly-zero aggregation weight through
    the delta-form reduction.  The FedDU server proposal is guarded the
    same way (a non-finite proposed model / tau_eff / acc falls back to
    the aggregated ``w_half``).  Under ``guard="reject_client"`` the round
    proceeds on the survivors; under ``guard="skip_round"`` ANY rejection
    (client or server) discards the whole round — the carry is restored to
    the round-start state with only the round counter advanced, so a bad
    round is exactly a no-op.  All guard branches are keyed on static
    config, and the carry/metrics structure is identical in every mode, so
    turning guards on compiles ZERO additional programs.

    ``cfg.faults`` (test-only) injects deterministic device faults into
    the uploaded updates BEFORE the guard sees them — a static unroll over
    the frozen fault tuple, so the corruption is part of the traced graph
    and fires identically under jit/scan/mesh.

    Returns (new_state, {"tau_eff", "server_acc", "health"}); ``health``
    is the number of guard rejections this round (active clients scrubbed,
    plus 1 if the server step was rejected) — identically 0.0 when the
    guard is off.
    """
    if cfg.use_masks:
        # Static-shape FedAP: params, gradients and momentum are multiplied
        # by the 0/1 keep-masks riding in the carry, every round.  With the
        # coupled-closure masks built by `pruning.param_masks` this equals
        # training the re-materialized model (norm-free archs) at unchanged
        # shapes — the prune round runs inside the compiled scan.
        masks = state["masks"]
        _m = lambda t: apply_masks(t, masks)
        base_grad_fn, base_la_fn = grad_fn, loss_and_acc_fn
        if cfg.masked_compute == "kernel":
            # Filter-level masks thread into the model fns, which route
            # masked dense layers through the differentiable Pallas
            # masked_matmul kernel — pruned blocks are skipped on the MXU
            # in forward AND backward.  The param masks still scrub
            # grads/params/momentum so aggregation semantics are identical
            # to "params" mode.
            fmasks = state["filter_masks"]
            grad_fn = lambda p, b: _m(base_grad_fn(p, b, fmasks))
            loss_and_acc_fn = lambda p, b: base_la_fn(p, b, fmasks)
        else:
            grad_fn = lambda p, b: _m(base_grad_fn(p, b))
    else:
        _m = lambda t: t

    params = _m(state["params"])
    lr = cfg.lr * (cfg.lr_decay ** state["round"])

    # (2) local epochs, vmapped over the client dim — clients diverge inside
    # the program; there is NO collective over the client axis here.
    if cfg.local_momentum == "communicated":
        m0 = _m(state["global_m"])             # FedDA: broadcast momentum
    else:
        m0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.algorithm == "feddyn":
        if "sel" not in batch:
            raise ValueError(
                "algorithm='feddyn' needs batch['sel'] (the selected "
                "clients' global indices) to gather per-client state — "
                "sample_round_batches emits it")
        h_all = state["client_state"]["per_client"]["h"]
        h_sel = _m(jax.tree.map(lambda x: x[batch["sel"]], h_all))
        locals_, local_ms = jax.vmap(
            lambda b, hk: local_train(cfg, grad_fn, params, m0, b, lr,
                                      anchor=params, h=hk))(
                batch["client"], h_sel)
    elif cfg.algorithm == "fedprox":
        locals_, local_ms = jax.vmap(
            lambda b: local_train(cfg, grad_fn, params, m0, b, lr,
                                  anchor=params))(batch["client"])
    else:
        locals_, local_ms = jax.vmap(
            lambda b: local_train(cfg, grad_fn, params, m0, b,
                                  lr))(batch["client"])

    # Deterministic fault injection (test-only): corrupt the uploaded
    # updates BEFORE aggregation / the guard.  A static python unroll over
    # the frozen fault tuple — the faults are part of the traced graph.
    if cfg.faults:  # lint: static-branch (config-keyed)
        sel_ids = batch.get("sel")
        if sel_ids is None:
            sel_ids = jnp.arange(batch["sizes"].shape[0], dtype=jnp.int32)
        for f in cfg.faults:
            locals_ = f.apply_client(locals_, params, sel_ids,
                                     state["round"])

    # In-scan health guard: all-device finiteness check per client.  A
    # rejected client is scrubbed back to the broadcast point (so NaN/inf
    # never reaches a reduction — 0-weight alone would not neutralize NaN)
    # and contributes zero aggregation weight via the delta-form path.
    sizes = batch["sizes"].astype(jnp.float32)
    active = batch.get("active")
    guard_on = cfg.guard != "off"
    base_act = (active.astype(jnp.float32) if active is not None
                else jnp.ones_like(sizes))
    if guard_on:
        _cvec = lambda v, leaf: v.reshape(v.shape + (1,) * (leaf.ndim - 1))
        client_ok = jnp.ones(sizes.shape, bool)
        checked = [locals_]
        if cfg.local_momentum == "communicated":
            checked.append(local_ms)
        for tree in checked:
            for leaf in jax.tree.leaves(tree):
                client_ok = client_ok & jnp.all(
                    jnp.isfinite(leaf), axis=tuple(range(1, leaf.ndim)))
        rejected = jnp.sum(base_act * (~client_ok).astype(jnp.float32))
        act = base_act * client_ok.astype(jnp.float32)
        _scrub = lambda trees, base: jax.tree.map(
            lambda l, b: jnp.where(_cvec(client_ok, l), l,
                                   b.astype(l.dtype)), trees, base)
        locals_ = _scrub(locals_, params)
        if cfg.local_momentum == "communicated":
            local_ms = _scrub(local_ms, m0)
    else:
        rejected = jnp.zeros(())
        act = base_act

    # (3-4) upload + FedAvg: ONE weighted reduction over the client axis.
    # With a dropout mask or an active guard the reduction runs in DELTA
    # form around the broadcast point (an all-dropped round is exactly a
    # no-op); otherwise the legacy direct einsum — bit-identical to the
    # pre-dropout engine.
    if active is not None or guard_on:
        w = sizes * act
        w = w / jnp.maximum(jnp.sum(w), 1e-12)

        def agg_tree(trees, base):
            def one(l, b):
                d = jnp.einsum("c,c...->...", w, l.astype(jnp.float32)
                               - b.astype(jnp.float32))
                return (b.astype(jnp.float32) + d).astype(l.dtype)
            return jax.tree.map(one, trees, base)

        w_half = agg_tree(locals_, params)
        new_global_m = (agg_tree(local_ms, m0)
                        if cfg.local_momentum == "communicated" else None)
    else:
        w = sizes / jnp.sum(sizes)
        agg = lambda l: jnp.einsum(
            "c,c...->...", w, l.astype(jnp.float32)).astype(l.dtype)
        w_half = jax.tree.map(agg, locals_)
        new_global_m = (jax.tree.map(agg, local_ms)
                        if cfg.local_momentum == "communicated" else None)

    # FedDyn: update the per-client correction of the selected ACTIVE
    # clients (scatter), the server average, and pull w_half toward the
    # implicit consensus point — all BEFORE the FedDU server update, which
    # then trains from the corrected model.
    new_client_state = state.get("client_state")
    if cfg.algorithm == "feddyn":
        alpha = cfg.feddyn.alpha
        n_total = jax.tree.leaves(h_all)[0].shape[0]
        bcast = lambda v, leaf: v.reshape(v.shape + (1,) * (leaf.ndim - 1))
        drift = jax.tree.map(
            lambda l, p0: l.astype(jnp.float32) - p0.astype(jnp.float32),
            locals_, params)
        h_sel_new = jax.tree.map(
            lambda hk, d: hk - alpha * bcast(act, d) * d, h_sel, drift)
        h_new = jax.tree.map(
            lambda ha, hs: ha.at[batch["sel"]].set(hs.astype(ha.dtype)),
            h_all, h_sel_new)
        h_shared_new = jax.tree.map(
            lambda hs, d: hs - (alpha / n_total)
            * jnp.einsum("c,c...->...", act, d),
            _m(state["client_state"]["shared"]["h"]), drift)
        if alpha > 0:  # lint: static-branch (at alpha == 0, h is identically zero)
            w_half = jax.tree.map(
                lambda wh, hs: (wh.astype(jnp.float32) - hs / alpha
                                ).astype(wh.dtype), w_half, h_shared_new)
        new_client_state = {"per_client": {"h": _m(h_new)},
                            "shared": {"h": _m(h_shared_new)}}

    # (5a) FedDU dynamic server update (Formulas 4-7).  acc comes from the
    # FIRST server step's own forward — no separate evaluation pass.
    if cfg.use_server_update:
        tau = jax.tree.leaves(batch["server"])[0].shape[0]
        la_grad = jax.value_and_grad(loss_and_acc_fn, has_aux=True)

        def sstep(carry, b):
            p, acc0, is_first = carry
            (_, acc), g = la_grad(p, b)
            g = _m(g)
            acc0 = jnp.where(is_first, acc, acc0)
            p = jax.tree.map(lambda pi, gi: (pi - lr * gi).astype(pi.dtype), p, g)
            return (p, acc0, jnp.zeros((), bool)), None

        (w_end, acc, _), _ = jax.lax.scan(
            sstep, (w_half, jnp.zeros(()), jnp.ones((), bool)), batch["server"])
        # Formula 6 via the telescoping identity: mean path gradient.
        g0 = jax.tree.map(
            lambda a, b_: (a.astype(jnp.float32) - b_.astype(jnp.float32))
            / (tau * lr), w_half, w_end)
        t_eff = tau_eff(cfg.feddu, acc=acc, round_idx=state["round"],
                        n0=batch["n0"], n_prime=jnp.sum(batch["sizes"]),
                        d_round=batch["d_round"], d_server=batch["d_server"],
                        tau=tau)
        proposed = feddu_apply(w_half, g0, t_eff, lr)
    else:
        proposed = w_half
        t_eff = jnp.zeros(())
        acc = jnp.zeros(())

    # Server-step guard: a diverged FedDU proposal (non-finite model,
    # tau_eff or gate accuracy) falls back to the plain aggregate w_half.
    if guard_on and cfg.use_server_update:
        server_ok = jnp.isfinite(t_eff) & jnp.isfinite(acc)
        for leaf in jax.tree.leaves(proposed):
            server_ok = server_ok & jnp.all(jnp.isfinite(leaf))
        proposed = jax.tree.map(
            lambda pr, wh: jnp.where(server_ok, pr, wh), proposed, w_half)
        t_eff = jnp.where(server_ok, t_eff, 0.0)
        acc = jnp.where(server_ok, acc, 0.0)
    else:
        server_ok = jnp.ones((), bool)

    # (5b) FedDUM server momentum on the pseudo-gradient (Formulas 8/12).
    if cfg.server_momentum:
        pseudo = server_pseudo_gradient(params, proposed)
        new_params, new_server_m = server_momentum_step(
            params, state["server_m"], pseudo, cfg.feddum)
    else:
        new_params, new_server_m = proposed, state["server_m"]

    new_state = {"params": _m(new_params), "server_m": _m(new_server_m),
                 "round": state["round"] + 1}
    if cfg.local_momentum == "communicated":
        new_state["global_m"] = _m(new_global_m)
    if new_client_state is not None:
        new_state["client_state"] = new_client_state
    if cfg.use_masks:
        new_state["masks"] = masks
        if cfg.masked_compute == "kernel":
            new_state["filter_masks"] = state["filter_masks"]

    # Round discard: with every client rejected there is no information in
    # the round (reject_client), and under skip_round ANY rejection voids
    # it — restore the round-start carry (round counter still advances, so
    # the key chain and lr schedule stay aligned with a fault-free run).
    if guard_on:
        survivors = jnp.sum(act) > 0
        if cfg.guard == "reject_client":
            discard = ~survivors
        else:  # skip_round
            discard = (~survivors) | (rejected > 0) | (~server_ok)
        health = rejected + (~server_ok).astype(jnp.float32)
        for k in ("params", "server_m", "global_m", "client_state"):
            if k in new_state:
                new_state[k] = jax.tree.map(
                    lambda o, n: jnp.where(discard, o, n),
                    state[k], new_state[k])
        t_eff = jnp.where(discard, 0.0, t_eff)
        acc = jnp.where(discard, 0.0, acc)
    else:
        health = jnp.zeros(())
    return new_state, {"tau_eff": t_eff, "server_acc": acc,
                       "health": health}


# ---------------------------------------------------------------------------
# Device-side sampling — jax.random replaces the host np.random permutations
# ---------------------------------------------------------------------------

def sample_clients(key: jax.Array, num_clients: int, k: int) -> jax.Array:
    """Step (1): D^t — k distinct client indices, drawn on device."""
    return jax.random.choice(key, num_clients, (k,), replace=False)


def epoch_indices(key: jax.Array, n: int, count: int) -> jax.Array:
    """``count`` sample indices drawn as repeated without-replacement
    epochs over ``n`` samples (the paper's epoch semantics), on device."""
    reps = -(-count // n)  # ceil
    perms = jax.vmap(lambda k: jax.random.permutation(k, n))(
        jax.random.split(key, reps))
    return perms.reshape(-1)[:count]


def sample_round_batches(key: jax.Array, data: dict, *, clients_per_round: int,
                         batch_size: int, local_steps: int, server_batch: int,
                         server_tau: int, dropout_rate: float = 0.0) -> dict:
    """Builds one round's ``round_core`` batch entirely on device.

    data (all jnp, see FederatedData.device_arrays):
      client_x [N, n_k, ...], client_y [N, n_k], sizes [N],
      client_dists [N, classes], p_bar [classes], d_server scalar,
      server_x [n0, ...], server_y [n0].

    ``dropout_rate`` > 0 simulates stragglers: each selected client
    independently drops with that probability, emitted as the 0/1
    ``"active"`` mask.  At the default 0.0 the key is split exactly as
    before (3 ways), so existing runs stay bit-identical; dropout configs
    split 4 ways and draw their own deterministic chain.
    """
    from repro.core import niid

    if dropout_rate:
        k_sel, k_cl, k_srv, k_drop = jax.random.split(key, 4)
    else:
        k_sel, k_cl, k_srv = jax.random.split(key, 3)
    num_clients, n_k = data["client_y"].shape[:2]
    n0 = data["server_y"].shape[0]

    sel = sample_clients(k_sel, num_clients, clients_per_round)
    count = local_steps * batch_size
    idx = jax.vmap(lambda k: epoch_indices(k, n_k, count))(
        jax.random.split(k_cl, clients_per_round))              # [C, count]
    cx = jax.vmap(lambda x, i: x[i])(data["client_x"][sel], idx)
    cy = jax.vmap(lambda y, i: y[i])(data["client_y"][sel], idx)
    cx = cx.reshape(clients_per_round, local_steps, batch_size, *cx.shape[2:])
    cy = cy.reshape(clients_per_round, local_steps, batch_size, *cy.shape[2:])

    sidx = epoch_indices(k_srv, n0, server_tau * server_batch)
    sx = data["server_x"][sidx].reshape(
        server_tau, server_batch, *data["server_x"].shape[1:])
    sy = data["server_y"][sidx].reshape(
        server_tau, server_batch, *data["server_y"].shape[1:])

    p_round = niid.round_distribution(data["client_dists"], data["sizes"], sel)
    d_round = niid.non_iid_degree(p_round, data["p_bar"])
    batch = {
        "client": (cx, cy),
        "sizes": data["sizes"][sel],
        "server": (sx, sy),
        "d_round": d_round,
        "d_server": data["d_server"],
        "n0": jnp.asarray(n0, jnp.float32),
        "sel": sel.astype(jnp.int32),
    }
    if dropout_rate:
        batch["active"] = (
            jax.random.uniform(k_drop, (clients_per_round,))
            >= dropout_rate).astype(jnp.float32)
    return batch
