"""zamba2-1.2b [arXiv:2411.15242] — hybrid: Mamba2 backbone + SHARED
attention block applied periodically (weights reused).

38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
long_500k runs NATIVELY (O(1) SSM state; the shared attention block uses a
sliding window).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    rope="1d",
    norm="rmsnorm",
    act="silu",
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, chunk=256),
    hybrid=HybridConfig(attn_every=6),
    sliding_window=4096,      # for the shared attention block only
    fl_client_axis="data",
    fsdp=False,
    citation="arXiv:2411.15242",
)
