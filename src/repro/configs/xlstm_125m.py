"""xlstm-125m [arXiv:2405.04517] — sLSTM + mLSTM blocks (attention-free).

12L d_model=768 4H (kv=4) d_ff=0 (xLSTM blocks carry their own projection
factor instead of an FFN) vocab=50304.  long_500k runs natively (recurrent
state is O(1) in sequence length).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope="none",
    norm="layernorm",
    act="gelu",
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0),
    fl_client_axis="data",
    fsdp=False,
    citation="arXiv:2405.04517",
)
