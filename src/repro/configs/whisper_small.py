"""whisper-small [arXiv:2212.04356] — encoder-decoder audio transformer.

12L (encoder + decoder) d_model=768 12H (kv=12, i.e. MHA) d_ff=3072
vocab=51865.  The mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` provides precomputed frame embeddings [B, 1500, 768].
long_500k runs with a sliding-window decoder self-attention; cross-attn is
always to the fixed 1500-frame encoder output.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    rope="none",              # whisper uses learned/sinusoidal abs positions
    norm="layernorm",
    act="gelu",
    encoder=EncoderConfig(num_layers=12, frames=1500),
    sliding_window=8192,      # decoder self-attn window for long_500k
    pad_heads_to=16,
    fl_client_axis="data",
    fsdp=False,
    citation="arXiv:2212.04356",
)
