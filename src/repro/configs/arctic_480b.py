"""arctic-480b [hf:Snowflake/snowflake-arctic-base] — dense-MoE hybrid.

35L d_model=7168 56H (GQA kv=8), MoE 128 experts top-2 with expert
d_ff=4864 PLUS an always-on dense residual FFN branch, vocab=32000.
Cross-silo FL, FSDP x TP with expert-parallel sharding.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    rope="1d",
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864, dense_d_ff=4864),
    sliding_window=8192,
    pad_heads_to=16,
    fl_client_axis="pod",
    fsdp=True,
    citation="hf:Snowflake/snowflake-arctic-base",
)
