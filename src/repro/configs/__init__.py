"""Config registry: ``get_config('<arch-id>')`` and the shape table.

All 10 assigned architectures (+ the paper's own CIFAR models, which live
in repro.models.cnn and are configured inline by the experiments).
"""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_ARCHS = {
    "whisper-small": "repro.configs.whisper_small",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "olmo-1b": "repro.configs.olmo_1b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "llama3-405b": "repro.configs.llama3_405b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

ARCH_NAMES = tuple(_ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(_ARCHS[name]).CONFIG


__all__ = ["ARCH_NAMES", "INPUT_SHAPES", "InputShape", "ModelConfig", "get_config"]
