"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE,
top-1 routing with a shared expert, early-fusion multimodal (text path here;
fusion embeddings arrive pre-projected like the VLM stub).

48L d_model=5120 40H (GQA kv=8) d_ff=8192, MoE 128 experts top-1,
vocab=202048.  Cross-silo FL, FSDP x TP + expert parallel.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    rope="1d",
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(num_experts=128, top_k=1, expert_d_ff=8192, shared_expert=True),
    sliding_window=8192,
    pad_heads_to=16,
    fl_client_axis="pod",
    fsdp=True,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
