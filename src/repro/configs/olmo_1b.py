"""olmo-1b [arXiv:2402.00838] — dense with NON-PARAMETRIC LayerNorm.

16L d_model=2048 16H (MHA, kv=16) d_ff=8192 vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    rope="1d",
    norm="nonparam",          # OLMo: LayerNorm without scale/bias params
    act="silu",
    sliding_window=8192,
    tie_embeddings=True,
    fl_client_axis="data",
    fsdp=False,
    citation="arXiv:2402.00838",
)
