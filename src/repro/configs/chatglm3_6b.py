"""chatglm3-6b [arXiv:2406.12793] — dense, 2d-RoPE, aggressive GQA (kv=2).

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope="2d",                # GLM-style two-stream rotary
    norm="rmsnorm",
    act="silu",
    sliding_window=8192,
    fl_client_axis="data",
    fsdp=False,
    citation="arXiv:2406.12793",
)
