"""qwen2-vl-7b [arXiv:2409.12191] — VLM backbone with M-RoPE.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
Vision encoder (ViT) + projector are STUBS: input_specs provides the
interleaved text+patch embedding sequence plus the 3-axis (temporal,
height, width) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope="mrope",
    norm="rmsnorm",
    act="silu",
    vlm=VLMConfig(num_vision_tokens=1024),
    sliding_window=8192,
    pad_heads_to=16,
    fl_client_axis="data",
    fsdp=False,
    citation="arXiv:2409.12191",
)
