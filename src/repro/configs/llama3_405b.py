"""llama3-405b [arXiv:2407.21783] — dense GQA flagship.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
Cross-silo FL, FSDP x TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope="1d",
    norm="rmsnorm",
    act="silu",
    sliding_window=8192,
    fl_client_axis="pod",
    fsdp=True,
    citation="arXiv:2407.21783",
)
