"""Architecture + shape + FL configuration dataclasses.

Every assigned architecture is a :class:`ModelConfig`; the four required
input shapes are :data:`INPUT_SHAPES`.  Configs are pure data — models are
assembled from them by ``repro.models.api.build_model``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    dense_d_ff: int = 0          # arctic-style dense residual branch (0 = none)
    shared_expert: bool = False  # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N (per-head state size)
    conv_width: int = 4
    expand: int = 2              # d_inner = expand * d_model
    num_ssm_heads: int = 0       # 0 -> d_inner // 64
    chunk: int = 256             # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4         # every k-th block is sLSTM, rest mLSTM
    proj_factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style audio encoder (conv frontend is a STUB: input_specs
    provides precomputed mel-frame embeddings [B, frames, d_model])."""
    num_layers: int = 12
    frames: int = 1500


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style: Mamba2 backbone + one SHARED attention block applied
    every ``attn_every`` layers (weights reused at each application)."""
    attn_every: int = 6


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """qwen2-vl: vision frontend is a STUB — input_specs provides the
    interleaved text+patch embedding sequence and the 3-axis M-RoPE ids."""
    num_vision_tokens: int = 1024    # of the sequence, for spec realism


# Nested sub-config classes by ModelConfig field name (checkpoint metadata
# round-trips them through plain dicts).
_SUB_CONFIGS = {"moe": MoEConfig, "ssm": SSMConfig, "xlstm": XLSTMConfig,
                "encoder": EncoderConfig, "hybrid": HybridConfig,
                "vlm": VLMConfig}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    rope: str = "1d"              # 1d | 2d | mrope | none
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam
    act: str = "silu"             # silu (SwiGLU) | gelu (plain MLP)
    head_dim: int = 0             # 0 -> d_model // num_heads
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None
    hybrid: Optional[HybridConfig] = None
    vlm: Optional[VLMConfig] = None
    # Long-context execution: dense archs run long_500k ONLY via this window
    # (ring-buffer KV cache); SSM/hybrid run natively. None = full attention.
    sliding_window: Optional[int] = None
    # --- distribution ----------------------------------------------------
    # Which mesh axis hosts FL clients ('data' for <=10B archs, 'pod' for
    # cross-silo giants; see DESIGN.md Section 4).
    fl_client_axis: str = "data"
    # FSDP: shard parameters over the 'data' axis too (giants).
    fsdp: bool = False
    # Pad attention-head count up to a multiple of this so the TP axis
    # shards attention evenly (dead heads have zero wo rows — semantics
    # exact; §Perf C1).  0 = off.  Archs whose head counts do not divide
    # the 16-way model axis (56/40/28/12) set 16.
    pad_heads_to: int = 0
    # Remat policy for the backward pass: 'none' | 'block' | 'dots'
    remat: str = "block"
    # dtype of params in the distributed runtime
    param_dtype: str = "bfloat16"
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def padded_num_heads(self) -> int:
        """Head count after §Perf-C1 padding (== num_heads when off)."""
        p = self.pad_heads_to
        if not p or self.num_heads % p == 0:
            return self.num_heads
        return (self.num_heads + p - 1) // p * p

    @property
    def padded_num_kv_heads(self) -> int:
        """KV heads must divide the padded head count; MHA archs (whisper)
        pad KV alongside Q."""
        h = self.padded_num_heads
        kv = self.num_kv_heads
        return kv if h % kv == 0 else h

    # -- (de)serialization: the checkpoint metadata format -----------------
    def to_dict(self) -> dict:
        """JSON-safe dict (nested sub-configs included) — the inverse of
        :meth:`from_dict`; used by ``RunResult.save`` checkpoint metadata."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ModelConfig":
        """Rebuild a config from :meth:`to_dict` output (e.g. a checkpoint's
        ``meta.json``).  Unknown keys fail loudly rather than being dropped."""
        d = dict(d)
        for key, sub_cls in _SUB_CONFIGS.items():
            if d.get(key) is not None:
                d[key] = sub_cls(**d[key])
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"ModelConfig.from_dict: unknown field(s) {sorted(unknown)} "
                f"— checkpoint written by an incompatible version?")
        return cls(**d)

    def reduced(self, **overrides) -> "ModelConfig":
        """The smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64,
            param_dtype="float32",
            fsdp=False,
            remat="none",
        )
        if self.num_kv_heads == self.num_heads:     # MHA archs stay MHA
            small["num_kv_heads"] = small["num_heads"]
        if self.moe:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                expert_d_ff=128, dense_d_ff=128 if self.moe.dense_d_ff else 0)
        if self.ssm:
            small["ssm"] = dataclasses.replace(self.ssm, state_dim=16, chunk=32)
        if self.encoder:
            small["encoder"] = dataclasses.replace(self.encoder, num_layers=2, frames=64)
        if self.sliding_window:
            small["sliding_window"] = 64
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
