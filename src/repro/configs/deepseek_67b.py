"""deepseek-67b [arXiv:2401.02954] — dense llama-architecture.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
Cross-silo FL (clients on the pod axis), FSDP x TP sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    rope="1d",
    norm="rmsnorm",
    act="silu",
    sliding_window=8192,      # long_500k via sliding-window variant
    fl_client_axis="pod",
    fsdp=True,
    citation="arXiv:2401.02954",
)
