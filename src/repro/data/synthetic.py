"""Synthetic datasets.

CIFAR-10/100 are not available in this offline container (see DESIGN.md
Section 2), so the paper-repro experiments use a *structured* synthetic
classification task whose FL dynamics mirror image classification:

* each class c has a random prototype mu_c on the unit sphere in pixel
  space, plus class-conditional low-rank structure (a few shared "feature"
  directions with class-specific coefficients) and additive noise;
* samples are reshaped to [H, W, C] images so the exact conv models from
  the paper (CNN/VGG11/LeNet5/ResNet18) run unchanged;
* difficulty is controlled by noise_scale — chosen so FedAvg lands in the
  0.5-0.8 accuracy band after a few hundred rounds, the same operating
  regime as the paper's CIFAR-10 tables.

For the LLM-scale architectures, token streams are synthesized from a
per-client mixture over "topic" n-gram generators — label skew becomes
topic skew, so the non-IID machinery (Formulas 2-3) applies verbatim with
topics as labels.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    num_classes: int = 10
    image_shape: tuple = (16, 16, 3)
    train_size: int = 50000
    test_size: int = 10000
    noise_scale: float = 0.9
    feature_rank: int = 12
    seed: int = 0


def synthetic_classification(spec: SyntheticSpec):
    """Returns (train_x, train_y, test_x, test_y) as float32/int32 arrays."""
    rng = np.random.default_rng(spec.seed)
    dim = int(np.prod(spec.image_shape))
    protos = rng.standard_normal((spec.num_classes, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    basis = rng.standard_normal((spec.feature_rank, dim)).astype(np.float32)
    basis /= np.linalg.norm(basis, axis=1, keepdims=True)
    coeff = rng.standard_normal((spec.num_classes, spec.feature_rank)).astype(np.float32)

    def make(n, seed):
        r = np.random.default_rng(seed)
        y = r.integers(0, spec.num_classes, n).astype(np.int32)
        z = r.standard_normal((n, spec.feature_rank)).astype(np.float32) * 0.3
        x = (protos[y]
             + (coeff[y] + z) @ basis * 0.5
             + r.standard_normal((n, dim)).astype(np.float32) * spec.noise_scale)
        return x.reshape(n, *spec.image_shape), y

    train_x, train_y = make(spec.train_size, spec.seed + 1)
    test_x, test_y = make(spec.test_size, spec.seed + 2)
    return train_x, train_y, test_x, test_y


@dataclasses.dataclass(frozen=True)
class TokenSpec:
    vocab_size: int = 50304
    num_topics: int = 10       # topics double as "labels" for non-IID degrees
    seq_len: int = 512
    num_sequences: int = 2048
    ngram: int = 2
    seed: int = 0


def synthetic_tokens(spec: TokenSpec):
    """Topic-conditioned Markov token streams.

    Returns (tokens [N, S] int32, topics [N] int32).  Each topic owns a
    sparse bigram transition table over a topic-specific vocabulary slice,
    giving real sequence structure (a model can reduce loss by learning
    the transitions) while keeping generation cheap.
    """
    rng = np.random.default_rng(spec.seed)
    V, T = spec.vocab_size, spec.num_topics
    slice_size = max(64, V // (2 * T))
    starts = rng.integers(0, max(1, V - slice_size), T)
    # per-topic transition: next = (a * cur + b) % slice + start, with noise
    a = rng.integers(3, 97, T)
    b = rng.integers(1, slice_size, T)

    topics = rng.integers(0, T, spec.num_sequences).astype(np.int32)
    toks = np.empty((spec.num_sequences, spec.seq_len), np.int32)
    cur = rng.integers(0, slice_size, spec.num_sequences)
    noise = rng.random((spec.num_sequences, spec.seq_len)) < 0.1
    jumps = rng.integers(0, slice_size, (spec.num_sequences, spec.seq_len))
    for s in range(spec.seq_len):
        cur = np.where(noise[:, s], jumps[:, s], (a[topics] * cur + b[topics]) % slice_size)
        toks[:, s] = starts[topics] + cur
    return toks, topics
