"""Non-IID partitioners (paper Section 4.1 protocol + Dirichlet).

Label-shard protocol (the paper's): sort training data by label, split
into ``2 * num_clients`` equal fractions, deal each client 2 random
fractions — most clients end up with exactly 2 labels.

Dirichlet(alpha) is the other standard protocol, provided for the
non-IID-degree sweeps (Figure 6 / Table 5 reproduce by varying how the
SERVER data is drawn — parameter ``server_niid``).
"""
from __future__ import annotations

import numpy as np


def label_shard_partition(labels: np.ndarray, num_clients: int,
                          shards_per_client: int = 2, seed: int = 0):
    """Returns a list of index arrays, one per client (equal sizes)."""
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    num_shards = num_clients * shards_per_client
    usable = (len(order) // num_shards) * num_shards
    shards = order[:usable].reshape(num_shards, -1)
    perm = rng.permutation(num_shards)
    return [
        np.concatenate([shards[perm[c * shards_per_client + i]]
                        for i in range(shards_per_client)])
        for c in range(num_clients)
    ]


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_size: int = 8):
    """Dirichlet(alpha) label-proportion partition. Smaller alpha = more skew."""
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    while True:
        idx_per_client = [[] for _ in range(num_clients)]
        for c in range(num_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet([alpha] * num_clients)
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cid, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[cid].extend(part.tolist())
        if min(len(ix) for ix in idx_per_client) >= min_size:
            return [np.asarray(sorted(ix)) for ix in idx_per_client]


def server_subset(labels: np.ndarray, pool: np.ndarray, size: int,
                  *, niid_target: str = "iid", seed: int = 0):
    """Draw the server's shared data from ``pool`` indices.

    niid_target:
      'iid'      — uniform draw (the paper's d ~ 9e-6 setting)
      'mild'     — half the classes over-represented 3:1 (d ~ 0.3)
      'severe'   — only half the classes present (d ~ 0.6)
    Reproduces the paper's Figure 6 / Table 5 server-data regimes.
    """
    rng = np.random.default_rng(seed)
    y = labels[pool]
    num_classes = int(labels.max()) + 1
    if niid_target == "iid":
        weights = np.ones(num_classes)
    elif niid_target == "mild":
        weights = np.where(np.arange(num_classes) < num_classes // 2, 3.0, 1.0)
    elif niid_target == "severe":
        weights = np.where(np.arange(num_classes) < num_classes // 2, 1.0, 0.0)
    else:
        raise ValueError(niid_target)
    p = weights[y].astype(np.float64)
    p /= p.sum()
    return pool[rng.choice(len(pool), size=size, replace=False, p=p)]
