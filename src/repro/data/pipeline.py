"""Federated dataset container + builder (paper Section 4.1 protocol).

CIFAR-10 protocol transplanted to the synthetic dataset:
  * 40000 training images are device data, label-shard partitioned over
    100 clients (2 shards each);
  * the server draws p * 40000 images from the REMAINING 10000 training
    images (p in {1%, 5%, 10%}), with a controllable non-IID degree;
  * the held-out test split scores the global model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import partition as part
from repro.data.synthetic import (
    SyntheticSpec,
    TokenSpec,
    synthetic_classification,
    synthetic_tokens,
)


@dataclasses.dataclass
class FederatedData:
    client_x: np.ndarray      # [N, n_k, ...]  (equal n_k: label-shard protocol)
    client_y: np.ndarray      # [N, n_k]
    sizes: np.ndarray         # [N] float n_k
    client_dists: np.ndarray  # [N, num_classes] P_k
    server_x: np.ndarray      # [n0, ...]
    server_y: np.ndarray      # [n0]
    server_dist: np.ndarray   # [num_classes] P_0
    test_x: np.ndarray
    test_y: np.ndarray

    def device_arrays(self, *, mesh=None, client_axes: tuple = ("data",),
                      shard_test: bool = True) -> dict:
        """The whole federated dataset as ONE device-resident dict — the
        single host->device transfer point for the scan-compiled engine
        (`repro.core.engine.sample_round_batches` draws every round's
        client subset and batches from these arrays on device).

        With ``mesh`` the dict is placed for the client-sharded MeshBackend:
        the per-client arrays (``client_x``/``client_y``/``sizes``/
        ``client_dists``) shard their leading client dimension over the
        mesh ``client_axes`` (falling back to replication when the client
        count does not divide), so each device STORES only its clients'
        data, and — with ``shard_test`` — the test split shards its batch
        dimension the same way, padded with copies of row 0 up to the axis
        size so evaluation is ALWAYS data-parallel (the MeshBackend's eval
        program corrects the padded rows out exactly; `MeshBackend`
        closes over the true row count).  The server pool and scalars stay
        replicated (per-round server batches are sharding-constrained
        in-scan instead — `fl_specs.fl_sim_batch_specs`).  Without
        ``mesh`` the arrays land on the default device, exactly as
        before."""
        import jax
        import jax.numpy as jnp

        from repro.core import niid

        dists = jnp.asarray(self.client_dists, jnp.float32)
        sizes = jnp.asarray(self.sizes, jnp.float32)
        p_bar = niid.global_distribution(dists, sizes)
        out = {
            "client_x": jnp.asarray(self.client_x),
            "client_y": jnp.asarray(self.client_y, jnp.int32),
            "sizes": sizes,
            "client_dists": dists,
            "p_bar": p_bar,
            "d_server": niid.non_iid_degree(
                jnp.asarray(self.server_dist, jnp.float32), p_bar),
            "server_x": jnp.asarray(self.server_x),
            "server_y": jnp.asarray(self.server_y, jnp.int32),
            "test_x": jnp.asarray(self.test_x),
            "test_y": jnp.asarray(self.test_y, jnp.int32),
        }
        if mesh is None:
            return out
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.sharding.fl_specs import client_dim_sharding

        replicated = NamedSharding(mesh, P())
        client_sharded = client_dim_sharding(mesh, client_axes,
                                             self.client_x.shape[0])
        shardings = {k: replicated for k in out}
        for k in ("client_x", "client_y", "sizes", "client_dists"):
            shardings[k] = client_sharded
        if shard_test:
            axis_size = 1
            for a in client_axes:
                axis_size *= mesh.shape[a]
            n = self.test_x.shape[0]
            pad = -n % axis_size
            if pad:
                from repro.utils.arrays import pad_rows_with_first

                out["test_x"] = jnp.asarray(
                    pad_rows_with_first(self.test_x, n + pad))
                out["test_y"] = jnp.asarray(
                    pad_rows_with_first(self.test_y, n + pad), jnp.int32)
            test_sharded = client_dim_sharding(mesh, client_axes, n + pad)
            shardings["test_x"] = shardings["test_y"] = test_sharded
        return jax.device_put(out, shardings)


def _dists(ys: np.ndarray, num_classes: int) -> np.ndarray:
    d = np.stack([np.bincount(y, minlength=num_classes) for y in ys]).astype(np.float32)
    return d / np.clip(d.sum(1, keepdims=True), 1, None)


def build_federated_data(
    *,
    num_clients: int = 100,
    server_fraction: float = 0.05,     # p
    server_niid: str = "iid",          # 'iid' | 'mild' | 'severe' (Fig. 6)
    device_pool: int = 40000,
    spec: SyntheticSpec | None = None,
    partition: str = "label_shard",    # or 'dirichlet'
    dirichlet_alpha: float = 0.5,
    seed: int = 0,
) -> FederatedData:
    spec = spec or SyntheticSpec()
    train_x, train_y, test_x, test_y = synthetic_classification(spec)
    device_pool = min(device_pool, len(train_x) - 1000)
    dev_x, dev_y = train_x[:device_pool], train_y[:device_pool]
    rest = np.arange(device_pool, len(train_x))

    if partition == "label_shard":
        idxs = part.label_shard_partition(dev_y, num_clients, seed=seed)
    elif partition == "dirichlet":
        idxs = part.dirichlet_partition(dev_y, num_clients, alpha=dirichlet_alpha, seed=seed)
        m = min(len(ix) for ix in idxs)          # equalize for the vmapped engine
        idxs = [ix[:m] for ix in idxs]
    else:
        raise ValueError(partition)

    client_x = np.stack([dev_x[ix] for ix in idxs])
    client_y = np.stack([dev_y[ix] for ix in idxs])

    n0 = max(1, int(server_fraction * device_pool))
    n0 = min(n0, len(rest))
    server_idx = part.server_subset(train_y, rest, n0, niid_target=server_niid, seed=seed + 7)
    server_y = train_y[server_idx]
    server_dist = np.bincount(server_y, minlength=spec.num_classes).astype(np.float32)
    server_dist /= server_dist.sum()

    return FederatedData(
        client_x=client_x,
        client_y=client_y,
        sizes=np.full(num_clients, client_x.shape[1], np.float32),
        client_dists=_dists(client_y, spec.num_classes),
        server_x=train_x[server_idx],
        server_y=server_y,
        server_dist=server_dist,
        test_x=test_x,
        test_y=test_y,
    )


def build_lm_federated_data(
    *,
    num_clients: int = 8,
    server_fraction: float = 0.05,     # p
    server_niid: str = "iid",
    test_fraction: float = 0.1,
    spec: TokenSpec | None = None,
    seed: int = 0,
) -> FederatedData:
    """The paper's Section-4.1 federated protocol transplanted to a
    NEXT-TOKEN corpus: each sequence's TOPIC plays the role of its label.

    * sequences are label-shard partitioned over ``num_clients`` by topic
      (2 topic shards each — the same skew protocol as the CIFAR repro,
      with equal n_k for the vmapped engine);
    * the server draws ``p`` of the device pool from the REMAINING
      sequences with a controllable topic non-IID degree (Formula 2's
      D(P_0) is the topic-distribution distance);
    * ``client_x``/``client_y`` are the [n_k, S-1] int32 next-token pairs
      ``(tokens[:-1], tokens[1:])`` — ``(x, y)`` batch tuples, so the
      executor backends, the sharding specs and the f64 oracle drive the
      LM through the exact code path the CNN uses.
    """
    spec = spec or TokenSpec()
    toks, topics = synthetic_tokens(spec)
    x, y = np.asarray(toks[:, :-1]), np.asarray(toks[:, 1:])

    n = toks.shape[0]
    n_test = max(1, int(test_fraction * n))
    train_n = n - n_test
    device_pool = max(num_clients, int(0.8 * train_n))
    device_pool = min(device_pool, train_n - 1)
    rest = np.arange(device_pool, train_n)

    idxs = part.label_shard_partition(topics[:device_pool], num_clients,
                                      seed=seed)
    client_ix = np.stack([ix for ix in idxs])

    n0 = max(1, int(server_fraction * device_pool))
    n0 = min(n0, len(rest))
    server_idx = part.server_subset(topics, rest, n0,
                                    niid_target=server_niid, seed=seed + 7)
    server_dist = np.bincount(topics[server_idx],
                              minlength=spec.num_topics).astype(np.float32)
    server_dist /= server_dist.sum()

    return FederatedData(
        client_x=x[client_ix],
        client_y=y[client_ix],
        sizes=np.full(num_clients, client_ix.shape[1], np.float32),
        client_dists=_dists(topics[client_ix], spec.num_topics),
        server_x=x[server_idx],
        server_y=y[server_idx],
        server_dist=server_dist,
        test_x=x[train_n:],
        test_y=y[train_n:],
    )
