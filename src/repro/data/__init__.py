from repro.data.pipeline import FederatedData, build_federated_data
from repro.data.partition import label_shard_partition, dirichlet_partition
from repro.data.synthetic import synthetic_classification, synthetic_tokens

__all__ = [
    "FederatedData",
    "build_federated_data",
    "label_shard_partition",
    "dirichlet_partition",
    "synthetic_classification",
    "synthetic_tokens",
]
