from repro.sharding.specs import (
    MeshPlan,
    make_plan,
    param_specs,
    batch_specs,
    cache_specs,
)

__all__ = ["MeshPlan", "make_plan", "param_specs", "batch_specs", "cache_specs"]
