"""PartitionSpecs for the FL train step's state and batch pytrees."""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.specs import MeshPlan, param_specs


def _axis(axes: tuple):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def fl_state_specs(state_shapes: Any, model_axes: Any, plan: MeshPlan, *,
                   client_axes: tuple = ()) -> Any:
    """Engine round state = {params, server_m, [global_m], [masks],
    [filter_masks], [client_state], round}: every momentum buffer — and the
    FedAP keep-masks of the static-shape masked mode
    (``EngineConfig.use_masks``) — mirrors the params' model sharding
    (TP/FSDP, replicated over client axes); the round counter is
    replicated.  The kernel-mode ``filter_masks`` slot (per-layer [d_l]
    vectors, a few KB) is fully replicated: every shard needs the whole
    block mask to decide which MXU blocks to skip.  Key-generic so the
    communicated-momentum (FedDA) state and the mask slots shard without
    special-casing.

    The ``client_state`` slot (FedProx/FedDyn) splits in two: leaves under
    ``per_client`` carry a LEADING num-clients dim and shard over
    ``client_axes`` exactly like the federated dataset (replicated when
    the dim does not divide the axis size — the production-safe fallback
    used throughout this module); leaves under ``shared`` are
    param-structured and follow the model placement.

    ``model_axes=None`` (the MeshBackend's simulation models, which publish
    no logical-axis tree) replicates every param-structured slot: on the
    simulation path the CLIENT axis of the batch is what shards over the
    mesh, and the global model rides replicated."""
    ca = _axis(client_axes)
    csize = plan.axis_size(client_axes) if client_axes else 1

    def per_client_spec(leaf):
        dim = leaf.shape[0] if len(leaf.shape) else 0
        if client_axes and dim % csize == 0:
            return P(ca)
        return P()

    def shared_spec(v):
        if model_axes is None:
            return jax.tree.map(lambda _: P(), v)
        return param_specs(v, model_axes, plan)

    def one(k, v):
        if k == "round":
            return P()
        if k == "client_state":
            return {"per_client": jax.tree.map(per_client_spec,
                                               v["per_client"]),
                    "shared": shared_spec(v["shared"])}
        if k == "filter_masks" or model_axes is None:
            return jax.tree.map(lambda _: P(), v)
        return param_specs(v, model_axes, plan)

    return {k: one(k, v) for k, v in state_shapes.items()}


def client_dim_sharding(mesh, client_axes: tuple, leading_dim: int):
    """NamedSharding for an array whose LEADING dim is the FL-client axis:
    sharded over ``client_axes`` when the dim divides the axis size,
    replicated otherwise (the production-safe fallback used throughout
    this module).  One implementation for every client-leading placement —
    the federated dataset (``FederatedData.device_arrays``) and the FedAP
    probe stack (``fedap_decision_sharded``) must never disagree."""
    from jax.sharding import NamedSharding

    size = 1
    for a in client_axes:
        size *= mesh.shape[a]
    if client_axes and leading_dim % size == 0:
        return NamedSharding(mesh, P(_axis(client_axes)))
    return NamedSharding(mesh, P())


def fl_sim_batch_specs(clients_per_round: int, plan: MeshPlan, *,
                       server_batch: int | None = None,
                       with_active: bool = False) -> dict:
    """PartitionSpecs for the SIMULATION path's round batch — the pytree
    built on device by ``engine.sample_round_batches``:

      client  (x [C, steps, b, ...], y [C, steps, b]) — C over the client
              axes (the per-client local-epoch vmap partitions over the
              mesh; the FedAvg einsum becomes per-shard partial sums + one
              all-reduce, inserted by GSPMD);
      sizes   [C] — alongside the client dim;
      server  (x [tau, b, ...], y [tau, b]) — with ``server_batch`` given,
              the PER-STEP batch dim b shards over the client axes, so each
              of the tau FedDU server-update steps (the Formula 4-7 scan in
              ``engine.round_core``) computes per-shard partial gradients +
              one GSPMD all-reduce instead of replicating the whole server
              step on every device; ``server_batch=None`` (or a
              non-divisible b) keeps it replicated;
      the non-IID scalars — replicated.

    A non-divisible ``clients_per_round`` falls back to replication, the
    production-safe default everywhere else in this module."""
    ca = _axis(plan.client_axes)
    size = plan.axis_size(plan.client_axes) if plan.client_axes else 1
    ok = bool(plan.client_axes) and clients_per_round % size == 0
    cspec = P(ca) if ok else P()
    sok = bool(plan.client_axes) and server_batch is not None \
        and server_batch % size == 0
    sspec = P(None, ca) if sok else P()
    specs = {
        "client": (cspec, cspec),
        "sizes": cspec,
        "server": (sspec, sspec),
        "d_round": P(),
        "d_server": P(),
        "n0": P(),
        # "sel" ([C] int32 selected-client ids) stays replicated: it indexes
        # the client_state's per-client leaves, whose gather/scatter GSPMD
        # resolves against their own (possibly client-sharded) placement.
        "sel": P(),
    }
    if with_active:
        # dropout indicator [C], alongside the client dim like "sizes"
        specs["active"] = cspec
    return specs


def fl_batch_partition_specs(batch_shapes: Any, plan: MeshPlan) -> Any:
    """batch = {client, server, sizes, d_round, d_server, n0}.

    client leaves  [C, steps, b_c, ...]: C over client axes, b_c over the
                   within-client batch axes (pod-silo archs).
    server leaves  [tau, b, ...]: b over every non-model axis (the server
                   update is data-parallel across the whole mesh).
    """
    ca = _axis(plan.client_axes)
    ba = _axis(plan.batch_axes)
    server_axes = plan.client_axes + plan.batch_axes
    sa = _axis(server_axes)

    def one_client(leaf, bdim):
        # client leaves: [C, steps, b_c, ...]; positions: [C, steps, P, b_c, S]
        nd = len(leaf.shape)
        parts = [None] * nd
        if plan.client_axes and leaf.shape[0] % plan.axis_size(plan.client_axes) == 0:
            parts[0] = ca
        if plan.batch_axes and nd > bdim and \
                leaf.shape[bdim] % plan.axis_size(plan.batch_axes) == 0:
            parts[bdim] = ba
        return P(*parts)

    def one_server(leaf, bdim=1):
        # server leaves: [tau, B, ...]; positions: [tau, P, B, S]
        nd = len(leaf.shape)
        parts = [None] * nd
        if nd > bdim and server_axes and \
                leaf.shape[bdim] % plan.axis_size(server_axes) == 0:
            parts[bdim] = sa
        return P(*parts)

    out = {
        "client": {k: one_client(v, 3 if k == "positions" else 2)
                   for k, v in batch_shapes["client"].items()},
        "server": {k: one_server(v, 2 if k == "positions" else 1)
                   for k, v in batch_shapes["server"].items()},
        "sizes": P(),
        "d_round": P(),
        "d_server": P(),
        "n0": P(),
    }
    for k in ("sel", "active"):
        if k in batch_shapes:
            out[k] = P()
    return out


def serve_batch_specs(batch_shapes: dict, plan: MeshPlan) -> dict:
    """Inference batches: batch dim over every non-model axis.
    Key-aware: 'positions' is [P, B, S] (batch at dim 1); all other leaves
    carry batch at dim 0."""
    axes = plan.client_axes + plan.batch_axes
    a = _axis(axes)

    def one(leaf, bdim):
        nd = len(leaf.shape)
        parts = [None] * nd
        if axes and nd > bdim and leaf.shape[bdim] % plan.axis_size(axes) == 0:
            parts[bdim] = a
        return P(*parts)

    return {k: one(v, 1 if k == "positions" else 0) for k, v in batch_shapes.items()}
