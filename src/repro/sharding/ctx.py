"""Activation-sharding context.

GSPMD propagates FSDP *parameter* shardings into activations unless told
otherwise (an embedding whose d_model dim is sharded over 'data' makes the
residual stream d-sharded and batch-REPLICATED — measured 16x memory blowup
on llama3-405b prefill; see EXPERIMENTS.md §Perf).  Production frameworks
pin the residual stream with with_sharding_constraint; models here call
:func:`constrain_batch` at block boundaries, and the launch layer decides
the actual axes via this context (models stay mesh-agnostic).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes: tuple):
    """Enable activation constraints while tracing (lower under this)."""
    tok = _ACTIVE.set((mesh, tuple(batch_axes)))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def constrain_batch(x, batch_dim: int = 0):
    """Pin dim ``batch_dim`` of ``x`` to the configured batch axes and leave
    every other dim unsharded-by-constraint (GSPMD may still refine)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, batch_axes = ctx
    if not batch_axes or x.ndim <= batch_dim:
        return x
    if x.shape[batch_dim] % _size(mesh, batch_axes) != 0:
        return x
    parts = [None] * x.ndim
    parts[batch_dim] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


def _size(mesh, axes):
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s
