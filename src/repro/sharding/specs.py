"""Logical-axis -> mesh PartitionSpec rules.

The mesh is (data=16, model=16) single-pod or (pod=2, data=16, model=16)
multi-pod (see repro.launch.mesh).  FL semantics determine the *client*
axis (DESIGN.md Section 4):

  * data-client archs (<= ~10B): clients live on 'data' (x 'pod' when
    multi-pod) — parameters carry a leading client dim sharded over those
    axes; TP shards head/ffn dims over 'model'.
  * pod-client archs (cross-silo giants): clients live on 'pod'; inside a
    silo parameters are FSDP-sharded over 'data' and TP-sharded over
    'model'.

Every mapping is divisibility-checked against the actual dim size; a
non-divisible dim falls back to replication (e.g. whisper's 12 heads on a
16-way model axis) — the production-safe default.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# logical axis -> candidate mesh-axis role
_TP_AXES = {"vocab", "heads", "kv_heads", "mlp", "expert_mlp", "experts", "ssm_inner"}
_FSDP_AXES = {"embed"}


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    multi_pod: bool
    client_axes: tuple          # mesh axes hosting FL clients
    fsdp_axes: tuple            # mesh axes for parameter FSDP
    tp_axes: tuple              # mesh axes for tensor parallelism
    batch_axes: tuple           # mesh axes sharding the within-client batch
    num_clients: int

    def axis_size(self, names: tuple) -> int:
        s = 1
        for n in names:
            s *= self.mesh.shape[n]
        return s


def make_plan(mesh: Mesh, cfg: ModelConfig) -> MeshPlan:
    multi_pod = "pod" in mesh.shape
    if cfg.fl_client_axis == "data":
        client_axes = ("pod", "data") if multi_pod else ("data",)
        fsdp_axes = ()
        batch_axes = ()
    elif cfg.fl_client_axis == "pod":
        client_axes = ("pod",) if multi_pod else ()
        fsdp_axes = ("data",) if cfg.fsdp else ()
        batch_axes = ("data",)
    else:
        client_axes = ()
        fsdp_axes = ("data",) if cfg.fsdp else ()
        batch_axes = ("data",) if not multi_pod else ("pod", "data")
    num_clients = 1
    for a in client_axes:
        num_clients *= mesh.shape[a]
    return MeshPlan(mesh=mesh, multi_pod=multi_pod, client_axes=client_axes,
                    fsdp_axes=fsdp_axes, tp_axes=("model",),
                    batch_axes=batch_axes, num_clients=num_clients)


# §Perf C1 note: jit input shardings must divide evenly, so non-divisible
# head counts are handled by WEIGHT-LEVEL padding at init (REPRO_PAD_HEADS
# in repro.models.layers.init_attention), not by relaxing this check.


def _divisible(dim: int, plan: MeshPlan, axes: tuple) -> bool:
    return dim % plan.axis_size(axes) == 0 if axes else True


def _shardable(name: str, dim: int, plan: MeshPlan, axes: tuple) -> bool:
    return _divisible(dim, plan, axes)


def _spec_for(shape: tuple, logical: tuple, plan: MeshPlan,
              *, client_leading: bool) -> P:
    """PartitionSpec for one tensor given its logical axis names."""
    parts: list = []
    used: set = set()
    offset = 0
    if client_leading:
        ca = tuple(a for a in plan.client_axes)
        if ca and _divisible(shape[0], plan, ca):
            parts.append(ca if len(ca) > 1 else ca[0])
            used.update(ca)
        else:
            parts.append(None)
        offset = 1
    for i, name in enumerate(logical):
        dim = shape[offset + i]
        target: Optional[tuple] = None
        if name in _TP_AXES:
            target = plan.tp_axes
        elif name in _FSDP_AXES and plan.fsdp_axes:
            target = plan.fsdp_axes
        if target and not used.intersection(target) and _shardable(name, dim, plan, target):
            parts.append(target if len(target) > 1 else target[0])
            used.update(target)
        else:
            parts.append(None)
    return P(*parts)


def param_specs(shapes: Any, axes: Any, plan: MeshPlan,
                *, client_leading: bool = False) -> Any:
    """PartitionSpec tree matching the param tree.

    shapes: pytree of ShapeDtypeStruct (or arrays); axes: logical-axis tree.
    client_leading: params carry a leading FL-client dim (the federated
    training state).
    """
    # axes-tree leaves are plain tuples (pytree nodes), so flatten the two
    # trees separately with parallel leaf orders and zip.
    s_leaves, s_def = jax.tree.flatten(shapes)
    a_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    if len(s_leaves) != len(a_leaves):
        raise ValueError(f"param/axes tree mismatch: {len(s_leaves)} vs {len(a_leaves)}")
    specs = [_spec_for(s.shape, ax, plan, client_leading=client_leading)
             for s, ax in zip(s_leaves, a_leaves)]
    return jax.tree.unflatten(s_def, specs)


def _tree_spec(tree: Any, fn) -> Any:
    return jax.tree.map(fn, tree)


def batch_specs(batch: Any, plan: MeshPlan, *, client_leading: bool = False) -> Any:
    """Shard the batch: leading client dim over client axes (if present),
    then the batch dim over batch_axes; everything else replicated."""
    def one(leaf):
        shp = leaf.shape
        parts: list = []
        i = 0
        if client_leading:
            ca = plan.client_axes
            ok = ca and shp[0] % plan.axis_size(ca) == 0
            parts.append((ca if len(ca) > 1 else ca[0]) if ok else None)
            i = 1
            # [C, steps, b, ...]: steps unsharded
            if len(shp) > 1:
                parts.append(None)
                i = 2
        ba = plan.batch_axes
        if i < len(shp) and ba and shp[i] % plan.axis_size(ba) == 0:
            parts.append(ba if len(ba) > 1 else ba[0])
            i += 1
        while i < len(shp):
            parts.append(None)
            i += 1
        return P(*parts[: len(shp)])

    # positions [P,B,S] have batch at dim 1 — handled specially by caller if
    # needed; here dim-0 heuristics suffice for dry-run coherence.
    return _tree_spec(batch, one)


def cache_specs(cache_shapes: Any, plan: MeshPlan, cfg: ModelConfig) -> Any:
    """KV caches: [L, B, S, KV, hd] -> batch over batch_axes (+client axes
    merged during inference), kv heads over model when divisible; SSM
    states analogous."""
    all_batch = tuple(a for a in (plan.client_axes + plan.batch_axes))

    def one(leaf):
        shp = leaf.shape
        nd = len(shp)
        parts = [None] * nd
        kvh = cfg.padded_num_kv_heads

        def fits(dim, axes):
            return axes and dim % plan.axis_size(axes) == 0

        if nd == 5:        # [L, B, S, KV, hd]
            if fits(shp[1], all_batch):
                parts[1] = all_batch if len(all_batch) > 1 else all_batch[0]
            if shp[3] == kvh and fits(shp[3], plan.tp_axes):
                parts[3] = plan.tp_axes[0]
        elif nd == 4:      # [B, S, KV, hd] or [L, B, ...] ssm
            if fits(shp[0], all_batch):
                parts[0] = all_batch if len(all_batch) > 1 else all_batch[0]
            elif fits(shp[1], all_batch):
                parts[1] = all_batch if len(all_batch) > 1 else all_batch[0]
            if shp[2] == kvh and fits(shp[2], plan.tp_axes):
                parts[2] = plan.tp_axes[0]
        elif nd >= 1:
            if fits(shp[0], all_batch):
                parts[0] = all_batch if len(all_batch) > 1 else all_batch[0]
            elif nd > 1 and fits(shp[1], all_batch):
                parts[1] = all_batch if len(all_batch) > 1 else all_batch[0]
        return P(*parts)

    return _tree_spec(cache_shapes, one)
