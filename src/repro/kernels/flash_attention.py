"""Flash attention (TPU Pallas): blocked online-softmax GQA attention.

Canonical TPU structure: grid (batch, q_heads, q_blocks, kv_blocks) with the
kv dimension innermost; running max / sum / accumulator live in VMEM scratch
and persist across the kv grid dimension.  GQA is handled IN THE INDEX MAP:
with the [g, kv] head ordering used by the models (head h = g * KV + kv),
the kv head for query head h is simply ``h % KV`` — no K/V replication.

Causal and sliding-window masks are applied per block pair.  Block shapes
are MXU-aligned (q/kv blocks multiples of 128 recommended; head_dim is the
lane dim).  VMEM working set per step:
  q (bq x hd) + k,v (bk x hd each) + acc (bq x hd f32) + p (bq x bk f32)
e.g. bq=bk=256, hd=128: ~0.6 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window, block_q: int,
                  block_k: int, num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # [bq, bk]

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones(s.shape, jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                            # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q [B, Sq, H, hd]; k/v [B, Skv, KV, hd] -> [B, Sq, H, hd].

    Requires Sq % block_q == 0 and Skv % block_k == 0 (callers pad).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    if h % kvh != 0:
        raise ValueError(
            f"flash_attention: query heads H={h} must be a multiple of "
            f"kv heads KV={kvh} (q {q.shape}, k {k.shape})")
    if sq % block_q != 0 or skv % block_k != 0:
        raise ValueError(
            f"flash_attention: Sq={sq} must be a multiple of "
            f"block_q={block_q} and Skv={skv} a multiple of "
            f"block_k={block_k}; callers pad "
            f"(q {q.shape}, k {k.shape})")
    sm_scale = 1.0 / math.sqrt(hd)
    nq, nk = sq // block_q, skv // block_k

    qt = q.transpose(0, 2, 1, 3)                    # [B, H, Sq, hd]
    kt = k.transpose(0, 2, 1, 3)                    # [B, KV, Skv, hd]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, q_, k_: (b_, h_ % kvh, k_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, q_, k_: (b_, h_ % kvh, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
