"""FedAP structured-pruning matmul (TPU Pallas).

``masked_matmul(x, w, block_mask)`` computes ``x @ w`` where ``block_mask``
([N / block_n] of 0/1) marks column blocks of ``w`` as pruned.  Pruned
blocks are SKIPPED on the MXU (``pl.when`` guards the dot), so structured
pruning's FLOP savings are realized with static shapes inside a live jit —
the mechanism FedAP uses between the pruning round and the re-jit to the
compacted model (DESIGN.md Section 3).

Block layout: grid (M/bm, N/bn, K/bk), K innermost, f32 accumulator in VMEM
scratch.  Mask granularity = bn (128-aligned, the MXU lane width), matching
FedAP's 128-aligned kept-filter counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _masked_mm_kernel(x_ref, w_ref, mask_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(2)
    keep = mask_ref[0] > 0

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(keep)
    def _mac():
        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = jnp.where(keep, acc_scr[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def masked_matmul(x, w, block_mask, *, block_m: int = 128, block_n: int = 128,
                  block_k: int = 128, interpret: bool = False):
    """x [M, K] @ w [K, N] with pruned column blocks skipped.

    block_mask: [N // block_n] float/int (1 = keep, 0 = pruned).
    """
    m, kdim = x.shape
    _, n = w.shape
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0
    assert block_mask.shape == (n // block_n,)
    nk = kdim // block_k

    kernel = functools.partial(_masked_mm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, jnp.asarray(block_mask))
