"""FedAP structured-pruning matmul (TPU Pallas), differentiable.

``masked_matmul(x, w, block_mask)`` computes ``x @ w`` where ``block_mask``
([N / block_n] of 0/1) marks column blocks of ``w`` as pruned.  Pruned
blocks are SKIPPED on the MXU (``pl.when`` guards the dot), so structured
pruning's FLOP savings are realized with static shapes inside a live jit —
the mechanism FedAP uses between the pruning round and the re-jit to the
compacted model (DESIGN.md Section 3).

The op carries a ``jax.custom_vjp``, so it is usable inside the TRAINING
engine (``EngineConfig.masked_compute="kernel"``), not just on the
eval/serving path.  The backward pass skips the same MXU work as the
forward:

  dx = dy @ w.T    — the pruned column blocks of ``w`` are ROW blocks of
                     ``w.T``; their contraction slices are skipped, which
                     is exact because the forward zeroed the matching
                     columns of the output (so any upstream cotangent on
                     them is discarded by the chain rule);
  dw = x.T @ dy    — pruned COLUMN blocks are skipped and their output
                     blocks are written as exact zeros (a pruned filter
                     receives an exactly-zero gradient, keeping mask-mode
                     training self-sustaining inside a compiled scan).

Block layout (all three kernels): contraction dim innermost, f32
accumulator in VMEM scratch.  Mask granularity = bn (128-aligned, the MXU
lane width), matching FedAP's 128-aligned kept-filter counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _masked_mm_kernel(x_ref, w_ref, mask_ref, o_ref, acc_scr, *, nk: int):
    """Forward: o[i, j] = sum_k x[i, k] @ w[k, j], skipped when block j is
    pruned (grid (M/bm, N/bn, K/bk), K innermost)."""
    ki = pl.program_id(2)
    keep = mask_ref[0] > 0

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(keep)
    def _mac():
        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = jnp.where(keep, acc_scr[...], 0.0).astype(o_ref.dtype)


def _masked_dx_kernel(dy_ref, w_ref, mask_ref, dx_ref, acc_scr, *, nn: int):
    """Backward-x: dx[i, j] = sum_n dy[i, n] @ w.T[n, j] with pruned ROW
    blocks of ``w.T`` (= pruned column blocks n of ``w``) skipped
    (grid (M/bm, K/bk, N/bn), N innermost).  Exact: the forward zeroed the
    pruned output columns, so their cotangent never contributes."""
    ni = pl.program_id(2)
    keep = mask_ref[0] > 0

    @pl.when(ni == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(keep)
    def _mac():
        # dy block [bm, bn] x w block [bk, bn] contracted on the N axis
        # == dy_blk @ w_blk.T, without materializing the transpose.
        acc_scr[...] += jax.lax.dot_general(
            dy_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())))

    @pl.when(ni == nn - 1)
    def _finish():
        dx_ref[...] = acc_scr[...].astype(dx_ref.dtype)


def _masked_dw_kernel(x_ref, dy_ref, mask_ref, dw_ref, acc_scr, *, nm: int):
    """Backward-w: dw[i, j] = sum_m x.T[i, m] @ dy[m, j] with pruned column
    blocks j skipped and their outputs written as EXACT zeros
    (grid (K/bk, N/bn, M/bm), M innermost)."""
    mi = pl.program_id(2)
    keep = mask_ref[0] > 0

    @pl.when(mi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(keep)
    def _mac():
        # x block [bm, bk] x dy block [bm, bn] contracted on the M axis
        # == x_blk.T @ dy_blk, without materializing the transpose.
        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), dy_ref[...].astype(jnp.float32),
            (((0,), (0,)), ((), ())))

    @pl.when(mi == nm - 1)
    def _finish():
        dw_ref[...] = jnp.where(keep, acc_scr[...], 0.0).astype(dw_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (blocks = (block_m, block_n, block_k, interpret))
# ---------------------------------------------------------------------------

def _fwd_call(blocks, x, w, block_mask):
    bm, bn, bk, interpret = blocks
    m, kdim = x.shape
    n = w.shape[1]
    nk = kdim // bk
    return pl.pallas_call(
        functools.partial(_masked_mm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, block_mask)


def _dx_call(blocks, dy, w, block_mask):
    bm, bn, bk, interpret = blocks
    m, n = dy.shape
    kdim = w.shape[0]
    nn = n // bn
    return pl.pallas_call(
        functools.partial(_masked_dx_kernel, nn=nn),
        grid=(m // bm, kdim // bk, nn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (j, k)),
            pl.BlockSpec((1,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, kdim), dy.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(dy, w, block_mask)


def _dw_call(blocks, x, dy, block_mask):
    bm, bn, bk, interpret = blocks
    m, kdim = x.shape
    n = dy.shape[1]
    nm = m // bm
    return pl.pallas_call(
        functools.partial(_masked_dw_kernel, nm=nm),
        grid=(kdim // bk, n // bn, nm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (k, i)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kdim, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, dy, block_mask)


# ---------------------------------------------------------------------------
# custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _masked_matmul(blocks, x, w, block_mask):
    return _fwd_call(blocks, x, w, block_mask)


def _masked_matmul_fwd(blocks, x, w, block_mask):
    return _fwd_call(blocks, x, w, block_mask), (x, w, block_mask)


def _masked_matmul_bwd(blocks, residuals, dy):
    x, w, block_mask = residuals
    dx = _dx_call(blocks, dy, w, block_mask)
    dw = _dw_call(blocks, x, dy, block_mask)
    return dx, dw, jnp.zeros_like(block_mask)


_masked_matmul.defvjp(_masked_matmul_fwd, _masked_matmul_bwd)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def masked_matmul(x, w, block_mask, *, block_m: int = 128, block_n: int = 128,
                  block_k: int = 128, interpret: bool = False):
    """x [M, K] @ w [K, N] with pruned column blocks skipped, differentiable.

    block_mask: [N // block_n] float/int (1 = keep, 0 = pruned).

    Shape/alignment preconditions raise ``ValueError`` at trace time (not
    ``assert``: they must survive ``python -O`` and name the offending
    shapes).
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"masked_matmul expects 2-D operands, got "
                         f"x.shape={x.shape} w.shape={w.shape}")
    m, kdim = x.shape
    k2, n = w.shape
    if kdim != k2:
        raise ValueError(f"masked_matmul contraction mismatch: x.shape="
                         f"{x.shape} vs w.shape={w.shape} (K {kdim} != {k2})")
    if m % block_m or n % block_n or kdim % block_k:
        raise ValueError(
            f"masked_matmul shapes must be block-aligned: x.shape={x.shape} "
            f"w.shape={w.shape} vs blocks (block_m={block_m}, "
            f"block_n={block_n}, block_k={block_k}); pad M (see "
            f"repro.models.cnn.masked_dense) or pick divisible blocks")
    block_mask = jnp.asarray(block_mask, jnp.float32)
    if block_mask.shape != (n // block_n,):
        raise ValueError(
            f"masked_matmul block_mask must have shape (N // block_n,) = "
            f"({n // block_n},), got {block_mask.shape} for w.shape={w.shape} "
            f"block_n={block_n}")
    return _masked_matmul((block_m, block_n, block_k, interpret),
                          x, w, block_mask)
