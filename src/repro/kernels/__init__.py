"""Pallas TPU kernels for the perf-critical compute layers.

  flash_attention — blocked online-softmax GQA attention (train/prefill)
  decode_attention — flash-decode vs. KV cache (decode_32k / long_500k)
  ssd_scan        — Mamba2 SSD chunked scan (zamba2)
  masked_matmul   — FedAP structured-pruning block-skip matmul

Each kernel ships with a pure-jnp oracle in ref.py; tests sweep
shapes/dtypes in interpret mode and assert allclose.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
