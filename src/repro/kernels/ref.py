"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q [B,Sq,H,hd], k/v [B,Skv,KV,hd] — [g, kv] head grouping."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, g, kvh, hd).astype(jnp.float32)
    scores = jnp.einsum("bqgkd,bskd->bgkqs", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    scores = jnp.where(ok, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgkqs,bskd->bqgkd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths=None):
    """q [B,1,H,hd], cache k/v [B,S,KV,hd].  ``lengths`` (int32 [B]),
    when given, limits attention to each sequence's first ``lengths[b]``
    cache slots (continuous batching); otherwise every slot is attended."""
    b, _, h, hd = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, g, kvh, hd).astype(jnp.float32)
    scores = jnp.einsum("bqgkd,bskd->bgkqs", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    if lengths is not None:
        valid = jnp.arange(s_len)[None, :] < lengths[:, None]       # [B, S]
        scores = jnp.where(valid[:, None, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgkqs,bskd->bqgkd", w, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def ssd_scan_ref(x, bmat, cmat, dt, a_log, d, dt_bias):
    """Naive sequential SSD recurrence (the definition)."""
    bsz, s, nh, p = x.shape
    n = bmat.shape[-1]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)
    a = jnp.exp(-dtv * jnp.exp(a_log))
    xf = x.astype(jnp.float32)

    def body(h, t):
        xt, bt, ct, at, dtt = t
        h = h * at[..., None, None] + jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((bsz, nh, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        body, h0,
        (xf.transpose(1, 0, 2, 3), bmat.astype(jnp.float32).transpose(1, 0, 2),
         cmat.astype(jnp.float32).transpose(1, 0, 2), a.transpose(1, 0, 2),
         dtv.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3) + xf * d[:, None]
    return y.astype(x.dtype)


def masked_matmul_ref(x, w, block_mask, *, block_n: int = 128):
    """x @ w with pruned column blocks zeroed."""
    full_mask = jnp.repeat(jnp.asarray(block_mask, jnp.float32), block_n)
    out = x.astype(jnp.float32) @ w.astype(jnp.float32)
    return (out * full_mask[None, :]).astype(x.dtype)


def masked_matmul_fwd_ref64(x, w, block_mask, *, block_n: int = 128):
    """Float64 NumPy forward oracle: y = (x @ w) * column-block mask."""
    import numpy as np

    x64 = np.asarray(x, np.float64)
    w64 = np.asarray(w, np.float64)
    full_mask = np.repeat(np.asarray(block_mask, np.float64), block_n)
    return (x64 @ w64) * full_mask[None, :]


def masked_matmul_vjp_ref64(x, w, block_mask, dy, *, block_n: int = 128):
    """Float64 NumPy backward oracle for ``masked_matmul``.

    The primal is y = (x @ w) * m with m the expanded column-block mask, so

        dx = (dy * m) @ w.T
        dw = x.T @ (dy * m)     — pruned column blocks of dw exactly zero.

    Returns (dx, dw) in float64.
    """
    import numpy as np

    x64 = np.asarray(x, np.float64)
    w64 = np.asarray(w, np.float64)
    full_mask = np.repeat(np.asarray(block_mask, np.float64), block_n)
    dym = np.asarray(dy, np.float64) * full_mask[None, :]
    return dym @ w64.T, x64.T @ dym
