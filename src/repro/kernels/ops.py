"""Jit'd public wrappers for the Pallas kernels.

On a real TPU runtime these dispatch to the compiled kernels; in this
container (CPU) they run in interpret mode when ``REPRO_PALLAS_INTERPRET``
is set (the tests set it), and the model layers only route here when
``attn_impl='pallas'`` is requested.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.masked_matmul import masked_matmul as _masked_mm
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=256):
    sq, skv = q.shape[1], k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window, block_q=bq,
                  block_k=bk, interpret=_interpret())


def decode_attention(q, k, v, lengths=None, *, block_k=512):
    s = k.shape[1]
    bk = min(block_k, s)
    if s % bk:
        return ref.decode_attention_ref(q, k, v, lengths)
    return _decode(q, k, v, lengths, block_k=bk, interpret=_interpret())


def ssd_scan(x, bmat, cmat, dt, a_log, d, dt_bias, *, chunk=128):
    s = x.shape[1]
    ch = min(chunk, s)
    if s % ch:
        return ref.ssd_scan_ref(x, bmat, cmat, dt, a_log, d, dt_bias)
    return _ssd(x, bmat, cmat, dt, a_log, d, dt_bias, chunk=ch,
                interpret=_interpret())


def masked_matmul(x, w, block_mask, *, block_m=128, block_n=128, block_k=128):
    return _masked_mm(x, w, block_mask, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=_interpret())
