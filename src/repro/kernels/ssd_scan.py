"""Mamba2 SSD chunked scan (TPU Pallas).

Recurrence per head:  H_t = a_t H_{t-1} + (dt_t x_t) B_t^T,  y_t = C_t H_t + D x_t
with a_t = exp(-softplus(dt_t + bias) * exp(A_log)).

Grid (batch, chunks), chunk dimension SEQUENTIAL: the carried state
H [nh, p, N] lives in VMEM scratch and persists across chunk steps — the
Pallas analogue of the chunk-level lax.scan in the reference.  Within a
chunk everything is dense matmul work (MXU): the intra-chunk decay matrix
[chunk, chunk] and two dot_generals.

VMEM per step (chunk=256, nh=32, p=64, N=64):
  x (256 x 2048) + B,C (256 x 64) + decay (256 x 256 x nh f32 — dominant)
The decay tensor is materialized per head-group to stay under VMEM; this
kernel keeps it whole for clarity (nh <= 48 fits at chunk 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref, d_ref, bias_ref,
                o_ref, h_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)               # [chunk, nh, p]
    bm = b_ref[0].astype(jnp.float32)              # [chunk, N]
    cm = c_ref[0].astype(jnp.float32)              # [chunk, N]
    dt = dt_ref[0].astype(jnp.float32)             # [chunk, nh]
    a_log = alog_ref[...]                          # [nh]
    d = d_ref[...]                                 # [nh]
    bias = bias_ref[...]                           # [nh]

    dtv = jax.nn.softplus(dt + bias)               # [chunk, nh]
    la = -dtv * jnp.exp(a_log)                     # log a_t  [chunk, nh]
    xs = x * dtv[..., None]                        # [chunk, nh, p]

    cum = jnp.cumsum(la, axis=0)                   # [chunk, nh]
    total = cum[-1]                                # [nh]
    # intra-chunk: y_i += sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) xs_j
    li = cum[:, None, :]                           # [i, 1, nh]
    lj = cum[None, :, :]                           # [1, j, nh]
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))[:, :, None]
    # mask INSIDE the exp: for j > i the exponent is positive and large
    # (cum is decreasing), and exp(+big) * 0 would be inf * 0 = NaN.
    decay = jnp.exp(jnp.where(mask > 0, li - lj, -1e30))  # [i, j, nh]
    inner = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # [i, j]
    w = inner[:, :, None] * decay                  # [i, j, nh]
    y_intra = jnp.einsum("ijh,jhp->ihp", w, xs)
    # carried state contribution: y_i += C_i (exp(cum_i) * H)
    carried = jnp.exp(cum)[:, :, None, None] * h_scr[...][None]    # [i, nh, p, N]
    y_carry = jnp.einsum("in,ihpn->ihp", cm, carried)
    # state update: H' = exp(total) H + sum_j exp(total - cum_j) xs_j B_j^T
    decay_end = jnp.exp(total[None] - cum)         # [j, nh]
    h_new = h_scr[...] * jnp.exp(total)[:, None, None] + jnp.einsum(
        "jhp,jn,jh->hpn", xs, bm, decay_end)
    h_scr[...] = h_new

    o_ref[0] = (y_intra + y_carry + x * d[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, bmat, cmat, dt, a_log, d, dt_bias, *, chunk: int = 128,
             interpret: bool = False):
    """x [B,S,nh,p], bmat/cmat [B,S,N], dt [B,S,nh] -> y [B,S,nh,p]."""
    bsz, s, nh, p = x.shape
    n = bmat.shape[-1]
    if s % chunk != 0:
        raise ValueError(
            f"ssd_scan: sequence length S={s} must be a multiple of "
            f"chunk={chunk} (x {x.shape})")
    nchunk = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nchunk),
        in_specs=[
            pl.BlockSpec((1, chunk, nh, p), lambda b_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, nh), lambda b_, c_: (b_, c_, 0)),
            pl.BlockSpec((nh,), lambda b_, c_: (0,)),
            pl.BlockSpec((nh,), lambda b_, c_: (0,)),
            pl.BlockSpec((nh,), lambda b_, c_: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, nh, p), lambda b_, c_: (b_, c_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, nh, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((nh, p, n), jnp.float32)],
        interpret=interpret,
    )(x, bmat, cmat, dt, a_log, d, dt_bias)
