"""Flash-decode (TPU Pallas): single-token GQA attention vs. a KV cache.

One new query token per sequence attends over a [S, hd] cache per kv head.
Grid (batch, kv_heads, kv_blocks): each kv head processes its G grouped
query heads at once (q block [G, hd] — rows = grouped heads, MXU-friendly),
with the online-softmax state in VMEM scratch persisting over kv blocks.

This is the decode_32k / long_500k hot loop: memory-bound (the whole cache
streams through VMEM once), so block_k is chosen large (512) to amortize
grid overhead against the 819 GB/s HBM stream.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, sm_scale: float, num_kv_blocks: int, block_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)             # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # [G, bk]

    # Continuous batching: only this sequence's first ``lengths[b]`` cache
    # slots are valid (later slots belong to a PREVIOUS occupant of the
    # decode slot, or were never written).
    pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    valid = pos < len_ref[0]                         # [1, bk]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # Re-mask after the exp: with m_new == NEG_INF (no valid slot seen yet)
    # exp(NEG_INF - NEG_INF) == 1 would credit masked slots with softmax
    # mass; the where keeps l/acc exactly zero until a valid block arrives.
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, lengths=None, *, block_k: int = 512,
                     interpret: bool = False):
    """q [B, 1, H, hd]; k/v cache [B, S, KV, hd] -> [B, 1, H, hd].

    ``lengths`` (optional, int32 [B]) is the per-sequence count of valid
    cache slots: slot ``i`` is attended iff ``i < lengths[b]`` — the
    continuous-batching contract, where each decode slot's cache page
    holds a different request at a different fill level.  Without it all
    S slots are attended (ring-buffer serving, every slot valid).
    Requires S % block_k == 0.
    """
    b, one, h, hd = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    if one != 1:
        raise ValueError(
            f"decode_attention: q must carry a single decode step, got "
            f"q {q.shape} (expected [B, 1, H, hd])")
    if h % kvh != 0:
        raise ValueError(
            f"decode_attention: query heads H={h} must be a multiple of "
            f"kv heads KV={kvh} (q {q.shape}, k {k.shape})")
    if s_len % block_k != 0:
        raise ValueError(
            f"decode_attention: cache length S={s_len} must be a multiple "
            f"of block_k={block_k} (k {k.shape})")
    if lengths is not None and lengths.shape != (b,):
        raise ValueError(
            f"decode_attention: lengths must be [B]={b} valid-slot counts, "
            f"got {lengths.shape}")
    g = h // kvh
    sm_scale = 1.0 / math.sqrt(hd)
    nk = s_len // block_k

    # head h = g_idx * KV + kv  ->  group by kv head: [B, KV, G, hd]
    qt = q[:, 0].reshape(b, g, kvh, hd).transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)                    # [B, KV, S, hd]
    vt = v.transpose(0, 2, 1, 3)
    # No lengths -> every slot valid; an S-filled vector keeps the kernel
    # single-program (the mask where() is the identity at full length).
    lens = (jnp.full((b,), s_len, jnp.int32) if lengths is None
            else lengths.astype(jnp.int32))

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               num_kv_blocks=nk, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, j_, k_: (b_,)),
            pl.BlockSpec((1, 1, g, hd), lambda b_, j_, k_: (b_, j_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, j_, k_: (b_, j_, k_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, j_, k_: (b_, j_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, j_, k_: (b_, j_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qt, kt, vt)
    # [B, KV, G, hd] -> [B, 1, H, hd] with h = g_idx * KV + kv
    return out.transpose(0, 2, 1, 3).reshape(b, 1, h, hd)
