"""Pytree arithmetic used throughout the FL core.

Every FL algorithm in this repo manipulates whole parameter pytrees
(weights, momenta, pseudo-gradients).  These helpers keep that code
readable and ensure dtype discipline (accumulation in the leaf dtype,
explicit casts only via ``tree_cast``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_weighted_mean(trees, weights):
    """Weighted mean of a list of pytrees. ``weights`` is a 1-D array-like;
    it is normalized internally (FedAvg's n_k / n')."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def _combine(*leaves):
        acc = leaves[0] * w[0].astype(leaves[0].dtype)
        for i, leaf in enumerate(leaves[1:], start=1):
            acc = acc + leaf * w[i].astype(leaf.dtype)
        return acc

    return jax.tree.map(_combine, *trees)


def tree_dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def tree_norm(a):
    return jnp.sqrt(
        sum(jax.tree.leaves(jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)))
    )


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_size(a) -> int:
    """Total number of scalar parameters in the pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))
