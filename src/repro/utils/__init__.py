from repro.utils.arrays import pad_rows_with_first
from repro.utils.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_zeros_like,
    tree_weighted_mean,
    tree_dot,
    tree_norm,
    tree_cast,
    tree_size,
)

__all__ = [
    "pad_rows_with_first",
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_zeros_like",
    "tree_weighted_mean",
    "tree_dot",
    "tree_norm",
    "tree_cast",
    "tree_size",
]
