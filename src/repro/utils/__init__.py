from repro.utils.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_zeros_like,
    tree_weighted_mean,
    tree_dot,
    tree_norm,
    tree_cast,
    tree_size,
)

__all__ = [
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_zeros_like",
    "tree_weighted_mean",
    "tree_dot",
    "tree_norm",
    "tree_cast",
    "tree_size",
]
