"""Host-side (NumPy) array plumbing shared across layers.

Kept separate from :mod:`repro.utils.tree` (device pytree arithmetic):
these helpers run at data-placement / decision time on the host.
"""
from __future__ import annotations

import numpy as np


def pad_rows_with_first(a: np.ndarray, target_rows: int) -> np.ndarray:
    """Pad ``a`` along axis 0 to ``target_rows`` with copies of row 0.

    The canonical padding of every "pad then mask/correct the pad back
    out" path in this repo — the mesh-sharded test split
    (`FederatedData.device_arrays`: the eval program subtracts the padded
    rows' row-0 contribution exactly) and the ragged FedAP probe stack
    (`fedap_decision_sharded`: padded rows are masked out of the
    Fisher/Lipschitz statistics).  Row 0 (not zeros) keeps the padded
    rows numerically well-behaved through any model forward.  ``a`` must
    be non-empty; ``target_rows`` must be >= ``len(a)``.
    """
    a = np.asarray(a)
    if a.shape[0] == 0:
        raise ValueError("cannot pad an empty array with copies of row 0")
    pad = target_rows - a.shape[0]
    if pad < 0:
        raise ValueError(
            f"target_rows={target_rows} < existing rows {a.shape[0]}")
    if pad == 0:
        return a
    return np.concatenate(
        [a, np.broadcast_to(a[:1], (pad,) + a.shape[1:])])
