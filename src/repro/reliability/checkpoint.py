"""Durable chunk-boundary run checkpoints — atomic write, exact resume.

A checkpoint captures EVERYTHING the :class:`~repro.core.backend.
PlanExecutor` needs to continue a killed run bit-identically: the engine
round state, the scan key chain (as raw PRNG key data), the plan cursor
(index into ``plan.compiled()``), the completed-round/chunk counters, the
history rows and artifacts accumulated so far, the run's ``init_params``
(the Lipschitz reference of later Prune events), and a serialized plan
spec so ``FederatedTrainer.resume(dir)`` can rebuild the schedule without
out-of-band knowledge.

Durability protocol (crash-safe at every point):

1. the payload is written into a hidden temp directory
   (``.tmp-step-NNNN``) — ``arrays.npz`` (every array leaf, '/'-joined
   pytree paths) + ``meta.json`` (the JSON skeleton), both fsynced;
2. the temp directory is renamed to ``step-NNNN`` with ``os.replace``
   semantics (atomic on POSIX);
3. the ``LATEST`` pointer file is updated via its own temp-file +
   ``os.replace``.

A crash mid-write leaves either a stale ``LATEST`` (pointing at the last
complete snapshot) or a dangling ``.tmp-*`` directory, both of which
:func:`load_checkpoint` ignores; it never sees a half-written snapshot.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import zipfile
from typing import Any

import numpy as np

from repro.core.plan import (
    Callback,
    CheckpointError,
    Eval,
    Prune,
    Scan,
    Snapshot,
    TrainPlan,
)

RUN_FORMAT = "repro-run-checkpoint-v1"


# ---------------------------------------------------------------------------
# Generic (skeleton, arrays) split — artifacts mix arrays, scalars, strings


def _encode(obj: Any, path: str, arrays: dict) -> Any:
    """Split a mixed pytree into a JSON skeleton + a flat array dict
    (npz keys are '/'-joined paths into the structure)."""
    if isinstance(obj, dict):
        enc = {}
        for k, v in obj.items():
            k = str(k)
            if "/" in k:
                raise CheckpointError(
                    f"checkpoint keys may not contain '/': {k!r}")
            enc[k] = _encode(v, f"{path}/{k}", arrays)
        return {"__dict__": enc}
    if isinstance(obj, (list, tuple)):
        return {"__seq__": [_encode(v, f"{path}/{i}", arrays)
                            for i, v in enumerate(obj)],
                "tuple": isinstance(obj, tuple)}
    if hasattr(obj, "ndim") and hasattr(obj, "dtype"):   # np/jnp array leaf
        arrays[path] = np.asarray(obj)
        return {"__array__": path}
    if isinstance(obj, (np.generic,)):
        obj = obj.item()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return {"__value__": obj}
    raise CheckpointError(
        f"cannot checkpoint {type(obj).__name__} at {path!r}")


def _decode(skel: Any, arrays: dict) -> Any:
    if "__dict__" in skel:
        return {k: _decode(v, arrays) for k, v in skel["__dict__"].items()}
    if "__seq__" in skel:
        seq = [_decode(v, arrays) for v in skel["__seq__"]]
        return tuple(seq) if skel.get("tuple") else seq
    if "__array__" in skel:
        try:
            return arrays[skel["__array__"]]
        except KeyError as e:
            raise CheckpointError(
                f"checkpoint arrays.npz is missing {skel['__array__']!r} "
                f"(partial or corrupted snapshot)") from e
    return skel["__value__"]


# ---------------------------------------------------------------------------
# Plan (de)serialization — the resume path rebuilds the schedule


def plan_spec(plan: TrainPlan) -> list[dict]:
    """A JSON-able description of the plan's events.  Callback events
    record only their name — a function cannot round-trip through a
    checkpoint, so resuming a Callback plan requires passing the plan
    object back to ``resume`` (validated against this spec)."""
    spec = []
    for e in plan.events:
        if isinstance(e, Scan):
            spec.append({"type": "Scan", "rounds": e.rounds})
        elif isinstance(e, Eval):
            spec.append({"type": "Eval", "name": e.name})
        elif isinstance(e, Prune):
            spec.append({"type": "Prune", "mode": e.mode, "name": e.name,
                         "reuse": e.reuse})
        elif isinstance(e, Snapshot):
            spec.append({"type": "Snapshot", "name": e.name})
        elif isinstance(e, Callback):
            spec.append({"type": "Callback", "name": e.name})
        else:  # pragma: no cover — TrainPlan validates event types
            raise TypeError(f"unknown plan event: {e!r}")
    return spec


def plan_from_spec(spec: list[dict], *, checkpoint_every: int | None = None,
                   checkpoint_dir=None) -> TrainPlan:
    """Rebuild a TrainPlan from :func:`plan_spec` output.  Callback
    events cannot be reconstructed — raises :class:`CheckpointError`
    telling the caller to pass the original plan to ``resume``."""
    events = []
    for s in spec:
        t = s.get("type")
        if t == "Scan":
            events.append(Scan(s["rounds"]))
        elif t == "Eval":
            events.append(Eval(name=s["name"]))
        elif t == "Prune":
            events.append(Prune(mode=s["mode"], name=s["name"],
                                reuse=s.get("reuse")))
        elif t == "Snapshot":
            events.append(Snapshot(name=s["name"]))
        elif t == "Callback":
            raise CheckpointError(
                f"the checkpointed plan contains a Callback event "
                f"({s.get('name')!r}) whose function cannot be restored "
                f"from disk — pass the original plan: "
                f"trainer.resume(dir, plan=plan)")
        else:
            raise CheckpointError(f"unknown event type in checkpoint "
                                  f"plan spec: {t!r}")
    return TrainPlan(events, checkpoint_every=checkpoint_every,
                     checkpoint_dir=checkpoint_dir)


# ---------------------------------------------------------------------------
# Atomic write / load


def _fsync_write(path: pathlib.Path, write_fn) -> None:
    with open(path, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())


def save_checkpoint(directory, payload: dict) -> pathlib.Path:
    """Atomically persist one executor snapshot; returns the snapshot
    directory (``step-NNNN``, NNNN = the plan cursor).  ``payload`` must
    carry ``cursor`` plus whatever mixed pytrees the executor resumes
    from — the split into arrays and JSON is structural, not schema'd."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    name = f"step-{int(payload['cursor']):04d}"
    tmp = d / f".tmp-{name}-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    arrays: dict = {}
    skel = _encode(payload, "", arrays)
    _fsync_write(tmp / "arrays.npz",
                 lambda f: np.savez(f, **arrays))
    meta = {"format": RUN_FORMAT, "payload": skel}
    _fsync_write(tmp / "meta.json",
                 lambda f: f.write(json.dumps(meta, indent=2).encode()))

    final = d / name
    if final.exists():               # same-cursor overwrite (re-run)
        shutil.rmtree(final)
    os.replace(tmp, final)
    ptr_tmp = d / ".LATEST.tmp"
    _fsync_write(ptr_tmp, lambda f: f.write(name.encode()))
    os.replace(ptr_tmp, d / "LATEST")
    return final


def latest_checkpoint(directory) -> pathlib.Path | None:
    """The snapshot directory ``LATEST`` points at, or None if the
    directory holds no complete checkpoint yet."""
    d = pathlib.Path(directory)
    ptr = d / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    step = d / name
    if not (step / "meta.json").exists():
        return None
    return step


def load_checkpoint(path) -> dict:
    """Load a run checkpoint: ``path`` is either a checkpoint root (the
    ``LATEST`` pointer is followed) or a single ``step-NNNN`` snapshot.
    Partial, mismatched-format or corrupted snapshots raise
    :class:`CheckpointError` instead of a raw KeyError/zip crash."""
    p = pathlib.Path(path)
    if not (p / "meta.json").exists():
        step = latest_checkpoint(p)
        if step is None:
            raise CheckpointError(
                f"{p}: no run checkpoint found (no LATEST pointer and no "
                f"meta.json — was the run configured with "
                f"checkpoint_dir?)")
        p = step
    try:
        with open(p / "meta.json") as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"{p}: unreadable meta.json ({e})") from e
    if meta.get("format") != RUN_FORMAT:
        raise CheckpointError(
            f"{p}: not a {RUN_FORMAT} checkpoint "
            f"(format={meta.get('format')!r})")
    arrays_path = p / "arrays.npz"
    if not arrays_path.exists():
        raise CheckpointError(f"{p}: partial checkpoint (missing "
                              f"arrays.npz)")
    try:
        with np.load(arrays_path) as z:
            arrays = {k: z[k] for k in z.files}
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointError(f"{p}: corrupted arrays.npz ({e})") from e
    return _decode(meta["payload"], arrays)
