"""Deterministic fault injection — every recovery claim gets a repro.

A :class:`FaultPlan` is an ordered, hashable tuple of fault events that
threads through TEST-ONLY hooks at three levels of the stack:

* **device faults** (:class:`NaNGrad`, :class:`CorruptUpdate`) rewrite a
  matching client's uploaded model update *inside* ``round_core`` — the
  fault is part of the traced graph (a static unroll over the fault
  tuple), so it fires deterministically at the configured (client, round)
  under jit, scan, and the mesh backend alike, and the in-scan health
  guard (``EngineConfig.guard``) is exercised by exactly the corruption
  the test asked for.  Each fault mirrors itself in NumPy float64
  (``ref_apply_client``) so the oracle in :mod:`repro.core.ref_engine`
  sees the same corrupted uploads;
* **host faults** (:class:`KillAfterChunk`) fire in the
  :class:`~repro.core.backend.PlanExecutor` schedule loop, raising
  :class:`SimulatedCrash` AFTER the chunk-boundary checkpoint write — the
  resume-bit-identity tests kill a run exactly where a real preemption
  would land;
* **serving faults** (:class:`NaNLogits`) poison one decode slot's
  logits inside the wave program, driving the engine's non-finite-logit
  slot retirement.

Faults are frozen dataclasses (hashable), so a device-fault tuple can
ride in the frozen :class:`~repro.core.engine.EngineConfig` that keys the
session compile cache.
"""
from __future__ import annotations

import dataclasses


class SimulatedCrash(RuntimeError):
    """Raised by the executor when a :class:`KillAfterChunk` fault fires.

    The crash is injected AFTER the chunk's checkpoint write (exactly like
    a preemption between chunks), so ``FederatedTrainer.resume`` can
    continue the run from the snapshot on disk."""


class FaultPlan(tuple):
    """An ordered, hashable collection of fault events.

    ``FaultPlan(NaNGrad(client=3, round=5), KillAfterChunk(2))`` — pass it
    (or a plain tuple) as ``FLConfig(faults=...)``; the trainer routes
    device faults into the engine config and host faults into the
    executor."""

    def __new__(cls, *faults):
        return super().__new__(cls, faults)

    @property
    def device(self) -> tuple:
        return tuple(f for f in self if hasattr(f, "apply_client"))

    @property
    def host(self) -> tuple:
        return tuple(f for f in self if hasattr(f, "chunks"))


def _bcast(v, leaf):
    """Broadcast a [C] vector over a [C, ...] leaf."""
    return v.reshape(v.shape + (1,) * (leaf.ndim - 1))


@dataclasses.dataclass(frozen=True)
class NaNGrad:
    """Client ``client``'s uploaded update becomes all-NaN at global round
    ``round`` (matched against the scan carry's round counter and the
    sampled ``batch["sel"]`` indices — the client must be selected that
    round for the fault to land)."""

    client: int
    round: int

    def apply_client(self, locals_, params, sel, round_):
        import jax
        import jax.numpy as jnp

        hit = (sel == self.client) & (round_ == float(self.round))
        return jax.tree.map(
            lambda l: jnp.where(_bcast(hit, l), jnp.float32(jnp.nan),
                                l).astype(l.dtype), locals_)

    def ref_apply_client(self, locals_, params, sel, round_):
        import jax
        import numpy as np

        out = []
        for c, tree in enumerate(locals_):
            if int(sel[c]) == self.client and round_ == float(self.round):
                tree = jax.tree.map(lambda l: np.full_like(l, np.nan), tree)
            out.append(tree)
        return out


@dataclasses.dataclass(frozen=True)
class CorruptUpdate:
    """Scale a client's update delta around the broadcast round-start
    model: ``theta_k <- theta_global + scale * (theta_k - theta_global)``.
    ``client=None`` / ``round=None`` match every client / every round.
    Large scales (e.g. 1e6) model a diverged or byzantine upload that is
    still finite in f32 — the guard catches it only once it overflows
    downstream, which is exactly the scenario worth testing."""

    scale: float = 1e6
    client: int | None = None
    round: int | None = None

    def _hit(self, sel, round_, ones):
        hit = ones
        if self.client is not None:
            hit = hit & (sel == self.client)
        if self.round is not None:
            hit = hit & (round_ == float(self.round))
        return hit

    def apply_client(self, locals_, params, sel, round_):
        import jax
        import jax.numpy as jnp

        hit = self._hit(sel, round_, jnp.ones(sel.shape, bool))
        return jax.tree.map(
            lambda l, p: jnp.where(
                _bcast(hit, l),
                p.astype(jnp.float32) + self.scale
                * (l.astype(jnp.float32) - p.astype(jnp.float32)),
                l.astype(jnp.float32)).astype(l.dtype),
            locals_, params)

    def ref_apply_client(self, locals_, params, sel, round_):
        import jax
        import numpy as np

        np_hit = self._hit(np.asarray(sel), round_,
                           np.ones(np.shape(sel), bool))
        out = []
        for c, tree in enumerate(locals_):
            if np_hit[c]:
                tree = jax.tree.map(lambda l, p: p + self.scale * (l - p),
                                    tree, params)
            out.append(tree)
        return out


@dataclasses.dataclass(frozen=True)
class KillAfterChunk:
    """Host fault: the executor raises :class:`SimulatedCrash` once
    ``chunks`` Scan chunks have completed (counted over the WHOLE run, so
    a resumed run that restored ``chunks_done > chunks`` does not re-die).
    The chunk-boundary checkpoint (if configured) is written first."""

    chunks: int

    def __post_init__(self):
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")


@dataclasses.dataclass(frozen=True)
class NaNLogits:
    """Serving fault: slot ``slot``'s logits become NaN on the decode step
    where its emitted-token count equals ``n_out`` (fires at most once per
    occupancy — after retirement the error bit is cleared on admit)."""

    slot: int
    n_out: int = 0

    def apply_logits(self, logits, state):
        import jax.numpy as jnp

        hit = ((jnp.arange(logits.shape[0]) == self.slot)
               & (state["n_out"] == self.n_out) & state["active"])
        return jnp.where(hit[:, None, None], jnp.float32(jnp.nan),
                         logits.astype(jnp.float32)).astype(logits.dtype)


def device_faults(faults) -> tuple:
    """The subset of ``faults`` that runs inside ``round_core``."""
    return tuple(f for f in (faults or ()) if hasattr(f, "apply_client"))


def host_faults(faults) -> tuple:
    """The subset of ``faults`` the executor schedule loop handles."""
    return tuple(f for f in (faults or ()) if hasattr(f, "chunks"))
