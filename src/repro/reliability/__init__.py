"""Fault tolerance for the federated stack.

Three legs (see ISSUE 10 / the README "Fault tolerance & recovery"
section): in-scan health guards (``EngineConfig.guard``), chunk-boundary
checkpoint/resume (:mod:`repro.reliability.checkpoint`), and a
deterministic fault-injection harness (:mod:`repro.reliability.faults`).
"""
from repro.core.plan import CheckpointError
from repro.reliability.checkpoint import (
    RUN_FORMAT,
    latest_checkpoint,
    load_checkpoint,
    plan_from_spec,
    plan_spec,
    save_checkpoint,
)
from repro.reliability.faults import (
    CorruptUpdate,
    FaultPlan,
    KillAfterChunk,
    NaNGrad,
    NaNLogits,
    SimulatedCrash,
    device_faults,
    host_faults,
)

__all__ = [
    "CheckpointError",
    "CorruptUpdate",
    "FaultPlan",
    "KillAfterChunk",
    "NaNGrad",
    "NaNLogits",
    "RUN_FORMAT",
    "SimulatedCrash",
    "device_faults",
    "host_faults",
    "latest_checkpoint",
    "load_checkpoint",
    "plan_from_spec",
    "plan_spec",
    "save_checkpoint",
]
