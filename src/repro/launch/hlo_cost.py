"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE — useless for
scan-over-layers programs (a 126-layer llama3 shows up as one layer).  This
module re-derives the roofline inputs from the HLO module text:

  * FLOPs        — every ``dot`` (2 * numel(out) * prod(contracting dims))
                   and ``convolution`` — multiplied through enclosing
                   while-loop trip counts (``known_trip_count`` backend
                   config, emitted by XLA for lax.scan).
  * HBM bytes    — per top-level instruction: operand bytes + output bytes,
                   fusions counted as single ops (their internals are
                   on-chip), multiplied through trip counts.  This is the
                   standard post-fusion HBM-traffic model.
  * collectives  — counts and operand bytes per kind, multiplied through
                   trip counts (a collective inside a scanned layer fires
                   once per layer).

Branches of ``conditional`` are charged at full cost (upper bound; the
zamba2 shared-attention cond fires on 1-in-6 layers — we report both raw
and annotated numbers where it matters).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0, "u1": 1, "s1": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\\]+n[":\\]+(\d+)')


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(type_str: str) -> int:
    n_total = 0
    for _, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: list[str]


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult

    @property
    def wire_bytes(self) -> float:
        # ring factors on a 16-way axis (documented in hlo_analysis)
        f = {"all-reduce": 2 * 15 / 16, "all-gather": 15 / 16,
             "reduce-scatter": 15 / 16, "all-to-all": 15 / 16,
             "collective-permute": 1.0}
        return sum(v * f.get(k, 1.0) for k, v in self.collective_bytes.items())


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: dict[str, CostTotals] = {}

    # -- parsing -----------------------------------------------------------
    @staticmethod
    def _split_instr(line: str):
        """Parse '%name = TYPE opcode(args), attrs' robustly (tuple types
        may contain '/*index=N*/' comments and nested brackets)."""
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        if not s.startswith("%") and not s[:1].isalpha():
            return None
        eq = s.find(" = ")
        if eq < 0:
            return None
        name = s[:eq].strip().lstrip("%")
        rest = s[eq + 3:]
        if rest.startswith("("):          # tuple type: balance parens
            depth, i = 0, 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            ty = rest[: i + 1]
            tail = rest[i + 1:].lstrip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                return None
            ty = rest[:sp]
            tail = rest[sp + 1:]
        par = tail.find("(")
        if par < 0:
            return None
        opcode = tail[:par].strip()
        args = tail[par + 1:]
        return name, ty, opcode, args

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if stripped.endswith("{") and "->" in stripped:
                hdr = _COMP_HDR.match(stripped)
                if hdr:
                    cur = hdr.group(1)
                    self.comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            parsed = self._split_instr(line)
            if parsed is None:
                continue
            name, ty, opcode, args = parsed
            operands = re.findall(r"%([\w.\-]+)", args)
            self.comps[cur].append(Instr(name, ty, opcode, line, operands))

    def _symtab(self, comp: str) -> dict[str, str]:
        return {i.name: i.type_str for i in self.comps.get(comp, [])}

    # -- per-opcode costs ----------------------------------------------------
    def _dot_flops(self, instr: Instr, symtab: dict) -> float:
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
        if not m:
            return 0.0
        cdims = [int(x) for x in m.group(1).split(",") if x]
        lhs_ty = symtab.get(instr.operands[0] if instr.operands else "", "")
        dims = _shape_dims(lhs_ty)
        if not dims:
            return 0.0
        _, lhs_dims = dims[0]
        k = 1
        for c in cdims:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        return 2.0 * _numel(instr.type_str) * k

    def _conv_flops(self, instr: Instr, symtab: dict) -> float:
        rhs_ty = symtab.get(instr.operands[1] if len(instr.operands) > 1 else "", "")
        dims = _shape_dims(rhs_ty)
        if not dims:
            return 0.0
        _, kdims = dims[0]
        kernel = 1
        for d in kdims[:-1]:           # all but output-feature dim
            kernel *= d
        return 2.0 * _numel(instr.type_str) * kernel

    # -- HBM traffic model ------------------------------------------------------
    def _param_effective_bytes(self, comp: str, param_idx: int, full_ty: str) -> int:
        """Bytes a fused computation actually READS of parameter ``param_idx``.

        If every use is a dynamic-slice (scan reading one layer's weights out
        of the stacked [L, ...] buffer) charge the slice sizes; if the only
        use is operand 0 of a dynamic-update-slice (in-place scan output),
        charge nothing for the read (the buffer is written, not read).
        Otherwise charge the full parameter.
        """
        instrs = self.comps.get(comp, [])
        pname = None
        for i in instrs:
            if i.opcode == "parameter" and i.line.split("parameter(")[-1].startswith(str(param_idx)):
                pname = i.name
                break
        if pname is None:
            return _shape_bytes(full_ty)
        uses = [i for i in instrs if pname in i.operands]
        if not uses:
            return 0
        total = 0
        for u in uses:
            if u.opcode == "dynamic-slice" and u.operands and u.operands[0] == pname:
                total += _shape_bytes(u.type_str)
            elif u.opcode == "dynamic-update-slice" and u.operands and u.operands[0] == pname:
                total += 0          # written in place; update counted as output
            else:
                return _shape_bytes(full_ty)
        return total

    def _instr_bytes(self, ins: Instr, symtab: dict) -> float:
        """Post-fusion HBM traffic of one top-level instruction."""
        op = ins.opcode
        if op == "dynamic-slice":
            return 2.0 * _shape_bytes(ins.type_str)
        if op == "dynamic-update-slice":
            upd = _shape_bytes(symtab.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
            return 2.0 * upd
        if op == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", ins.line)
            out_b = _shape_bytes(ins.type_str)
            if not called or called.group(1) not in self.comps:
                return out_b + sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
            comp = called.group(1)
            # fusion whose root is a dynamic-update-slice writes only the
            # update region (scan stacking its per-iteration output into the
            # carried [L, ...] buffer) — charge the update, not the buffer.
            root = next((i for i in self.comps[comp] if "ROOT" in i.line), None)
            if root is not None and root.opcode == "dynamic-update-slice" \
                    and len(root.operands) > 1:
                sub_tab = self._symtab(comp)
                out_b = _shape_bytes(sub_tab.get(root.operands[1], "")) or out_b
            in_b = 0
            for idx, o in enumerate(ins.operands):
                full_ty = symtab.get(o, "")
                if not full_ty:
                    continue
                in_b += self._param_effective_bytes(comp, idx, full_ty)
            return out_b + in_b
        return _shape_bytes(ins.type_str) + sum(
            _shape_bytes(symtab.get(o, "")) for o in ins.operands)

    # -- computation cost -----------------------------------------------------
    def comp_cost(self, comp: str, *, fused: bool = False) -> CostTotals:
        key = f"{comp}|{fused}"
        if key in self._memo:
            return self._memo[key]
        total = CostTotals()
        symtab = self._symtab(comp)
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            if op == "dot":
                total.flops += self._dot_flops(ins, symtab)
            elif op == "convolution":
                total.flops += self._conv_flops(ins, symtab)
            elif op == "while":
                trip = 1
                tm = _TRIP.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                body = re.search(r"body=%?([\w.\-]+)", ins.line)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if body:
                    total.add(self.comp_cost(body.group(1)), trip)
                if cond:
                    total.add(self.comp_cost(cond.group(1)), trip)
                continue
            elif op == "fusion":
                called = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if called:
                    sub = self.comp_cost(called.group(1), fused=True)
                    total.flops += sub.flops       # dots inside fusions count
            elif op == "conditional":
                for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%?([\w.\-]+))",
                                     ins.line):
                    names = [n for grp in br for n in re.findall(r"%?([\w.\-]+)", grp)]
                    for n in names:
                        if n in self.comps:
                            total.add(self.comp_cost(n), 1.0)
                continue
            elif op in ("call", "custom-call", "async-start"):
                called = re.search(r"(?:to_apply|called_computations=\{)%?([\w.\-]+)", ins.line)
                if called and called.group(1) in self.comps:
                    total.add(self.comp_cost(called.group(1)), 1.0)

            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind:
                ob = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
                if ob == 0:
                    ob = _shape_bytes(ins.type_str)
                total.collective_bytes[kind] = total.collective_bytes.get(kind, 0.0) + ob
                total.collective_counts[kind] = total.collective_counts.get(kind, 0) + 1

            # HBM traffic: top-level instructions only; skip pure bookkeeping
            if not fused and op not in ("parameter", "constant", "tuple",
                                        "get-tuple-element", "bitcast", "while",
                                        "conditional"):
                total.bytes += self._instr_bytes(ins, symtab)
        self._memo[key] = total
        return total

    def entry_cost(self) -> CostTotals:
        if self.entry is None:
            # fall back: largest computation
            self.entry = max(self.comps, key=lambda c: len(self.comps[c]))
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).entry_cost()
