import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------
# Multi-pod dry-run: prove every (arch x input-shape x mesh) lowers and
# compiles with coherent sharding, and extract the roofline terms.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
#       --shape train_4k --mesh pod,multipod --out benchmarks/results/dryrun
#
# The XLA_FLAGS line above MUST run before any jax import (device count is
# locked at first init).  Tests and benchmarks do NOT import this module's
# side effects — they see 1 device.
# --------------------------------------------------------------------------
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    FLRunConfig,
    fl_batch_specs,
    make_decode_step,
    make_fl_train_step,
    make_prefill_step,
)
from repro.models.api import build_model, decode_cache_len, input_specs
from repro.sharding.fl_specs import (
    fl_batch_partition_specs,
    fl_state_specs,
    serve_batch_specs,
)
from repro.sharding.specs import make_plan, param_specs
from repro.sharding import cache_specs as make_cache_specs
from repro.sharding.ctx import activation_sharding


def _with_sharding(shapes, specs, mesh):
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, specs)


def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool,
                donate: bool = True, extra_flags: dict | None = None) -> dict:
    """Lower + compile one (arch, shape, mesh).  Returns the result record."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(mesh, cfg)
    chips = mesh.size
    t0 = time.time()

    if shape.kind == "train":
        run = FLRunConfig(local_steps=1, server_tau=1)
        init_state, train_step = make_fl_train_step(cfg, run, plan.num_clients or 1)
        state_shapes = jax.eval_shape(init_state, jax.random.key(0))
        batch_shapes = fl_batch_specs(cfg, shape, max(plan.num_clients, 1), run,
                                      abstract=True)
        model = build_model(cfg)
        sspecs = fl_state_specs(state_shapes, model.axes(), plan)
        bspecs = fl_batch_partition_specs(batch_shapes, plan)
        state_in = _with_sharding(state_shapes, sspecs, mesh)
        batch_in = _with_sharding(batch_shapes, bspecs, mesh)
        with mesh, activation_sharding(mesh, plan.batch_axes):
            lowered = jax.jit(train_step).lower(state_in, batch_in)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        model, prefill_step = make_prefill_step(cfg)
        params_shapes = model.param_shapes()
        pspecs = param_specs(params_shapes, model.axes(), plan)
        batch_shapes = input_specs(cfg, shape, abstract=True)
        bspecs = serve_batch_specs(batch_shapes, plan)
        params_in = _with_sharding(params_shapes, pspecs, mesh)
        batch_in = _with_sharding(batch_shapes, bspecs, mesh)
        serve_axes = plan.client_axes + plan.batch_axes
        with mesh, activation_sharding(mesh, serve_axes):
            lowered = jax.jit(prefill_step).lower(params_in, batch_in)
            compiled = lowered.compile()
    else:  # decode
        model, decode_step = make_decode_step(cfg)
        params_shapes = model.param_shapes()
        pspecs = param_specs(params_shapes, model.axes(), plan)
        cache_len = decode_cache_len(cfg, shape)
        window = cfg.sliding_window if shape.name == "long_500k" else None
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_len, window=window))
        cspecs = make_cache_specs(cache_shapes, plan, cfg)
        batch_shapes = input_specs(cfg, shape, abstract=True)
        bspecs = serve_batch_specs(batch_shapes, plan)
        params_in = _with_sharding(params_shapes, pspecs, mesh)
        cache_in = _with_sharding(cache_shapes, cspecs, mesh)
        batch_in = _with_sharding(batch_shapes, bspecs, mesh)
        serve_axes = plan.client_axes + plan.batch_axes
        with mesh, activation_sharding(mesh, serve_axes):
            lowered = jax.jit(decode_step, donate_argnums=(1,)).lower(
                params_in, cache_in, batch_in)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):       # older jax: one dict per partition
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # Trip-count-aware per-device cost model (XLA's cost_analysis counts
    # while bodies once — see hlo_cost.py).  The partitioned module is the
    # PER-DEVICE program, so terms use chips=1.
    from repro.launch import hlo_cost
    tot = hlo_cost.analyze(hlo)
    terms = H.roofline_terms(flops=tot.flops, bytes_accessed=tot.bytes,
                             wire_bytes=tot.wire_bytes, chips=1)
    mflops = H.model_flops(cfg, shape, training=shape.kind == "train")

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "num_clients": plan.num_clients,
        "fl_client_axis": cfg.fl_client_axis,
        "compile_s": round(compile_s, 1),
        "hlo_flops_per_device": tot.flops,
        "hlo_bytes_per_device": tot.bytes,
        "collective_wire_bytes_per_device": tot.wire_bytes,
        "collective_counts": {k: int(v) for k, v in tot.collective_counts.items()},
        "collective_bytes_by_kind": {k: float(v) for k, v in tot.collective_bytes.items()},
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "roofline": terms,
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / (tot.flops * chips)) if tot.flops else None,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "per_device_bytes": {
            "arguments": (getattr(mem, "argument_size_in_bytes", 0) or 0) / chips,
            "temp": (getattr(mem, "temp_size_in_bytes", 0) or 0) / chips,
        },
        "ok": True,
    }
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod", help="pod | multipod | both")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = out / f"{tag}.json"
                if args.skip_existing and path.exists():
                    ok = json.loads(path.read_text()).get("ok")
                    if ok:
                        print(f"[skip] {tag}")
                        continue
                print(f"[run ] {tag} ...", flush=True)
                try:
                    rec = dryrun_pair(arch, shape, multi_pod=mp)
                    print(f"[ ok ] {tag}: compile={rec['compile_s']}s "
                          f"bottleneck={rec['roofline']['bottleneck']} "
                          f"flops/dev={rec['hlo_flops_per_device']:.3e}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[FAIL] {tag}: {e}", flush=True)
                path.write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
