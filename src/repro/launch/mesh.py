"""Production mesh builders.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the 'pod'
axis hosts cross-silo FL clients for the giant architectures and extends
the client axis for the small ones.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over the actually-present local devices (tests, CPU)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
