"""Production mesh builders.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the 'pod'
axis hosts cross-silo FL clients for the giant architectures and extends
the client axis for the small ones.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over the actually-present local devices (tests, CPU).

    Also the default substrate of the simulation MeshBackend
    (`repro.core.backend`): every local device lands on the 'data' axis,
    which hosts the FL client dimension — force a multi-device CPU mesh
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
