"""Pod-scale FL steps: the paper's round as ONE SPMD program.

``fl_train_step`` wraps the SAME unified round implementation as the
simulation driver — :func:`repro.core.engine.round_core` — so the two
paths cannot diverge (tests/test_engine_diff.py locks the parity):

    local E steps        — per-client restart-SGDM (FedDUM Formula 11);
                           NO collective over the client axis: clients
                           diverge inside the step.
    aggregate            — weighted mean over the client dim (one weight
                           all-reduce over the client axis; this IS the
                           paper's "upload models + FedAvg" step 3-4).
    FedDU server update  — tau server SGD steps on the shared batch,
                           normalized (Formula 6), scaled by tau_eff
                           (Formula 7); data-parallel over the whole mesh.
    FedDUM server SGDM   — pseudo-gradient momentum (Formulas 8/12).

This module only contributes the pod-specific pieces: the batch-dict model
adapter (``loss_and_accuracy`` fuses the Formula-7 acc gate into the first
server gradient step — §Perf iteration B2), the FLRunConfig->EngineConfig
wiring, and the (arch x shape) batch construction that
`sharding/fl_specs.py` partitions over the mesh.

State between rounds is just {global params, server momentum, [masks],
round} — FL clients are stateless (the momentum restart is what makes
this one program possible with zero extra communication).  With
``use_masks`` the FedAP keep-masks ride in that state, sharded exactly
like the params, so the prune round needs no re-lower of the mesh
program (``with_masks`` injects a decision mid-run).

This module is the POD-SCALE entry point — big-model (arch x shape) FL
over `sharding/fl_specs.py` partition specs.  The simulation-scale
client-sharded driver lives in :mod:`repro.core.backend`
(``MeshBackend``), which reuses the pieces here: its Prune events compute
the FedAP decision from mesh-sharded participants
(``fedap.fedap_decision_sharded`` — ragged probe sets padded and masked)
and inject MASK decisions through :func:`with_masks`, whose canonical
state transform is ``backend.masked_round_state`` (shared with the local
executor so the two paths cannot diverge); SHRINK decisions compact the
sharded state in one jitted shard-local gather
(``MeshBackend._sharded_shrink``) — the pod analogue of the same
no-host-round-trip rule this module follows for the round itself.  Its
server-update and eval batches shard over the mesh exactly as
``fl_batch_partition_specs`` shards the server batch dim here.

Serve steps (``prefill_step`` / ``decode_step``) run the aggregated global
model — plain distributed inference.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core.engine import (
    EngineConfig,
    FedDynConfig,
    FedProxConfig,
    build_model_fns,
    init_round_state,
    round_core,
)
from repro.core.momentum import FedDUMConfig
from repro.core.server_update import FedDUConfig
from repro.models.api import build_model, decode_cache_len, input_specs
from repro.sharding.specs import MeshPlan


@dataclasses.dataclass(frozen=True)
class FLRunConfig:
    lr: float = 1e-3              # eta' (local) and eta (server SGD)
    beta_local: float = 0.9       # FedDUM Formula 11
    beta_server: float = 0.9      # FedDUM Formula 8
    eta_server: float = 1.0
    local_steps: int = 1          # local iterations per round (E*n_k/B)
    server_tau: int = 1           # server iterations per round
    server_batch: int = 32
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    use_server_update: bool = True
    use_momentum: bool = True
    # Static-shape FedAP: keep-masks ride in the SPMD round state (sharded
    # like the params — sharding/fl_specs.py is key-generic over the state
    # dict), so the pod program prunes without a shape change or re-lower.
    use_masks: bool = False
    # "kernel" additionally threads filter-level masks (replicated, tiny)
    # into the model fns so masked dense layers run the differentiable
    # Pallas masked_matmul; requires a masks-aware model
    # (model.loss/apply accept masks=).  "params" masks the tree only.
    masked_compute: str = "params"
    # Client-state algorithm (fedavg | fedprox | feddyn) — the pod round
    # state grows the same client_state slot as the simulation path
    # (fl_specs.fl_state_specs shards its per-client leaves).
    algorithm: str = "fedavg"
    # In-scan health guard (engine.round_core): non-finite client uploads
    # get zero aggregation weight ("reject_client") or void the whole
    # round ("skip_round"); adds zero programs to the pod step.
    guard: str = "off"
    fedprox: FedProxConfig = dataclasses.field(default_factory=FedProxConfig)
    feddyn: FedDynConfig = dataclasses.field(default_factory=FedDynConfig)


def token_accuracy(model, params, batch) -> jnp.ndarray:
    logits, _ = model.apply(params, batch)
    ok = (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32)
    mask = batch.get("loss_mask")
    if mask is not None:
        return jnp.sum(ok * mask) / jnp.clip(jnp.sum(mask), 1.0, None)
    return jnp.mean(ok)


def loss_and_accuracy(model, params, batch, masks=None):
    """Single-forward loss + token accuracy (the Formula-7 acc gate fused
    into the first server gradient step — §Perf iteration B2: the separate
    accuracy forward cost one extra server-batch pass per round).

    ``masks`` (masked_compute="kernel" only) is forwarded to a masks-aware
    ``model.apply``; None keeps the plain call so existing pod models need
    no signature change."""
    if masks is None:
        logits, aux = model.apply(params, batch)
    else:
        logits, aux = model.apply(params, batch, masks=masks)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ok = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    if mask is not None:
        denom = jnp.clip(jnp.sum(mask), 1.0, None)
        loss = jnp.sum(nll * mask) / denom + aux
        acc = jnp.sum(ok * mask) / denom
    else:
        loss = jnp.sum(nll) / nll.size + aux
        acc = jnp.mean(ok)
    return loss, acc


def engine_config(run: FLRunConfig) -> EngineConfig:
    """The FLRunConfig -> EngineConfig wiring (locked against the simulation
    driver's FLConfig wiring by tests/test_engine_diff.py)."""
    return EngineConfig(
        lr=run.lr, lr_decay=1.0,
        use_server_update=run.use_server_update,
        local_momentum="restart" if run.use_momentum else "none",
        server_momentum=run.use_momentum,
        use_masks=run.use_masks,
        masked_compute=run.masked_compute,
        algorithm=run.algorithm,
        guard=run.guard,
        fedprox=run.fedprox,
        feddyn=run.feddyn,
        feddu=run.feddu,
        feddum=FedDUMConfig(beta_server=run.beta_server,
                            beta_local=run.beta_local,
                            eta_server=run.eta_server))


def make_fl_train_step(cfg: ModelConfig, run: FLRunConfig, num_clients: int,
                       *, model: Any = None):
    """Returns (init_state_fn(rng), train_step(state, batch) -> state_out).

    The round itself is `repro.core.engine.round_core`; this wires the
    batch-dict model adapter into it.  ``model`` overrides ``build_model``
    for tests (anything exposing init / loss / apply over batch dicts).

    batch:
      client: batch pytree with leading [C, steps, ...] dims
      server: batch pytree with leading [tau, ...] dim
      sizes:  [C] f32 n_k
      d_round, d_server: scalars (non-IID degrees, Formula 2)
      n0: scalar f32
    """
    model = build_model(cfg) if model is None else model
    eng = engine_config(run)

    # The kernel-mode arity decision (does round_core hand the carry's
    # filter masks to the model fns?) lives in ONE place —
    # engine.build_model_fns, shared with core.backend.model_fns — so the
    # pod and executor signatures cannot drift.  This module contributes
    # only the batch-dict adapters.
    def loss_fn(p, b, fm):
        if fm is None:
            return model.loss(p, b)
        return model.loss(p, b, masks=fm)

    def la_base(p, b, fm):
        return loss_and_accuracy(model, p, b, masks=fm)

    grad_fn, la_fn = build_model_fns(eng, loss_fn, la_base)

    def init_state(rng, filter_masks=None):
        return init_round_state(model.init(rng), eng,
                                filter_masks=filter_masks,
                                num_clients=num_clients)

    def train_step(state, batch):
        new_state, metrics = round_core(eng, grad_fn, la_fn, state, batch)
        return new_state, metrics["tau_eff"]

    return init_state, train_step


def with_masks(state: dict, masks: Any, filter_masks: Any = None) -> dict:
    """Inject FedAP keep-masks into a running masked round state — the pod
    analogue of the simulation executor's ``Prune(mode="mask")`` event:
    momentum restarts, params are masked, shapes (and the lowered mesh
    program) are untouched.  ``filter_masks`` swaps the kernel-mode filter
    masks too (required when the state carries a ``filter_masks`` slot —
    its pytree structure must stay identical)."""
    from repro.core.backend import masked_round_state

    if "masks" not in state:
        raise ValueError("state has no mask slot — build the step with "
                         "FLRunConfig(use_masks=True)")
    if "filter_masks" in state and filter_masks is None:
        raise ValueError(
            "state carries a filter_masks slot (masked_compute='kernel') — "
            "pass filter_masks=pruning.filter_masks(...) so the kernel path "
            "prunes the same filters the param masks zero")
    if filter_masks is not None and "filter_masks" not in state:
        raise ValueError(
            "filter_masks given but the state has no filter_masks slot — "
            "build the step with FLRunConfig(masked_compute='kernel')")
    return masked_round_state(state, masks, filter_masks=filter_masks)


def make_prefill_step(cfg: ModelConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, _ = model.apply(params, batch)
        # return only the last position (serving returns next-token logits)
        return logits[:, -1, :]

    return model, prefill_step


def make_decode_step(cfg: ModelConfig):
    model = build_model(cfg)

    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return model, decode_step


# ---------------------------------------------------------------------------
# FL batch construction for (arch x shape)
# ---------------------------------------------------------------------------

def fl_batch_specs(cfg: ModelConfig, shape: InputShape, num_clients: int,
                   run: FLRunConfig, *, abstract: bool = True, seed: int = 0):
    """The train-shape batch: the global batch is split over C clients;
    the server batch rides along (tau leading dim)."""
    import numpy as np

    c = num_clients
    b_c = max(1, shape.global_batch // c)
    base = input_specs(cfg, shape, abstract=abstract, seed=seed)

    def expand(leaf, lead):
        if abstract:
            return jax.ShapeDtypeStruct(lead + leaf.shape, leaf.dtype)
        reps = 1
        for d in lead:
            reps *= d
        return jnp.broadcast_to(leaf, lead + leaf.shape)

    def reshard_client(leaf):
        # [B, ...] -> [C, steps, b_c, ...]
        shp = (c, run.local_steps, b_c) + leaf.shape[1:]
        if abstract:
            return jax.ShapeDtypeStruct(shp, leaf.dtype)
        sliced = leaf[: c * b_c]
        tiled = jnp.reshape(sliced, (c, 1, b_c) + leaf.shape[1:])
        return jnp.broadcast_to(tiled, shp)

    def reshard_positions(leaf):
        # [P, B, S] -> [C, steps, P, b_c, S]
        shp = (c, run.local_steps, leaf.shape[0], b_c) + leaf.shape[2:]
        if abstract:
            return jax.ShapeDtypeStruct(shp, leaf.dtype)
        sliced = leaf[:, : c * b_c]
        tiled = jnp.transpose(
            jnp.reshape(sliced, (leaf.shape[0], c, b_c) + leaf.shape[2:]),
            (1, 0, 2) + tuple(range(3, leaf.ndim + 1)))[:, None]
        return jnp.broadcast_to(tiled, shp)

    client = {}
    for k, v in base.items():
        client[k] = reshard_positions(v) if k == "positions" else reshard_client(v)

    server_base = input_specs(cfg, dataclasses.replace(
        shape, global_batch=run.server_batch), abstract=abstract, seed=seed + 1)
    server = {k: expand(v, (run.server_tau,)) for k, v in server_base.items()}

    scalar = (lambda v: jax.ShapeDtypeStruct((), jnp.float32)) if abstract else \
        (lambda v: jnp.asarray(v, jnp.float32))
    sizes = (jax.ShapeDtypeStruct((c,), jnp.float32) if abstract
             else jnp.ones((c,), jnp.float32))
    batch = {
        "client": client,
        "server": server,
        "sizes": sizes,
        "d_round": scalar(0.3),
        "d_server": scalar(0.01),
        "n0": scalar(2048.0),
    }
    if run.algorithm == "feddyn":
        # selected-client ids indexing the client_state's per-client slot;
        # the pod shape exercises full participation (client k <- slot k)
        batch["sel"] = (jax.ShapeDtypeStruct((c,), jnp.int32) if abstract
                        else jnp.arange(c, dtype=jnp.int32))
    return batch
