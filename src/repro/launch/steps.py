"""Pod-scale FL steps: the paper's round as ONE SPMD program.

``fl_train_step`` is FedDUMAP's round (minus the one-shot FedAP prune,
which re-materializes between rounds):

    local E steps        — per-client restart-SGDM (FedDUM Formula 11);
                           NO collective over the client axis: clients
                           diverge inside the step.
    aggregate            — weighted mean over the client dim (one weight
                           all-reduce over the client axis; this IS the
                           paper's "upload models + FedAvg" step 3-4).
    FedDU server update  — tau server SGD steps on the shared batch,
                           normalized (Formula 6), scaled by tau_eff
                           (Formula 7); data-parallel over the whole mesh.
    FedDUM server SGDM   — pseudo-gradient momentum (Formulas 8/12).

State between rounds is just {global params, server momentum, round} —
FL clients are stateless (the momentum restart is what makes this one
program possible with zero extra communication).

Serve steps (``prefill_step`` / ``decode_step``) run the aggregated global
model — plain distributed inference.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core.server_update import FedDUConfig, tau_eff
from repro.models.api import build_model, decode_cache_len, input_specs
from repro.sharding.specs import MeshPlan


@dataclasses.dataclass(frozen=True)
class FLRunConfig:
    lr: float = 1e-3              # eta' (local) and eta (server SGD)
    beta_local: float = 0.9       # FedDUM Formula 11
    beta_server: float = 0.9      # FedDUM Formula 8
    eta_server: float = 1.0
    local_steps: int = 1          # local iterations per round (E*n_k/B)
    server_tau: int = 1           # server iterations per round
    server_batch: int = 32
    feddu: FedDUConfig = dataclasses.field(default_factory=FedDUConfig)
    use_server_update: bool = True
    use_momentum: bool = True


def token_accuracy(model, params, batch) -> jnp.ndarray:
    logits, _ = model.apply(params, batch)
    ok = (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32)
    mask = batch.get("loss_mask")
    if mask is not None:
        return jnp.sum(ok * mask) / jnp.clip(jnp.sum(mask), 1.0, None)
    return jnp.mean(ok)


def loss_and_accuracy(model, params, batch):
    """Single-forward loss + token accuracy (the Formula-7 acc gate fused
    into the first server gradient step — §Perf iteration B2: the separate
    accuracy forward cost one extra server-batch pass per round)."""
    logits, aux = model.apply(params, batch)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ok = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    if mask is not None:
        denom = jnp.clip(jnp.sum(mask), 1.0, None)
        loss = jnp.sum(nll * mask) / denom + aux
        acc = jnp.sum(ok * mask) / denom
    else:
        loss = jnp.sum(nll) / nll.size + aux
        acc = jnp.mean(ok)
    return loss, acc


def make_fl_train_step(cfg: ModelConfig, run: FLRunConfig, num_clients: int):
    """Returns (init_state_fn(rng), train_step(state, batch) -> state_out).

    batch:
      client: batch pytree with leading [C, steps, ...] dims
      server: batch pytree with leading [tau, ...] dim
      sizes:  [C] f32 n_k
      d_round, d_server: scalars (non-IID degrees, Formula 2)
      n0: scalar f32
    """
    model = build_model(cfg)
    grad_fn = jax.grad(model.loss)

    def init_state(rng):
        params = model.init(rng)
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"params": params, "server_m": m,
                "round": jnp.zeros((), jnp.float32)}

    def local_train(params, client_batch):
        """Restart-SGDM over ``local_steps`` batches (Formula 11)."""
        m0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def step(carry, b):
            p, m = carry
            g = grad_fn(p, b)
            if run.use_momentum:
                m = jax.tree.map(
                    lambda mi, gi: run.beta_local * mi
                    + (1 - run.beta_local) * gi.astype(jnp.float32), m, g)
                upd = m
            else:
                upd = g
            p = jax.tree.map(lambda pi, u: (pi - run.lr * u).astype(pi.dtype), p, upd)
            return (p, m), None

        (p, _), _ = jax.lax.scan(step, (params, m0), client_batch)
        return p

    def train_step(state, batch):
        params = state["params"]

        # (2) local epochs, vmapped over the client dim — no client collective
        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batch["client"])

        # (4) FedAvg aggregation: ONE weighted all-reduce over the client axis
        w = batch["sizes"] / jnp.sum(batch["sizes"])
        w_half = jax.tree.map(
            lambda l: jnp.einsum("c,c...->...", w.astype(jnp.float32),
                                 l.astype(jnp.float32)).astype(l.dtype), locals_)

        # (5) FedDU dynamic server update.  The Formula-7 accuracy gate is
        # computed from the FIRST server step's own forward (value_and_grad
        # with aux) — no separate evaluation pass (§Perf B2).
        if run.use_server_update:
            tau = jax.tree.leaves(batch["server"])[0].shape[0]
            la_grad = jax.value_and_grad(
                lambda p, b: loss_and_accuracy(model, p, b), has_aux=True)

            def sstep(carry, b):
                p, acc0, is_first = carry
                (_, acc), g = la_grad(p, b)
                acc0 = jnp.where(is_first, acc, acc0)
                p = jax.tree.map(lambda pi, gi: (pi - run.lr * gi).astype(pi.dtype), p, g)
                return (p, acc0, jnp.zeros((), bool)), None

            (w_end, acc, _), _ = jax.lax.scan(
                sstep, (w_half, jnp.zeros(()), jnp.ones((), bool)), batch["server"])
            g0 = jax.tree.map(
                lambda a, b_: (a.astype(jnp.float32) - b_.astype(jnp.float32))
                / (tau * run.lr), w_half, w_end)
            t_eff = tau_eff(run.feddu, acc=acc, round_idx=state["round"],
                            n0=batch["n0"], n_prime=jnp.sum(batch["sizes"]),
                            d_round=batch["d_round"], d_server=batch["d_server"],
                            tau=tau)
            proposed = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - t_eff * run.lr * g).astype(p.dtype),
                w_half, g0)
        else:
            proposed = w_half
            t_eff = jnp.zeros(())

        # FedDUM server momentum on the pseudo-gradient
        if run.use_momentum:
            pseudo = jax.tree.map(
                lambda a, b_: a.astype(jnp.float32) - b_.astype(jnp.float32),
                params, proposed)
            m = jax.tree.map(
                lambda mi, g: run.beta_server * mi + (1 - run.beta_server) * g,
                state["server_m"], pseudo)
            new_params = jax.tree.map(
                lambda p, mi: (p.astype(jnp.float32) - run.eta_server * mi).astype(p.dtype),
                params, m)
        else:
            m = state["server_m"]
            new_params = proposed

        return {"params": new_params, "server_m": m, "round": state["round"] + 1}, t_eff

    return init_state, train_step


def make_prefill_step(cfg: ModelConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, _ = model.apply(params, batch)
        # return only the last position (serving returns next-token logits)
        return logits[:, -1, :]

    return model, prefill_step


def make_decode_step(cfg: ModelConfig):
    model = build_model(cfg)

    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return model, decode_step


# ---------------------------------------------------------------------------
# FL batch construction for (arch x shape)
# ---------------------------------------------------------------------------

def fl_batch_specs(cfg: ModelConfig, shape: InputShape, num_clients: int,
                   run: FLRunConfig, *, abstract: bool = True, seed: int = 0):
    """The train-shape batch: the global batch is split over C clients;
    the server batch rides along (tau leading dim)."""
    import numpy as np

    c = num_clients
    b_c = max(1, shape.global_batch // c)
    base = input_specs(cfg, shape, abstract=abstract, seed=seed)

    def expand(leaf, lead):
        if abstract:
            return jax.ShapeDtypeStruct(lead + leaf.shape, leaf.dtype)
        reps = 1
        for d in lead:
            reps *= d
        return jnp.broadcast_to(leaf, lead + leaf.shape)

    def reshard_client(leaf):
        # [B, ...] -> [C, steps, b_c, ...]
        shp = (c, run.local_steps, b_c) + leaf.shape[1:]
        if abstract:
            return jax.ShapeDtypeStruct(shp, leaf.dtype)
        sliced = leaf[: c * b_c]
        tiled = jnp.reshape(sliced, (c, 1, b_c) + leaf.shape[1:])
        return jnp.broadcast_to(tiled, shp)

    def reshard_positions(leaf):
        # [P, B, S] -> [C, steps, P, b_c, S]
        shp = (c, run.local_steps, leaf.shape[0], b_c) + leaf.shape[2:]
        if abstract:
            return jax.ShapeDtypeStruct(shp, leaf.dtype)
        sliced = leaf[:, : c * b_c]
        tiled = jnp.transpose(
            jnp.reshape(sliced, (leaf.shape[0], c, b_c) + leaf.shape[2:]),
            (1, 0, 2) + tuple(range(3, leaf.ndim + 1)))[:, None]
        return jnp.broadcast_to(tiled, shp)

    client = {}
    for k, v in base.items():
        client[k] = reshard_positions(v) if k == "positions" else reshard_client(v)

    server_base = input_specs(cfg, dataclasses.replace(
        shape, global_batch=run.server_batch), abstract=abstract, seed=seed + 1)
    server = {k: expand(v, (run.server_tau,)) for k, v in server_base.items()}

    scalar = (lambda v: jax.ShapeDtypeStruct((), jnp.float32)) if abstract else \
        (lambda v: jnp.asarray(v, jnp.float32))
    sizes = (jax.ShapeDtypeStruct((c,), jnp.float32) if abstract
             else jnp.ones((c,), jnp.float32))
    return {
        "client": client,
        "server": server,
        "sizes": sizes,
        "d_round": scalar(0.3),
        "d_server": scalar(0.01),
        "n0": scalar(2048.0),
    }
