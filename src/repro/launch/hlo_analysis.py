"""Roofline-term extraction from compiled/lowered HLO.

Three terms per (arch x shape x mesh) — DESIGN.md / EXPERIMENTS.md §Roofline:

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_wire_bytes / (chips * ICI_BW)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text, resolve every
collective op's operand shapes through a symbol table of instruction
result types, and convert to wire bytes with the standard ring factors:

  all-reduce       2 (n-1)/n     (reduce-scatter + all-gather phases)
  all-gather       (n-1)/n
  reduce-scatter   (n-1)/n
  all-to-all       (n-1)/n
  collective-permute 1

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link (3 links/chip; we charge the busiest-link model: bytes
crossing each chip boundary / link bw).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([\w\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (possibly a tuple type)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    wire_bytes: float           # ring-adjusted, summed over ops

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def parse_collectives(hlo_text: str, *, ring_n: int = 16) -> CollectiveStats:
    """Scan optimized HLO; sum operand bytes of every collective.

    For `op(...)` the operand shapes are resolved from the instruction
    symbol table (fallback: the op's own result type, exact for
    all-reduce / collective-permute, output-size for all-gather).
    """
    # symbol table: instruction name -> result type string
    table: dict[str, str] = {}
    instrs: list[tuple[str, str, str, str]] = []  # (name, type, opcode, line)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, ty, opcode = m.groups()
        table[name.lstrip("%")] = ty
        base = opcode.split(".")[0]
        if base in _COLLECTIVES or any(line.lstrip().split("=", 1)[-1].lstrip()
                                       .startswith(c) for c in _COLLECTIVES):
            instrs.append((name.lstrip("%"), ty, base, line))

    counts: dict[str, int] = {}
    bytes_by_kind: dict[str, float] = {}
    wire = 0.0
    factor = {
        "all-reduce": 2.0 * (ring_n - 1) / ring_n,
        "all-gather": (ring_n - 1) / ring_n,
        "reduce-scatter": (ring_n - 1) / ring_n,
        "all-to-all": (ring_n - 1) / ring_n,
        "collective-permute": 1.0,
    }
    for name, ty, base, line in instrs:
        kind = next((c for c in _COLLECTIVES if c in line), base)
        # operand bytes: resolve %operand references in the call parens
        ops = re.findall(r"%([\w.\-]+)", line.split("(", 1)[-1])
        op_bytes = sum(_shape_bytes(table.get(o, "")) for o in ops)
        if op_bytes == 0:
            op_bytes = _shape_bytes(ty)
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + op_bytes
        wire += op_bytes * factor.get(kind, 1.0)
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by_kind, wire_bytes=wire)


def roofline_terms(*, flops: float, bytes_accessed: float, wire_bytes: float,
                   chips: int) -> dict[str, float]:
    compute = flops / (chips * PEAK_FLOPS)
    memory = bytes_accessed / (chips * HBM_BW)
    collective = wire_bytes / (chips * ICI_BW)
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bottleneck": dom,
    }


def model_flops(cfg, shape, *, training: bool) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for training;
    2 N D for inference (D = processed tokens)."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1          # one decoded token per sequence
    return 2.0 * n * d


def param_count(cfg) -> float:
    """Total parameters (analytic, matches init shapes)."""
    d, l, v = cfg.d_model, cfg.num_layers, cfg.vocab_size
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    attn = d * hd * (h + 2 * kv) + h * hd * d
    if cfg.family == "hybrid":
        m = cfg.ssm
        d_in = m.expand * d
        nh = m.num_ssm_heads or max(1, d_in // 64)
        mixer = d * (2 * d_in + 2 * m.state_dim + nh) + d_in * d
        ffn = 3 * d * cfg.d_ff
        shared_attn = attn
        return l * (mixer + ffn) + shared_attn + 2 * v * d
    if cfg.family == "ssm":
        f = int(cfg.xlstm.proj_factor * d)
        per = d * 2 * f + 3 * f * (f // cfg.num_heads) * cfg.num_heads + f * d
        return l * per + 2 * v * d
    if cfg.moe:
        m = cfg.moe
        ffn = m.num_experts * 3 * d * m.expert_d_ff + d * m.num_experts
        if m.dense_d_ff:
            ffn += 3 * d * m.dense_d_ff
        if m.shared_expert:
            ffn += 3 * d * m.expert_d_ff
    else:
        ffn = (3 if cfg.act == "silu" else 2) * d * cfg.d_ff
    n = l * (attn + ffn) + 2 * v * d
    if cfg.family == "encdec":
        n += cfg.encoder.num_layers * (attn + (2 * d * cfg.d_ff)) + l * attn  # enc + cross
    return n


def active_param_count(cfg) -> float:
    """Parameters touched per token (MoE: top_k of num_experts)."""
    n = param_count(cfg)
    if cfg.moe:
        m = cfg.moe
        every = m.num_experts * 3 * cfg.d_model * m.expert_d_ff * cfg.num_layers
        act = m.top_k * 3 * cfg.d_model * m.expert_d_ff * cfg.num_layers
        n = n - every + act
    return n
