"""Config-driven language-model assembly for all assigned architectures.

One class (:class:`LM`) covers the six families:

  dense   — llama-style decoder (deepseek-67b, chatglm3-6b, olmo-1b,
            llama3-405b) with GQA + RoPE(1d/2d) + SwiGLU.
  moe     — dense attention + token-choice MoE FFN (arctic-480b with dense
            residual, llama4 with shared expert, top-1/2 routing).
  vlm     — qwen2-vl backbone: M-RoPE, input arrives as precomputed
            embeddings (vision frontend stubbed per spec).
  encdec  — whisper: bidirectional encoder over precomputed frame
            embeddings (conv frontend stubbed) + causal decoder with
            cross-attention.
  hybrid  — zamba2: Mamba2 backbone + one SHARED attention block applied
            every k layers (weight reuse via lax.cond inside the scan).
  ssm     — xlstm: mLSTM blocks with periodic sLSTM blocks (unrolled; 12
            small layers).

Deep homogeneous stacks (dense/moe/vlm/hybrid decoders) are executed with
``lax.scan`` over stacked layer parameters (+ optional per-block remat), so
HLO size is O(1) in depth — required for the 512-device AOT dry-runs and
the production-standard choice.

Batches are dicts:
  tokens [B,S] int32          (dense/moe/encdec-decoder input)
  embeds [B,S,d]              (vlm: replaces tokens)
  enc_embeds [B,F,d]          (encdec: encoder frame embeddings)
  labels [B,S] int32, loss_mask [B,S] f32 (train)
  positions [P,B,S] int32     (optional; defaults to arange)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.ctx import constrain_batch


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# per-family block init / apply
# ---------------------------------------------------------------------------

def init_block(rng, cfg: ModelConfig, *, cross: bool = False):
    """One decoder block.  Returns (params, axes, meta)."""
    dtype = _dtype(cfg)
    ks = jax.random.split(rng, 8)
    params, axes, meta = {}, {}, {}

    if cfg.family == "hybrid":
        params["mamba"], axes["mamba"], meta["mamba"] = L.init_mamba2(ks[0], cfg, dtype)
        params["norm_m"], axes["norm_m"] = L.init_norm(cfg, dtype)
        params["mlp"], axes["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
        params["norm_f"], axes["norm_f"] = L.init_norm(cfg, dtype)
        return params, axes, meta

    params["attn"], axes["attn"] = L.init_attention(ks[0], cfg, dtype)
    params["norm_a"], axes["norm_a"] = L.init_norm(cfg, dtype)
    if cross:
        params["xattn"], axes["xattn"] = L.init_attention(ks[1], cfg, dtype)
        params["norm_x"], axes["norm_x"] = L.init_norm(cfg, dtype)
    if cfg.family == "moe":
        params["moe"], axes["moe"] = L.init_moe(ks[2], cfg, dtype)
    else:
        params["mlp"], axes["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    params["norm_f"], axes["norm_f"] = L.init_norm(cfg, dtype)
    return params, axes, meta


def apply_block(params, x, positions, cfg: ModelConfig, meta, *,
                window=None, attn_impl="xla", cross_kv=None, causal=True,
                masks=None):
    """Pre-norm residual block.  Returns (x, aux_loss_scalar).

    ``masks`` (optional) carries THIS layer's FedAP filter keep-masks —
    ``{"mlp": [d_ff] 0/1}`` — threaded through the masked FFN
    (:func:`repro.models.layers.apply_mlp`); None is the plain dense path.
    """
    aux = jnp.zeros((), jnp.float32)
    mlp_mask = None if masks is None else masks["mlp"]

    if cfg.family == "hybrid":
        h = L.apply_norm(params["norm_m"], x, cfg.norm)
        x = x + L.apply_mamba2(params["mamba"], h, meta["mamba"], cfg, impl=attn_impl)
        h = L.apply_norm(params["norm_f"], x, cfg.norm)
        x = x + L.apply_mlp(params["mlp"], h, cfg.act, mlp_mask)
        return x, aux

    h = L.apply_norm(params["norm_a"], x, cfg.norm)
    if causal:
        attn_out = L.attention_block(params["attn"], h, positions, cfg,
                                     window=window, attn_impl=attn_impl)
    else:  # encoder self-attention: bidirectional
        q = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wv"])
        out = L.attention(q, k, v, causal=False, window=None)
        attn_out = jnp.einsum("bshk,hkd->bsd", out, params["attn"]["wo"])
    x = x + attn_out

    if cross_kv is not None:
        h = L.apply_norm(params["norm_x"], x, cfg.norm)
        x = x + L.attention_block(params["xattn"], h, positions, cfg,
                                  attn_impl=attn_impl, cross_kv=cross_kv)

    h = L.apply_norm(params["norm_f"], x, cfg.norm)
    if cfg.family == "moe":
        y, moe_aux = L.apply_moe(params["moe"], h, cfg)
        aux = aux + moe_aux["load_balance"] + moe_aux["router_z"]
        x = x + y
    else:
        x = x + L.apply_mlp(params["mlp"], h, cfg.act, mlp_mask)
    return x, aux


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class LM:
    """Functional model: ``init``, ``apply`` (full-sequence logits),
    ``loss`` (next-token CE), ``init_cache`` + ``decode_step``."""

    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "xla"):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self._axes = None
        # meta is static per-config (shape bookkeeping for ssm/mlstm blocks)
        self._meta = self._build_meta()

    # -- meta ---------------------------------------------------------------
    def _build_meta(self):
        cfg = self.cfg
        meta = {}
        if cfg.family == "hybrid":
            m = cfg.ssm
            d_in = m.expand * cfg.d_model
            nh = m.num_ssm_heads or max(1, d_in // 64)
            meta["mamba"] = {"d_in": d_in, "nh": nh, "p": d_in // nh, "n": m.state_dim}
        if cfg.family == "ssm":
            f = int(cfg.xlstm.proj_factor * cfg.d_model)
            meta["mlstm"] = {"f": f, "nh": cfg.num_heads, "hd": f // cfg.num_heads}
            meta["slstm"] = {"nh": cfg.num_heads}
        return meta

    def _is_slstm(self, i: int) -> bool:
        return self.cfg.family == "ssm" and (i + 1) % self.cfg.xlstm.slstm_every == 0

    def hybrid_groups(self) -> list:
        """zamba2 layer groups: shared attention fires before each group of
        ``attn_every`` Mamba2 layers."""
        k = self.cfg.hybrid.attn_every
        n = self.cfg.num_layers
        return [(a, min(a + k, n)) for a in range(0, n, k)]

    @property
    def scanned(self) -> bool:
        """Deep homogeneous stacks are scanned; small heterogeneous ones
        (xlstm alternates block types; whisper enc+dec) are unrolled."""
        return self.cfg.family in ("dense", "moe", "vlm", "hybrid")

    # -- init -----------------------------------------------------------------
    def init_with_axes(self, rng):
        cfg = self.cfg
        dtype = _dtype(cfg)
        ks = jax.random.split(rng, 8)
        params, axes = {}, {}

        params["embed"] = L._normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                    1.0 / math.sqrt(cfg.d_model), dtype)
        axes["embed"] = ("vocab", "embed")
        if not cfg.tie_embeddings:
            params["unembed"] = L._normal(ks[1], (cfg.d_model, cfg.vocab_size),
                                          1.0 / math.sqrt(cfg.d_model), dtype)
            axes["unembed"] = ("embed", "vocab")
        params["norm_out"], axes["norm_out"] = L.init_norm(cfg, dtype)

        if cfg.family == "encdec":
            enc = cfg.encoder
            params["enc_pos"] = L._normal(ks[2], (enc.frames, cfg.d_model), 0.02, dtype)
            axes["enc_pos"] = (None, "embed")
            params["norm_enc"], axes["norm_enc"] = L.init_norm(cfg, dtype)
            eb, ea = [], None
            for i, k in enumerate(jax.random.split(ks[3], enc.num_layers)):
                p, a, _ = init_block(k, cfg)
                eb.append(p)
                ea = a
            params["encoder"] = {f"l{i}": p for i, p in enumerate(eb)}
            axes["encoder"] = {f"l{i}": ea for i in range(enc.num_layers)}
            db, da = [], None
            for i, k in enumerate(jax.random.split(ks[4], cfg.num_layers)):
                p, a, _ = init_block(k, cfg, cross=True)
                db.append(p)
                da = a
            params["decoder"] = {f"l{i}": p for i, p in enumerate(db)}
            axes["decoder"] = {f"l{i}": da for i in range(cfg.num_layers)}
            return params, axes

        if cfg.family == "ssm":
            blocks, baxes = {}, {}
            for i, k in enumerate(jax.random.split(ks[3], cfg.num_layers)):
                if self._is_slstm(i):
                    p, a, _ = L.init_slstm(k, cfg, dtype)
                    blocks[f"l{i}"] = {"cell": p}
                    baxes[f"l{i}"] = {"cell": a}
                else:
                    p, a, _ = L.init_mlstm(k, cfg, dtype)
                    blocks[f"l{i}"] = {"cell": p}
                    baxes[f"l{i}"] = {"cell": a}
                np_, na = L.init_norm(cfg, dtype)
                blocks[f"l{i}"]["norm"] = np_
                baxes[f"l{i}"]["norm"] = na
            params["blocks"], axes["blocks"] = blocks, baxes
            return params, axes

        # scanned families: stack layer params along a leading 'layers' axis
        def one(k):
            p, a, _ = init_block(k, cfg)
            return p, a

        layer_keys = jax.random.split(ks[3], cfg.num_layers)
        _, a0 = one(layer_keys[0])
        stacked = jax.vmap(lambda k: one(k)[0])(layer_keys)
        params["layers"] = stacked
        axes["layers"] = jax.tree.map(lambda ax: ("layers",) + ax, a0,
                                      is_leaf=lambda x: isinstance(x, tuple))
        if cfg.family == "hybrid":
            p, a = {}, {}
            p["attn"], a["attn"] = L.init_attention(ks[5], cfg, dtype)
            p["norm"], a["norm"] = L.init_norm(cfg, dtype)
            params["shared_attn"], axes["shared_attn"] = p, a
        return params, axes

    def init(self, rng):
        return self.init_with_axes(rng)[0]

    def axes(self):
        """Logical-axis tree (static); computed via a shape-only trace."""
        if self._axes is None:
            box = {}

            def f(rng):
                p, a = self.init_with_axes(rng)
                box["a"] = a
                return p

            jax.eval_shape(f, jax.random.key(0))
            self._axes = box["a"]
        return self._axes

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- forward --------------------------------------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(_dtype(cfg))
        else:
            x = params["embed"][batch["tokens"]]
        return x

    def _head(self, params, x):
        cfg = self.cfg
        x = L.apply_norm(params["norm_out"], x, cfg.norm)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return x @ w

    def _positions(self, batch, seq, bsz, offset=0):
        if "positions" in batch:
            return batch["positions"]
        return L.default_positions(bsz, seq, self.cfg.rope, offset)

    def _encode(self, params, batch):
        """Whisper encoder over precomputed frame embeddings."""
        cfg = self.cfg
        x = batch["enc_embeds"].astype(_dtype(cfg)) + params["enc_pos"][None]
        pos = L.default_positions(x.shape[0], x.shape[1], "none")
        for i in range(cfg.encoder.num_layers):
            x, _ = apply_block(params["encoder"][f"l{i}"], x, pos, cfg, {},
                               causal=False, attn_impl=self.attn_impl)
        return L.apply_norm(params["norm_enc"], x, cfg.norm)

    def apply(self, params, batch, *, window="auto", masks=None):
        """Full-sequence logits [B,S,V] (+ aux loss).

        ``masks`` (optional) carries the FedAP filter keep-masks of the
        static-shape masked mode — ``{"mlp": [L, d_ff] 0/1}``, one row per
        scanned layer, riding the layer scan as xs alongside that layer's
        params (structure fixed from round 0, zero re-jit).  Masked units
        are zeroed at the FFN pre-activation, which equals the shrunk
        model's logits exactly (silu(0) = gelu(0) = 0 through wo); when
        d_model/d_ff are 128-aligned the masked matmuls run the Pallas
        ``masked_matmul`` kernel, skipping fully-pruned column blocks.
        """
        cfg = self.cfg
        if masks is not None:
            if cfg.family == "moe":
                raise ValueError(
                    "masks= is unsupported for MoE stacks: a zeroed router "
                    "logit is not -inf, so masked experts would still "
                    "receive routed mass — prune experts with "
                    "Prune(mode='shrink') (core.pruning_lm.prune_lm_experts)")
            if not self.scanned:
                raise ValueError(
                    f"masks= requires a scanned stack, not family "
                    f"{cfg.family!r}")
        if window == "auto":
            window = None            # training/prefill default: full attention
        x = constrain_batch(self._embed_in(params, batch))
        bsz, seq = x.shape[0], x.shape[1]
        pos = self._positions(batch, seq, bsz)
        aux = jnp.zeros((), jnp.float32)

        if cfg.family == "encdec":
            enc = self._encode(params, batch)
            ek, ev = {}, {}
            for i in range(cfg.num_layers):
                blk = params["decoder"][f"l{i}"]
                k = jnp.einsum("bsd,dhk->bshk", enc, blk["xattn"]["wk"])
                v = jnp.einsum("bsd,dhk->bshk", enc, blk["xattn"]["wv"])
                x, _ = apply_block(blk, x, pos, cfg, {}, window=window,
                                   attn_impl=self.attn_impl, cross_kv=(k, v))
                x = constrain_batch(x)
            return self._head(params, x), aux

        if cfg.family == "ssm":
            for i in range(cfg.num_layers):
                blk = params["blocks"][f"l{i}"]
                h = L.apply_norm(blk["norm"], x, cfg.norm)
                if self._is_slstm(i):
                    x = x + L.apply_slstm(blk["cell"], h, self._meta["slstm"], cfg)
                else:
                    x = x + L.apply_mlstm(blk["cell"], h, self._meta["mlstm"], cfg)
                x = constrain_batch(x)
            return self._head(params, x), aux

        # scanned stacks (filter masks, when given, ride the scan as extra
        # xs — each step consumes its layer's params AND its mask row)
        def body(carry, scanned):
            x, aux = carry
            layer_params, layer_masks = \
                scanned if masks is not None else (scanned, None)
            x, a = apply_block(layer_params, x, pos, cfg, self._meta,
                               window=window, attn_impl=self.attn_impl,
                               masks=layer_masks)
            x = constrain_batch(x)
            return (x, aux + a), None

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        elif cfg.remat == "dots":
            # §Perf knob: save matmul outputs, recompute only elementwise —
            # trades activation memory for ~25% less recompute FLOPs
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        if cfg.family == "hybrid":
            # zamba2: the SHARED attention block runs before each group of
            # ``attn_every`` Mamba2 layers (weights reused; per-application
            # KV caches during decode — see init_cache).
            shared = params["shared_attn"]
            for a, b in self.hybrid_groups():
                h = L.apply_norm(shared["norm"], x, cfg.norm)
                x = x + L.attention_block(shared["attn"], h, pos, cfg,
                                          window=cfg.sliding_window,
                                          attn_impl=self.attn_impl)
                x = constrain_batch(x)
                group = jax.tree.map(lambda p: p[a:b], params["layers"])
                if masks is not None:
                    group = (group, jax.tree.map(lambda m: m[a:b], masks))
                (x, aux), _ = jax.lax.scan(body, (x, aux), group)
            return self._head(params, x), aux

        xs = params["layers"] if masks is None else (params["layers"], masks)
        (x, aux), _ = jax.lax.scan(body, (x, aux), xs)
        return self._head(params, x), aux

    # -- loss -------------------------------------------------------------------
    def loss(self, params, batch, *, window="auto", masks=None):
        logits, aux = self.apply(params, batch, window=window, masks=masks)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        if mask is not None:
            nll = nll * mask
            denom = jnp.clip(jnp.sum(mask), 1.0, None)
        else:
            denom = nll.size
        return jnp.sum(nll) / denom + aux

    def loss_and_acc(self, params, x, y, *, masks=None):
        """The simulation-driver model contract (mirrors
        ``PaperModel.loss_and_acc``): positional ``(x, y)`` = (tokens
        [B,S], labels [B,S]) int32 arrays -> (loss, token accuracy).

        Implemented via the pod adapter (:func:`launch.steps.
        loss_and_accuracy`), so the executor backends and the pod step
        share ONE loss/accuracy definition — the seam that lets
        ``FederatedTrainer``/``PlanExecutor`` drive transformer
        fine-tuning with the same code path as the CNN repro."""
        from repro.launch.steps import loss_and_accuracy

        return loss_and_accuracy(self, params, {"tokens": x, "labels": y},
                                 masks=masks)

    # -- FedAP seam (executor Prune events; see repro.core.backend) ----------
    def decide_kept(self, params, p_star, *, align=128):
        """``{"mlp": [L, keep]}`` kept-unit index rows from the Formula-15
        aggregate rate — weight-norm product scores inside the scanned
        stack, uniform ``align``-lane kept count (core.pruning_lm).  A pure
        host function of (params, p_star): the host and mesh FedAP entry
        points make the identical selection."""
        from repro.core import pruning_lm

        return {"mlp": pruning_lm.ffn_kept_indices(
            params, self.cfg, float(p_star), align=align)}

    def filter_masks(self, params, kept):
        """``{"mlp": [L, d_ff] 0/1}`` keep-masks for kernel-mode compute."""
        from repro.core import pruning_lm

        return pruning_lm.ffn_filter_masks(params, kept)

    def param_masks(self, params, kept):
        """Param-structured 0/1 masks (coupling-closed: wi/wg cols + wo
        rows) for the static-shape masked round state."""
        from repro.core import pruning_lm

        return pruning_lm.ffn_param_masks(params, kept)

    def shrink_params(self, params, kept):
        """Structurally gather the kept FFN units (params or any tree of
        identical structure — momentum buffers, FedDyn corrections)."""
        from repro.core import pruning_lm

        idx = kept.get("mlp") if kept else None
        return params if idx is None else pruning_lm.shrink_ffn_at(params, idx)

    # -- decode -------------------------------------------------------------------
    def init_cache(self, batch_size: int, cache_len: int, *, window=None):
        """Decode cache pytree.  ``cache_len`` is the visible context length
        (S for full attention; min(S, window) for ring-buffer archs)."""
        cfg = self.cfg
        dtype = _dtype(cfg)
        kvh, hd = cfg.padded_num_kv_heads, cfg.resolved_head_dim
        eff = cache_len if window is None else min(cache_len, window)

        def kv(n_layers, length):
            return {
                "k": jnp.zeros((n_layers, batch_size, length, kvh, hd), dtype),
                "v": jnp.zeros((n_layers, batch_size, length, kvh, hd), dtype),
            }

        if cfg.family == "encdec":
            return {
                "self": kv(cfg.num_layers, eff),
                "cross": kv(cfg.num_layers, cfg.encoder.frames),
                "index": jnp.zeros((), jnp.int32),
            }
        if cfg.family == "ssm":
            cache = {"index": jnp.zeros((), jnp.int32)}
            for i in range(cfg.num_layers):
                if self._is_slstm(i):
                    cache[f"l{i}"] = L.slstm_init_state(batch_size, cfg.d_model, dtype)
                else:
                    cache[f"l{i}"] = L.mlstm_init_state(batch_size, self._meta["mlstm"], dtype)
            return cache
        if cfg.family == "hybrid":
            m = self._meta["mamba"]
            lcount = cfg.num_layers
            n_groups = len(self.hybrid_groups())
            attn_len = min(eff, cfg.sliding_window or eff)
            conv = jnp.zeros((lcount, batch_size, cfg.ssm.conv_width - 1,
                              m["d_in"] + 2 * m["n"]), dtype)
            h = jnp.zeros((lcount, batch_size, m["nh"], m["p"], m["n"]), jnp.float32)
            return {
                "mamba": {"conv": conv, "h": h},
                # one KV cache per shared-attention APPLICATION (weights are
                # shared across groups; caches are not)
                "shared_attn": {
                    "k": jnp.zeros((n_groups, batch_size, attn_len, kvh, hd), dtype),
                    "v": jnp.zeros((n_groups, batch_size, attn_len, kvh, hd), dtype),
                },
                "index": jnp.zeros((), jnp.int32),
            }
        return {**kv(cfg.num_layers, eff), "index": jnp.zeros((), jnp.int32)}

    def prefill_cross(self, params, cache, batch):
        """encdec only: compute the fixed cross-attention K/V from the
        encoder output once, before decoding."""
        cfg = self.cfg
        enc = self._encode(params, batch)
        ks, vs = [], []
        for i in range(cfg.num_layers):
            blk = params["decoder"][f"l{i}"]
            ks.append(jnp.einsum("bsd,dhk->bshk", enc, blk["xattn"]["wk"]))
            vs.append(jnp.einsum("bsd,dhk->bshk", enc, blk["xattn"]["wv"]))
        cache["cross"]["k"] = jnp.stack(ks)
        cache["cross"]["v"] = jnp.stack(vs)
        return cache

    def decode_step(self, params, cache, batch, *, masks=None):
        """One-token decode.  batch: tokens [B,1] (or embeds [B,1,d]).
        Returns (logits [B,1,V], new cache).

        ``cache["index"]`` may be a scalar (lockstep decode) or an int32
        [B] vector (continuous batching: per-slot fill levels — rope
        positions, cache writes and attention validity all follow the
        per-slot index; see :func:`layers.attention_decode`).

        ``masks`` (optional) carries the FedAP filter keep-masks exactly
        as in :meth:`apply` — ``{"mlp": [L, d_ff] 0/1}`` riding the layer
        scan as zipped xs — so a mask-mode pruned checkpoint decodes
        through the block-skipping masked FFN matmuls at the dense shapes
        (logits identical to the shrunk model's)."""
        cfg = self.cfg
        if masks is not None:
            if cfg.family == "moe":
                raise ValueError(
                    "masks= is unsupported for MoE stacks: a zeroed router "
                    "logit is not -inf, so masked experts would still "
                    "receive routed mass — prune experts with "
                    "Prune(mode='shrink') (core.pruning_lm.prune_lm_experts)")
            if not self.scanned:
                raise ValueError(
                    f"masks= requires a scanned stack, not family "
                    f"{cfg.family!r}")
        x = constrain_batch(self._embed_in(params, batch))
        bsz = x.shape[0]
        idx = cache["index"]
        step_off = idx if jnp.ndim(idx) == 0 else idx[None, :, None]
        pos = self._positions(batch, 1, bsz) if "positions" in batch else \
            L.default_positions(bsz, 1, cfg.rope) + step_off

        if cfg.family == "encdec":
            new_k, new_v = [], []
            for i in range(cfg.num_layers):
                blk = params["decoder"][f"l{i}"]
                h = L.apply_norm(blk["norm_a"], x, cfg.norm)
                y, ck, cv = L.attention_decode(
                    blk["attn"], h, cache["self"]["k"][i], cache["self"]["v"][i],
                    idx, pos, cfg, attn_impl=self.attn_impl)
                new_k.append(ck)
                new_v.append(cv)
                x = x + y
                h = L.apply_norm(blk["norm_x"], x, cfg.norm)
                x = x + L.attention_decode_cross(
                    blk["xattn"], h, cache["cross"]["k"][i], cache["cross"]["v"][i], cfg)
                h = L.apply_norm(blk["norm_f"], x, cfg.norm)
                x = x + L.apply_mlp(blk["mlp"], h, cfg.act)
            cache = {**cache, "self": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
                     "index": idx + 1}
            return self._head(params, x), cache

        if cfg.family == "ssm":
            cache = dict(cache)
            for i in range(cfg.num_layers):
                blk = params["blocks"][f"l{i}"]
                h = L.apply_norm(blk["norm"], x, cfg.norm)
                if self._is_slstm(i):
                    y, cache[f"l{i}"] = L.slstm_decode(blk["cell"], h, cache[f"l{i}"],
                                                       self._meta["slstm"], cfg)
                else:
                    y, cache[f"l{i}"] = L.mlstm_decode(blk["cell"], h, cache[f"l{i}"],
                                                       self._meta["mlstm"], cfg)
                x = x + y
            cache["index"] = idx + 1
            return self._head(params, x), cache

        if cfg.family == "hybrid":
            shared = params["shared_attn"]

            def body(carry, scanned):
                x = carry
                layer_params, conv, h = scanned
                hh = L.apply_norm(layer_params["norm_m"], x, cfg.norm)
                y, (conv, h) = L.mamba2_decode(layer_params["mamba"], hh, (conv, h),
                                               self._meta["mamba"], cfg)
                x = x + y
                hh = L.apply_norm(layer_params["norm_f"], x, cfg.norm)
                x = x + L.apply_mlp(layer_params["mlp"], hh, cfg.act)
                return x, (conv, h)

            convs, hs = [], []
            new_sk = list(range(len(self.hybrid_groups())))
            new_sv = list(range(len(self.hybrid_groups())))
            for gi, (a, b) in enumerate(self.hybrid_groups()):
                # shared attention before the group, with ITS OWN kv cache
                hh = L.apply_norm(shared["norm"], x, cfg.norm)
                y, ck, cv = L.attention_decode(
                    shared["attn"], hh, cache["shared_attn"]["k"][gi],
                    cache["shared_attn"]["v"][gi], idx, pos, cfg,
                    window=cfg.sliding_window, attn_impl=self.attn_impl)
                x = x + y
                new_sk[gi], new_sv[gi] = ck, cv
                group = jax.tree.map(lambda p: p[a:b], params["layers"])
                x, (conv, h) = jax.lax.scan(
                    body, x, (group, cache["mamba"]["conv"][a:b],
                              cache["mamba"]["h"][a:b]))
                convs.append(conv)
                hs.append(h)
            cache = {"mamba": {"conv": jnp.concatenate(convs),
                               "h": jnp.concatenate(hs)},
                     "shared_attn": {"k": jnp.stack(new_sk), "v": jnp.stack(new_sv)},
                     "index": idx + 1}
            return self._head(params, x), cache

        # scanned dense/moe/vlm decode (filter masks, when given, ride the
        # layer scan as extra xs — same zip as apply())
        def body(carry, scanned):
            x, li = carry
            if masks is not None:
                layer_params, ck, cv, layer_masks = scanned
            else:
                layer_params, ck, cv = scanned
                layer_masks = None
            h = L.apply_norm(layer_params["norm_a"], x, cfg.norm)
            y, ck, cv = L.attention_decode(layer_params["attn"], h, ck, cv, idx, pos,
                                           cfg, attn_impl=self.attn_impl)
            x = x + y
            h = L.apply_norm(layer_params["norm_f"], x, cfg.norm)
            if cfg.family == "moe":
                y, _ = L.apply_moe(layer_params["moe"], h, cfg)
                x = x + y
            else:
                x = x + L.apply_mlp(layer_params["mlp"], h, cfg.act,
                                    None if layer_masks is None
                                    else layer_masks["mlp"])
            return (x, li + 1), (ck, cv)

        xs = (params["layers"], cache["k"], cache["v"])
        if masks is not None:
            xs = xs + (masks,)
        (x, _), (k_new, v_new) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.int32)), xs)
        cache = {"k": k_new, "v": v_new, "index": idx + 1}
        return self._head(params, x), cache
