from repro.models.cnn import (
    LeNet5,
    PaperModel,
    ResNet18,
    SimpleCNN,
    VGG11,
    masked_dense,
)

__all__ = ["LeNet5", "PaperModel", "ResNet18", "SimpleCNN", "VGG11",
           "masked_dense"]
