"""Public model-building API + input specs for every (arch x shape).

``input_specs`` is the single source of truth for what each input shape
means per family — used by smoke tests (concrete arrays) and by the
multi-pod dry-run (ShapeDtypeStruct stand-ins, no allocation).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models.lm import LM


def build_model(cfg: ModelConfig, *, attn_impl: str = "xla") -> LM:
    return LM(cfg, attn_impl=attn_impl)


def _pos_streams(cfg: ModelConfig) -> int:
    return {"none": 1, "1d": 1, "2d": 2, "mrope": 3}[cfg.rope]


def decode_cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Visible context during decode.  long_500k uses the sliding window
    (ring-buffer) for attention archs; SSM/hybrid have O(1) state anyway."""
    if shape.name == "long_500k" and cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape, *, abstract: bool = True,
                seed: int = 0) -> dict[str, Any]:
    """Batch pytree for (cfg, shape).

    abstract=True  -> jax.ShapeDtypeStruct leaves (dry-run lowering)
    abstract=False -> concrete random arrays (smoke tests / benchmarks)
    """
    b, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.param_dtype)
    rng = np.random.default_rng(seed)

    def arr(shp, dt, high=None):
        if abstract:
            return jax.ShapeDtypeStruct(shp, dt)
        if jnp.issubdtype(dt, jnp.integer):
            return jnp.asarray(rng.integers(0, high or cfg.vocab_size, shp), dt)
        return jnp.asarray(rng.standard_normal(shp), dt)

    if shape.kind == "decode":
        s_tok = 1
    else:
        s_tok = s

    batch: dict[str, Any] = {}
    if cfg.family == "vlm":
        batch["embeds"] = arr((b, s_tok, cfg.d_model), dtype)
        batch["positions"] = arr((_pos_streams(cfg), b, s_tok), jnp.int32, high=s)
    elif cfg.family == "encdec":
        batch["enc_embeds"] = arr((b, cfg.encoder.frames, cfg.d_model), dtype)
        batch["tokens"] = arr((b, s_tok), jnp.int32)
    else:
        batch["tokens"] = arr((b, s_tok), jnp.int32)

    if shape.kind == "train":
        batch["labels"] = arr((b, s_tok), jnp.int32)
        if cfg.family == "vlm":
            if abstract:
                batch["loss_mask"] = jax.ShapeDtypeStruct((b, s_tok), jnp.float32)
            else:  # vision-token positions excluded from the LM loss
                batch["loss_mask"] = jnp.asarray(
                    rng.random((b, s_tok)) > 0.25, jnp.float32)
    return batch
