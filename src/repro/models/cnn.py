"""The paper's evaluation models (Section 4.1), pure-JAX functional.

* CNN      — 3 conv (32/64/64, 3x3) + FC(64) + softmax head; ~122k params.
* LeNet5   — classic 6/16 conv + 120/84 FC.
* VGG11    — conv 64-128-256x2-512x4 + FC head (CIFAR variant).
* ResNet18 — basic blocks with GroupNorm (BN is unsound under FL
             aggregation; GN is the standard substitution).

Design constraints that matter for FedAP:
  * ``apply`` infers every channel count from the parameter shapes, so a
    structurally-pruned parameter tree runs through the SAME code.
  * FC weights that consume flattened conv maps are stored as
    [spatial, channels, out] so a channel prune is a single axis-1 slice
    (see CoupledParam in repro.core.pruning).
  * ``feature_maps`` returns post-activation maps keyed by layer name —
    the HRank statistic is computed on these.
  * ``apply(..., masks=...)`` takes the per-layer keep-masks of the
    static-shape masked mode (``pruning.filter_masks``): masked layers
    zero their feature maps, and dense layers with an output mask route
    through :func:`masked_dense` — the Pallas ``masked_matmul`` kernel
    when the feature dims are 128-aligned (pruned column blocks skipped
    on the MXU; the batch dim is zero-padded to the block multiple), an
    XLA fallback otherwise.  The kernel is differentiable (custom VJP
    with block-skipping backward kernels), so the SAME path serves
    training (``EngineConfig.masked_compute="kernel"``) and serving.
    For 0/1 masks this is numerically identical to running the
    mask-multiplied parameter tree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.pruning import CoupledParam, PrunableLayer, PruneSpec


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def conv2d(x, w, b=None, *, stride=1, padding="SAME"):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        out = out + b
    return out


def max_pool(x, size=2, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1), "SAME")


def avg_pool_global(x):
    return jnp.mean(x, axis=(1, 2))


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = math.gcd(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


def _he(rng, shape, fan_in):
    return (jax.random.normal(rng, shape) * jnp.sqrt(2.0 / fan_in)).astype(jnp.float32)


def _conv_init(rng, kh, kw, cin, cout):
    return {"w": _he(rng, (kh, kw, cin, cout), kh * kw * cin),
            "b": jnp.zeros((cout,), jnp.float32)}


def _dense_init(rng, fin, fout):
    return {"w": _he(rng, (fin, fout), fin), "b": jnp.zeros((fout,), jnp.float32)}


def _mask_channels(h, masks, name):
    """Zero the feature maps of pruned filters (masks[name]: [d] of 0/1,
    broadcast over the trailing channel axis).  For 0/1 masks this equals
    masking the layer's weight+bias, since relu(z) * m == relu(z * m)."""
    if masks is None or name not in masks:
        return h
    return h * masks[name]


# masked_dense moved to repro.models.layers (shared with the LM FFN
# stacks); re-exported here because this module is its historical home.
from repro.models.layers import masked_dense  # noqa: E402,F401


def softmax_xent_acc(logits, y):
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# model base
# ---------------------------------------------------------------------------

class PaperModel:
    """Functional-model facade shared by all paper models."""

    def init(self, rng) -> Any:
        raise NotImplementedError

    def apply(self, params, x, *, collect: bool = False, masks=None):
        raise NotImplementedError

    def loss_and_acc(self, params, x, y, *, masks=None):
        logits = self.apply(params, x, masks=masks)
        return softmax_xent_acc(logits, y)

    def feature_maps(self, params, x) -> dict[str, jnp.ndarray]:
        _, fmaps = self.apply(params, x, collect=True)
        return fmaps

    def prune_spec(self, params) -> PruneSpec:
        raise NotImplementedError

    def with_pruned(self, kept) -> "PaperModel":
        return self  # apply() is shape-polymorphic

    def flops_per_example(self, params, image_shape) -> float:
        """Analytic MAC-based FLOPs (matches the paper's MFLOPs columns)."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# CNN — the paper's synthetic 122570-param network
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimpleCNN(PaperModel):
    num_classes: int = 10
    image_shape: tuple = (32, 32, 3)
    channels: tuple = (32, 64, 64)
    fc_width: int = 64

    def init(self, rng):
        c = self.image_shape[-1]
        k = jax.random.split(rng, 5)
        h, w = self.image_shape[:2]
        # conv1 + pool, conv2 + pool, conv3 (no pool)
        h1, w1 = (h + 1) // 2, (w + 1) // 2
        h2, w2 = (h1 + 1) // 2, (w1 + 1) // 2
        spatial = h2 * w2
        params = {
            "conv1": _conv_init(k[0], 3, 3, c, self.channels[0]),
            "conv2": _conv_init(k[1], 3, 3, self.channels[0], self.channels[1]),
            "conv3": _conv_init(k[2], 3, 3, self.channels[1], self.channels[2]),
            "fc1": {"w": _he(k[3], (spatial, self.channels[2], self.fc_width),
                             spatial * self.channels[2]),
                    "b": jnp.zeros((self.fc_width,), jnp.float32)},
            "out": _dense_init(k[4], self.fc_width, self.num_classes),
        }
        return params

    def apply(self, params, x, *, collect=False, masks=None):
        fmaps = {}
        h = jax.nn.relu(conv2d(x, params["conv1"]["w"], params["conv1"]["b"]))
        h = _mask_channels(h, masks, "conv1")
        fmaps["conv1"] = h
        h = max_pool(h)
        h = jax.nn.relu(conv2d(h, params["conv2"]["w"], params["conv2"]["b"]))
        h = _mask_channels(h, masks, "conv2")
        fmaps["conv2"] = h
        h = max_pool(h)
        h = jax.nn.relu(conv2d(h, params["conv3"]["w"], params["conv3"]["b"]))
        h = _mask_channels(h, masks, "conv3")
        fmaps["conv3"] = h
        b = h.shape[0]
        h = h.reshape(b, -1, h.shape[-1])                       # [B, spatial, C]
        if masks is not None and "fc1" in masks:
            w1 = params["fc1"]["w"]
            h = jax.nn.relu(masked_dense(h.reshape(b, -1),
                                         w1.reshape(-1, w1.shape[-1]),
                                         masks["fc1"], params["fc1"]["b"]))
        else:
            h = jax.nn.relu(jnp.einsum("bpc,pcf->bf", h, params["fc1"]["w"]) + params["fc1"]["b"])
        fmaps["fc1"] = h
        logits = h @ params["out"]["w"] + params["out"]["b"]
        return (logits, fmaps) if collect else logits

    def prune_spec(self, params):
        return PruneSpec(layers=(
            PrunableLayer("conv1", ("conv1", "w"), 3,
                          (CoupledParam(("conv1", "b"), 0), CoupledParam(("conv2", "w"), 2))),
            PrunableLayer("conv2", ("conv2", "w"), 3,
                          (CoupledParam(("conv2", "b"), 0), CoupledParam(("conv3", "w"), 2))),
            PrunableLayer("conv3", ("conv3", "w"), 3,
                          (CoupledParam(("conv3", "b"), 0), CoupledParam(("fc1", "w"), 1))),
        ))

    def flops_per_example(self, params, image_shape=None):
        image_shape = image_shape or self.image_shape
        h, w, _ = image_shape
        f = 0.0
        shapes = [(h, w), ((h + 1) // 2, (w + 1) // 2), ((h + 3) // 4, (w + 3) // 4)]
        for i, name in enumerate(["conv1", "conv2", "conv3"]):
            kh, kw, cin, cout = params[name]["w"].shape
            f += 2 * kh * kw * cin * cout * shapes[i][0] * shapes[i][1]
        f += 2 * params["fc1"]["w"].size + 2 * params["out"]["w"].size
        return f


# ---------------------------------------------------------------------------
# LeNet5
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LeNet5(PaperModel):
    num_classes: int = 10
    image_shape: tuple = (32, 32, 3)

    def init(self, rng):
        c = self.image_shape[-1]
        k = jax.random.split(rng, 5)
        h, w = self.image_shape[:2]
        h1, w1 = (h + 1) // 2, (w + 1) // 2
        h2, w2 = (h1 + 1) // 2, (w1 + 1) // 2
        return {
            "conv1": _conv_init(k[0], 5, 5, c, 6),
            "conv2": _conv_init(k[1], 5, 5, 6, 16),
            "fc1": {"w": _he(k[2], (h2 * w2, 16, 120), h2 * w2 * 16),
                    "b": jnp.zeros((120,), jnp.float32)},
            "fc2": _dense_init(k[3], 120, 84),
            "out": _dense_init(k[4], 84, self.num_classes),
        }

    def apply(self, params, x, *, collect=False, masks=None):
        fmaps = {}
        h = jax.nn.relu(conv2d(x, params["conv1"]["w"], params["conv1"]["b"]))
        h = _mask_channels(h, masks, "conv1")
        fmaps["conv1"] = h
        h = max_pool(h)
        h = jax.nn.relu(conv2d(h, params["conv2"]["w"], params["conv2"]["b"]))
        h = _mask_channels(h, masks, "conv2")
        fmaps["conv2"] = h
        h = max_pool(h)
        b = h.shape[0]
        h = h.reshape(b, -1, h.shape[-1])
        if masks is not None and "fc1" in masks:
            w1 = params["fc1"]["w"]
            h = jax.nn.relu(masked_dense(h.reshape(b, -1),
                                         w1.reshape(-1, w1.shape[-1]),
                                         masks["fc1"], params["fc1"]["b"]))
        else:
            h = jax.nn.relu(jnp.einsum("bpc,pcf->bf", h, params["fc1"]["w"]) + params["fc1"]["b"])
        fmaps["fc1"] = h
        if masks is not None and "fc2" in masks:
            h = jax.nn.relu(masked_dense(h, params["fc2"]["w"], masks["fc2"],
                                         params["fc2"]["b"]))
        else:
            h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
        fmaps["fc2"] = h
        logits = h @ params["out"]["w"] + params["out"]["b"]
        return (logits, fmaps) if collect else logits

    def prune_spec(self, params):
        return PruneSpec(layers=(
            PrunableLayer("conv1", ("conv1", "w"), 3,
                          (CoupledParam(("conv1", "b"), 0), CoupledParam(("conv2", "w"), 2))),
            PrunableLayer("conv2", ("conv2", "w"), 3,
                          (CoupledParam(("conv2", "b"), 0), CoupledParam(("fc1", "w"), 1))),
            PrunableLayer("fc1", ("fc1", "w"), 2,
                          (CoupledParam(("fc1", "b"), 0), CoupledParam(("fc2", "w"), 0))),
            PrunableLayer("fc2", ("fc2", "w"), 1,
                          (CoupledParam(("fc2", "b"), 0), CoupledParam(("out", "w"), 0))),
        ))

    def flops_per_example(self, params, image_shape=None):
        image_shape = image_shape or self.image_shape
        h, w, _ = image_shape
        f = 0.0
        shapes = [(h, w), ((h + 1) // 2, (w + 1) // 2)]
        for i, name in enumerate(["conv1", "conv2"]):
            kh, kw, cin, cout = params[name]["w"].shape
            f += 2 * kh * kw * cin * cout * shapes[i][0] * shapes[i][1]
        for name in ["fc1", "fc2", "out"]:
            f += 2 * params[name]["w"].size
        return f


# ---------------------------------------------------------------------------
# VGG11 (CIFAR variant)
# ---------------------------------------------------------------------------

_VGG11_PLAN = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


@dataclasses.dataclass
class VGG11(PaperModel):
    num_classes: int = 10
    image_shape: tuple = (32, 32, 3)
    width_mult: float = 1.0

    def _plan(self):
        return [v if v == "M" else max(8, int(v * self.width_mult)) for v in _VGG11_PLAN]

    def init(self, rng):
        plan = self._plan()
        convs = [v for v in plan if v != "M"]
        keys = jax.random.split(rng, len(convs) + 1)
        params = {}
        cin = self.image_shape[-1]
        ci = 0
        for v in plan:
            if v == "M":
                continue
            params[f"conv{ci}"] = _conv_init(keys[ci], 3, 3, cin, v)
            cin = v
            ci += 1
        params["out"] = _dense_init(keys[-1], cin, self.num_classes)
        return params

    def apply(self, params, x, *, collect=False, masks=None):
        fmaps = {}
        h = x
        ci = 0
        for v in self._plan():
            if v == "M":
                h = max_pool(h)
            else:
                p = params[f"conv{ci}"]
                h = jax.nn.relu(conv2d(h, p["w"], p["b"]))
                h = _mask_channels(h, masks, f"conv{ci}")
                fmaps[f"conv{ci}"] = h
                ci += 1
        h = avg_pool_global(h)
        logits = h @ params["out"]["w"] + params["out"]["b"]
        return (logits, fmaps) if collect else logits

    def prune_spec(self, params):
        n_convs = sum(1 for v in _VGG11_PLAN if v != "M")
        layers = []
        for i in range(n_convs):
            nxt = (CoupledParam((f"conv{i + 1}", "w"), 2) if i + 1 < n_convs
                   else CoupledParam(("out", "w"), 0))
            layers.append(PrunableLayer(
                f"conv{i}", (f"conv{i}", "w"), 3,
                (CoupledParam((f"conv{i}", "b"), 0), nxt)))
        return PruneSpec(layers=tuple(layers))

    def flops_per_example(self, params, image_shape=None):
        image_shape = image_shape or self.image_shape
        h, w, _ = image_shape
        f, ci = 0.0, 0
        for v in self._plan():
            if v == "M":
                h, w = (h + 1) // 2, (w + 1) // 2
            else:
                kh, kw, cin, cout = params[f"conv{ci}"]["w"].shape
                f += 2 * kh * kw * cin * cout * h * w
                ci += 1
        f += 2 * params["out"]["w"].size
        return f


# ---------------------------------------------------------------------------
# ResNet18 with GroupNorm
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResNet18(PaperModel):
    num_classes: int = 100
    image_shape: tuple = (32, 32, 3)
    width: int = 64

    _stages = (2, 2, 2, 2)

    def init(self, rng):
        w0 = self.width
        keys = iter(jax.random.split(rng, 64))
        params = {"stem": _conv_init(next(keys), 3, 3, self.image_shape[-1], w0)}
        params["stem_gn"] = {"scale": jnp.ones((w0,)), "bias": jnp.zeros((w0,))}
        cin = w0
        for s, blocks in enumerate(self._stages):
            cout = w0 * (2 ** s)
            for b in range(blocks):
                name = f"s{s}b{b}"
                stride = 2 if (b == 0 and s > 0) else 1
                blk = {
                    "conv1": _conv_init(next(keys), 3, 3, cin, cout),
                    "gn1": {"scale": jnp.ones((cout,)), "bias": jnp.zeros((cout,))},
                    "conv2": _conv_init(next(keys), 3, 3, cout, cout),
                    "gn2": {"scale": jnp.ones((cout,)), "bias": jnp.zeros((cout,))},
                }
                if stride != 1 or cin != cout:
                    blk["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
                params[name] = blk
                cin = cout
        params["out"] = _dense_init(next(keys), cin, self.num_classes)
        return params

    def apply(self, params, x, *, collect=False, masks=None):
        fmaps = {}
        h = jax.nn.relu(group_norm(conv2d(x, params["stem"]["w"], params["stem"]["b"]),
                                   params["stem_gn"]["scale"], params["stem_gn"]["bias"]))
        for s, blocks in enumerate(self._stages):
            for b in range(blocks):
                name = f"s{s}b{b}"
                blk = params[name]
                stride = 2 if (b == 0 and s > 0) else 1
                y = jax.nn.relu(group_norm(
                    conv2d(h, blk["conv1"]["w"], blk["conv1"]["b"], stride=stride),
                    blk["gn1"]["scale"], blk["gn1"]["bias"]))
                y = _mask_channels(y, masks, f"{name}.conv1")
                fmaps[f"{name}.conv1"] = y
                y = group_norm(conv2d(y, blk["conv2"]["w"], blk["conv2"]["b"]),
                               blk["gn2"]["scale"], blk["gn2"]["bias"])
                sc = h
                if "proj" in blk:
                    sc = conv2d(h, blk["proj"]["w"], blk["proj"]["b"], stride=stride)
                h = jax.nn.relu(y + sc)
        h = avg_pool_global(h)
        logits = h @ params["out"]["w"] + params["out"]["b"]
        return (logits, fmaps) if collect else logits

    def prune_spec(self, params):
        # Prune only each block's FIRST conv: its output feeds conv2's input
        # only, so residual shapes are untouched (standard residual-safe rule).
        layers = []
        for s, blocks in enumerate(self._stages):
            for b in range(blocks):
                name = f"s{s}b{b}"
                layers.append(PrunableLayer(
                    f"{name}.conv1", (name, "conv1", "w"), 3,
                    (CoupledParam((name, "conv1", "b"), 0),
                     CoupledParam((name, "gn1", "scale"), 0),
                     CoupledParam((name, "gn1", "bias"), 0),
                     CoupledParam((name, "conv2", "w"), 2))))
        return PruneSpec(layers=tuple(layers))

    def flops_per_example(self, params, image_shape=None):
        image_shape = image_shape or self.image_shape
        h, w, _ = image_shape
        f = 2 * 9 * self.image_shape[-1] * params["stem"]["w"].shape[-1] * h * w
        for s, blocks in enumerate(self._stages):
            for b in range(blocks):
                name = f"s{s}b{b}"
                blk = params[name]
                stride = 2 if (b == 0 and s > 0) else 1
                h, w = (h + stride - 1) // stride, (w + stride - 1) // stride
                for cname in ["conv1", "conv2"]:
                    kh, kw, cin, cout = blk[cname]["w"].shape
                    f += 2 * kh * kw * cin * cout * h * w
                if "proj" in blk:
                    kh, kw, cin, cout = blk["proj"]["w"].shape
                    f += 2 * kh * kw * cin * cout * h * w
        f += 2 * params["out"]["w"].size
        return f
