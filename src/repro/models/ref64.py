"""Pure-NumPy float64 forward of the dense LM — the precision oracle.

The jax model (:class:`repro.models.lm.LM`) hard-casts its numerically
sensitive stages (rmsnorm statistics, rope angles, attention softmax) to
f32 — correct for training, but it means the model can never serve as its
own high-precision reference.  This module re-implements the dense-family
forward end-to-end in float64 NumPy — embedding, rmsnorm, 1d rope, GQA
causal attention, (masked) SwiGLU/GELU FFN, logits, loss/accuracy — with
no jax involvement, mirroring the :mod:`repro.core.ref_engine` oracle
idiom at the model level.

What it buys:

* ``tests/test_ref64.py`` locks the f32 jax forward against the f64
  truth (the whole-model float error budget, not just op-level allclose);
* the FedAP mask == shrink identity is PROVABLE here: in f64 with exact
  0/1 masks, the masked forward and the structurally compacted forward
  are bit-identical (silu(0) = gelu(0) = 0 through wo) — any deviation in
  the jax paths is therefore float reassociation, not semantics.

Scope: the scanned dense family (stacked ``params["layers"]``, rmsnorm,
rope 1d, silu/gelu) — the family the serving and FedAP-LM paths run on.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig

EPS = 1e-5   # matches layers.apply_norm


def _f64(tree):
    if isinstance(tree, dict):
        return {k: _f64(v) for k, v in tree.items()}
    return np.asarray(tree, np.float64)


def _check_cfg(cfg: ModelConfig):
    if cfg.family != "dense":
        raise ValueError(f"ref64 covers the dense family, not {cfg.family!r}")
    if cfg.norm != "rmsnorm" or cfg.rope != "1d":
        raise ValueError(
            f"ref64 covers norm='rmsnorm' + rope='1d', got "
            f"norm={cfg.norm!r} rope={cfg.rope!r}")
    if cfg.act not in ("silu", "gelu"):
        raise ValueError(f"ref64 covers act in ('silu','gelu'), {cfg.act!r}")


def rmsnorm(x, scale):
    y = x / np.sqrt(np.mean(np.square(x), -1, keepdims=True) + EPS)
    return y * scale


def rope_1d(x, positions, base: float = 10000.0):
    """x [B,S,n,hd], positions [S] -> interleaved-pairs rotation in f64."""
    hd = x.shape[-1]
    freqs = 1.0 / (base ** (np.arange(0, hd, 2, dtype=np.float64) / hd))
    ang = np.asarray(positions, np.float64)[:, None] * freqs   # [S, hd//2]
    sin = np.sin(ang)[None, :, None, :]
    cos = np.cos(ang)[None, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return np.stack([y1, y2], axis=-1).reshape(x.shape)


def _softmax(scores):
    m = np.max(scores, -1, keepdims=True)
    e = np.exp(scores - m)
    return e / np.sum(e, -1, keepdims=True)


def gqa_causal_attention(q, k, v):
    """q [B,S,H,hd], k/v [B,S,KV,hd]; [g, kv] head grouping as in
    layers.attention_ref."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, g, kvh, hd)
    scores = np.einsum("bqgkd,bskd->bgkqs", qg, k) / np.sqrt(float(hd))
    causal = np.tril(np.ones((s, s), bool))
    scores = np.where(causal[None, None, None], scores, -np.inf)
    w = _softmax(scores)
    out = np.einsum("bgkqs,bskd->bqgkd", w, v)
    return out.reshape(b, s, h, hd)


def _silu(x):
    return x / (1.0 + np.exp(-x))


def _gelu(x):
    # jax.nn.gelu default: tanh approximation
    return 0.5 * x * (1.0 + np.tanh(
        np.sqrt(2.0 / np.pi) * (x + 0.044715 * x ** 3)))


def mlp(blk, h, act: str, mask=None):
    hi = h @ blk["wi"]
    if mask is not None:
        hi = hi * mask                       # pre-activation zeroing
    if act == "silu":
        hg = h @ blk["wg"]
        if mask is not None:
            hg = hg * mask
        hi = _silu(hg) * hi
    else:
        hi = _gelu(hi)
    return hi @ blk["wo"]


def forward_f64(cfg: ModelConfig, params, tokens, masks=None):
    """Full-sequence logits [B,S,V] in float64.

    ``params`` is the jax LM param tree (stacked ``layers``); ``masks``
    the optional FedAP filter keep-masks ``{"mlp": [L, d_ff]}``.
    """
    _check_cfg(cfg)
    p = _f64(params)
    tokens = np.asarray(tokens)
    x = p["embed"][tokens]                                  # [B,S,d]
    positions = np.arange(tokens.shape[1])
    for layer in range(cfg.num_layers):
        blk = {k: (v if not isinstance(v, dict)
                   else {k2: v2[layer] for k2, v2 in v.items()})
               for k, v in p["layers"].items()}
        mask = (None if masks is None
                else np.asarray(masks["mlp"][layer], np.float64))

        h = rmsnorm(x, blk["norm_a"]["scale"])
        q = np.einsum("bsd,dhk->bshk", h, blk["attn"]["wq"])
        k = np.einsum("bsd,dhk->bshk", h, blk["attn"]["wk"])
        v = np.einsum("bsd,dhk->bshk", h, blk["attn"]["wv"])
        q = rope_1d(q, positions)
        k = rope_1d(k, positions)
        out = gqa_causal_attention(q, k, v)
        x = x + np.einsum("bshk,hkd->bsd", out, blk["attn"]["wo"])

        h = rmsnorm(x, blk["norm_f"]["scale"])
        x = x + mlp(blk["mlp"], h, cfg.act, mask)

    x = rmsnorm(x, p["norm_out"]["scale"])
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    return x @ w


def loss_and_acc_f64(cfg: ModelConfig, params, tokens, labels, masks=None):
    """Next-token CE + token accuracy in f64 (mirrors LM.loss_and_acc)."""
    logits = forward_f64(cfg, params, tokens, masks=masks)
    labels = np.asarray(labels)
    m = np.max(logits, -1, keepdims=True)
    logp = logits - m - np.log(np.sum(np.exp(logits - m), -1, keepdims=True))
    nll = -np.take_along_axis(logp, labels[..., None], -1)[..., 0]
    acc = np.mean(np.argmax(logits, -1) == labels)
    return float(np.mean(nll)), float(acc)
